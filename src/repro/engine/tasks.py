"""Pluggable federated tasks — the fourth registry axis (DESIGN.md §7).

A ``Task`` owns everything workload-specific that the round protocol
needs, so ``Engine`` and its backends stay workload-agnostic:

- ``partition_labels``  — the (N,) per-example label axis the non-IID
                          partitioner splits on (class labels for
                          classification, derived topic labels for LM)
- ``client_features``   — the (K, D) normalized histograms clients ship
                          the server for clustering (label histograms
                          for classification, token histograms for LM —
                          FedLECC's Hellinger geometry is distribution-
                          agnostic, so the same OPTICS + Algorithm 1
                          pipeline drives both)
- ``init_params``       — model init from the experiment seed
- ``build_fns``         — the ``(apply_fn, loss_fn, metric_fn)`` triple
                          consumed by ``local_train``, the loss poll,
                          and evaluation.  The contract is
                          ``loss_fn(apply_fn(params, x), y, weights)``;
                          ``apply_fn`` may return any pytree "context"
                          (classification returns logits; LM returns
                          ``(hidden, head)`` so the (B, S, V) logits
                          tensor never materializes)

Tasks self-register via ``@register_task``; ``FLConfig.task`` selects
one and ``FLConfig.task_kwargs`` parameterizes it (JSON-safe values
only, so configs keep round-tripping through ``to_dict``/``from_dict``).

``classification`` is the default and reproduces the pre-task engine
bit-for-bit (same partition, same MLP init stream, same jitted graphs).
``lm`` wraps ``repro.models.transformer`` + ``make_token_stream`` so
``FLConfig(task="lm", backend="host"|"compiled"|"scaleout")`` runs a
federated language model through the identical round protocol.

Imports of the training stack are lazy (method-local) so that config
validation — which resolves ``cfg.task`` against this module — never
drags in model code.
"""

from __future__ import annotations

from typing import Any, Callable

import numpy as np

from repro.engine.registry import register_task

__all__ = ["Task", "ClassificationTask", "LMTask", "build_task"]


class Task:
    """Workload contract consumed by ``Engine``.  Subclasses register
    with ``@register_task("name")`` and take ``(cfg, **task_kwargs)``."""

    name = "base"

    def __init__(self, cfg: Any):
        self.cfg = cfg

    # -- data → partition ------------------------------------------------
    def partition_labels(self, train) -> np.ndarray:
        """(N,) integer labels the Dirichlet/shard partitioner splits on."""
        raise NotImplementedError

    def partition_classes(self, n_classes: int) -> int:
        """Cardinality of the partition-label space (HD calibration)."""
        return n_classes

    def client_features(self, train, client_idx, n_classes: int) -> np.ndarray:
        """(K, D) row-normalized histograms used for client clustering."""
        raise NotImplementedError

    # -- model -----------------------------------------------------------
    def init_params(self, key, train, n_classes: int):
        raise NotImplementedError

    def build_fns(
        self, train, n_classes: int
    ) -> tuple[Callable, Callable, Callable]:
        """``(apply_fn, loss_fn, metric_fn)`` with the composition
        contract ``loss_fn(apply_fn(params, x), y, weights)`` and
        ``metric_fn(apply_fn(params, x), y)`` → scalar eval metric."""
        raise NotImplementedError

    def build_eval_extra(self, test, n_classes: int) -> Callable | None:
        """Optional extra held-out metrics: ``None`` (the default), or a
        callable ``(params, test_x, test_y) -> dict`` of JSON-safe
        values, surfaced as ``RoundResult.metrics`` on evaluated rounds
        (and as extra ``history`` keys by ``Engine.run``).  The LM task
        reports held-out perplexity, total and per topic cluster."""
        del test, n_classes
        return None


@register_task("classification")
class ClassificationTask(Task):
    """The paper's workload: MLP over class-conditional image features,
    clients clustered by label histograms.  This is the pre-task-axis
    engine behavior, hook for hook — the default-config regression test
    pins it bit-for-bit."""

    name = "classification"

    def partition_labels(self, train) -> np.ndarray:
        return np.asarray(train.y)

    def client_features(self, train, client_idx, n_classes: int) -> np.ndarray:
        from repro.data.partition import label_histograms

        return label_histograms(np.asarray(train.y), client_idx, n_classes)

    def init_params(self, key, train, n_classes: int):
        from repro.models.mlp import init_mlp

        feat = train.x.shape[1]
        return init_mlp(key, (feat, *self.cfg.hidden, n_classes))

    def build_fns(self, train, n_classes: int):
        from repro.models.mlp import accuracy, cross_entropy_loss, mlp_apply

        return mlp_apply, cross_entropy_loss, accuracy


@register_task("lm")
class LMTask(Task):
    """Federated language modeling: each client holds token sequences;
    the partition splits on a derived per-sequence topic label, and the
    server clusters clients by *token histograms* — the LM analogue of
    label-distribution skew (the histogram-Hellinger pipeline transfers
    unchanged).

    task_kwargs (all JSON-safe):

    - ``model``      — registered model-config name (default
                       ``"xlstm-125m"``); must be a token LM
                       (``input_mode="tokens"``, no MTP head — rejected
                       up front otherwise)
    - ``reduced``    — use the smoke-test variant (default True)
    - ``overrides``  — dict of ``ModelConfig`` field overrides applied
                       after reduction (shrink further for tests, force
                       dtype, ...).  ``dtype`` defaults to float32 so
                       cross-backend conformance holds at f32 tolerance.
    - ``hist_bins``  — token-histogram bins for clustering and the
                       partition-label space (default 64; tokens are
                       folded mod ``hist_bins``)
    """

    name = "lm"

    def __init__(self, cfg: Any, model: str = "xlstm-125m",
                 reduced: bool = True, overrides: dict | None = None,
                 hist_bins: int = 64):
        super().__init__(cfg)
        import dataclasses

        from repro.configs import get_config

        mc = get_config(model, reduced=bool(reduced))
        ov = {"dtype": "float32"}
        ov.update(overrides or {})
        mc = dataclasses.replace(mc, **ov)
        # The federated loss covers token-LM training (next-token CE +
        # MoE router aux); modality stubs and the MTP aux head are not
        # wired in — reject up front rather than silently diverging
        # from transformer.loss_fn.
        if mc.input_mode != "tokens":
            raise ValueError(
                f"task='lm' supports input_mode='tokens' only; model "
                f"{mc.name!r} has input_mode={mc.input_mode!r}"
            )
        if mc.mtp:
            raise ValueError(
                f"task='lm' does not wire the MTP aux loss into the "
                f"federated round; disable it for model {mc.name!r} via "
                f"task_kwargs={{'overrides': {{'mtp': False}}}}"
            )
        self.model_cfg = mc
        self.hist_bins = int(hist_bins)

    # -- data → partition ------------------------------------------------
    def _fold(self, tokens: np.ndarray) -> np.ndarray:
        return np.asarray(tokens) % self.hist_bins

    def partition_labels(self, train) -> np.ndarray:
        """Dominant (folded) token of each sequence — a cheap topic
        proxy; callers with real topic structure pass
        ``partition_labels=`` to ``make_engine`` instead (data
        override, see ``Engine.__init__``)."""
        x = self._fold(train.x)
        labs = [np.bincount(row, minlength=self.hist_bins).argmax() for row in x]
        return np.asarray(labs, dtype=np.int64)

    def partition_classes(self, n_classes: int) -> int:
        return self.hist_bins

    def client_features(self, train, client_idx, n_classes: int) -> np.ndarray:
        x = self._fold(train.x)
        h = np.stack([
            np.bincount(x[ix].ravel(), minlength=self.hist_bins)
            for ix in client_idx
        ]).astype(np.float64)
        return h / np.maximum(h.sum(1, keepdims=True), 1e-12)

    # -- model -----------------------------------------------------------
    def init_params(self, key, train, n_classes: int):
        from repro.models.transformer import init_transformer

        hi = int(np.asarray(train.x).max())
        if hi >= self.model_cfg.vocab:
            raise ValueError(
                f"token id {hi} out of range for model vocab "
                f"{self.model_cfg.vocab} — regenerate the stream with "
                f"vocab <= model vocab or override the model config"
            )
        return init_transformer(key, self.model_cfg)

    def build_fns(self, train, n_classes: int):
        import jax
        import jax.numpy as jnp

        from repro.models.transformer import forward, output_head

        mc = self.model_cfg

        def lm_apply(params, x):
            """Full-sequence hidden states + the output head — the
            "logits context" (logits themselves are never (B,S,V)) —
            plus the MoE router aux loss (0 for dense models)."""
            h, _, aux, _ = forward(params, mc, {"tokens": x})
            return h, output_head(params, mc), aux

        def _chunk_scan(ctx, labels, per_chunk):
            """Accumulate ``per_chunk(logits_f32, yc)`` over seq chunks of
            ``mc.loss_chunk``; seq_len must divide evenly (or be <= it)."""
            h, head, _ = ctx
            s = h.shape[1]
            c = min(mc.loss_chunk, s)
            nc = s // c
            assert nc * c == s, (
                f"seq_len {s} must be a multiple of loss_chunk {c}"
            )

            def body(carry, i):
                hc = jax.lax.dynamic_slice_in_dim(h, i * c, c, axis=1)
                yc = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
                logits = (hc @ head).astype(jnp.float32)
                return carry + per_chunk(logits, yc), None

            tot, _ = jax.lax.scan(body, jnp.zeros(()), jnp.arange(nc))
            return tot, s

        def lm_loss(ctx, labels, weights=None):
            """Mean next-token CE; ``weights`` are optional per-sequence
            weights (the mask/weights slot of the classification loss)."""
            b = labels.shape[0]
            w = (jnp.ones((b,), jnp.float32) if weights is None
                 else weights.astype(jnp.float32))

            def nll_sum(logits, yc):
                logz = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(
                    logits, yc[..., None].astype(jnp.int32), axis=-1
                )[..., 0]
                return jnp.sum((logz - gold) * w[:, None])

            tot, s = _chunk_scan(ctx, labels, nll_sum)
            loss = tot / jnp.maximum(w.sum() * s, 1e-9)
            if mc.moe:  # router load-balancing term, as transformer.loss_fn
                loss = loss + mc.moe.router_aux_weight * ctx[2]
            return loss

        def lm_metric(ctx, labels):
            """Next-token accuracy (the ``test_acc`` slot)."""

            def correct(logits, yc):
                pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return jnp.sum((pred == yc.astype(jnp.int32)).astype(jnp.float32))

            tot, s = _chunk_scan(ctx, labels, correct)
            return tot / (labels.shape[0] * s)

        return lm_apply, lm_loss, lm_metric

    def build_eval_extra(self, test, n_classes: int):
        """Held-out perplexity, total and per topic cluster (ROADMAP
        (h)): the LM analogue of Table II's per-class accuracy — it
        makes selection gains measurable per data mode.  Per-sequence
        NLL is one jitted chunk-scan (logits stay (B, c, V) per chunk,
        never (B, S, V)); topics are the task's derived per-sequence
        partition labels, so the clusters match the axis the non-IID
        split skews on."""
        import jax
        import jax.numpy as jnp

        from repro.models.transformer import forward, output_head

        mc = self.model_cfg
        topics = np.asarray(self.partition_labels(test))
        topic_ids = np.unique(topics)

        def _per_seq_nll(params, x, y):
            """(B,) mean next-token NLL per sequence — the per-sequence
            variant of ``_chunk_scan``'s chunked NLL (same chunking
            contract, vector carry instead of scalar)."""
            h, _, _, _ = forward(params, mc, {"tokens": x})
            head = output_head(params, mc)
            s = h.shape[1]
            c = min(mc.loss_chunk, s)
            nc = s // c
            assert nc * c == s, (
                f"seq_len {s} must be a multiple of loss_chunk {c}"
            )

            def body(carry, i):
                hc = jax.lax.dynamic_slice_in_dim(h, i * c, c, axis=1)
                yc = jax.lax.dynamic_slice_in_dim(y, i * c, c, axis=1)
                logits = (hc @ head).astype(jnp.float32)
                logz = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(
                    logits, yc[..., None].astype(jnp.int32), axis=-1
                )[..., 0]
                return carry + jnp.sum(logz - gold, axis=1), None

            tot, _ = jax.lax.scan(
                body, jnp.zeros((x.shape[0],), jnp.float32), jnp.arange(nc)
            )
            return tot / (nc * c)

        per_seq_nll = jax.jit(_per_seq_nll, donate_argnums=())

        def compute(params, test_x, test_y) -> dict:
            nll = np.asarray(per_seq_nll(params, test_x, test_y))
            out = {"ppl": float(np.exp(nll.mean()))}
            out["ppl_per_cluster"] = {
                str(int(t)): float(np.exp(nll[topics == t].mean()))
                for t in topic_ids
            }
            return out

        return compute


def build_task(cfg) -> Task:
    """Instantiate ``cfg.task`` with ``cfg.task_kwargs`` (the single
    construction path used by the engine and by config validation)."""
    from repro.engine.registry import TASK_REGISTRY

    return TASK_REGISTRY[cfg.task](cfg, **cfg.task_kwargs)
