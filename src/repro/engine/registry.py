"""Pluggable-component registries for the federated engine.

One ``Registry`` per orthogonal axis of a federated experiment
(Fu et al., 2022 — selection, aggregation, local-objective
modification, and the task under evaluation compose freely):

- **strategies**    — client-selection policies (``repro.core.strategies``)
- **aggregators**   — server update rules as objects with
                      ``init_state / aggregate / update_state``
                      (``repro.engine.aggregators``)
- **client modes**  — local-objective gradient modifiers
                      (``repro.engine.client_modes``)
- **tasks**         — the federated workload itself: model init, loss,
                      eval metric, and the client feature used for
                      clustering (``repro.engine.tasks``)
- **presets**       — named (strategy × mode × aggregator × task)
                      experiment cells (``repro.engine.presets``)
- **staleness**     — async-runtime staleness discounts d(s) applied to
                      buffered arrivals (``repro.engine.async_config``)

Components self-register at class-definition time via the decorators
(``@register_strategy("fedlecc")`` etc.), so adding a new method never
requires editing a dispatch table in the round loop, the benchmarks, or
the examples.  Lookups lazily import the known provider modules, so
``STRATEGY_REGISTRY["fedlecc"]`` works regardless of import order.

This module is intentionally dependency-free (stdlib only) — everything
else in ``repro.engine`` imports it, never the other way around.
"""

from __future__ import annotations

import importlib
from collections.abc import Mapping
from typing import Any, Callable, Iterator

__all__ = [
    "Registry",
    "STRATEGY_REGISTRY",
    "AGGREGATOR_REGISTRY",
    "CLIENT_MODE_REGISTRY",
    "TASK_REGISTRY",
    "PRESET_REGISTRY",
    "STALENESS_REGISTRY",
    "register_staleness",
    "list_staleness_discounts",
    "register_strategy",
    "register_aggregator",
    "register_client_mode",
    "register_task",
    "list_strategies",
    "list_aggregators",
    "list_client_modes",
    "list_tasks",
    "mask_selection_strategies",
    "traced_selection_strategies",
]

# Modules whose import populates each registry (decorator side-effects).
_PROVIDERS: dict[str, tuple[str, ...]] = {
    "strategy": ("repro.core.strategies",),
    "aggregator": ("repro.engine.aggregators",),
    "client_mode": ("repro.engine.client_modes",),
    "task": ("repro.engine.tasks",),
    "preset": ("repro.engine.presets",),
    "staleness": ("repro.engine.async_config",),
}


class Registry(Mapping[str, Any]):
    """A named string → component mapping with a ``register`` decorator.

    Behaves as a ``Mapping`` so legacy consumers written against plain
    dicts (``sorted(STRATEGIES)``, ``name in STRATEGIES``,
    ``STRATEGIES[name]``) keep working against the registry; dict-style
    insertion (``STRATEGIES["mine"] = Cls``) delegates to ``register``.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._items: dict[str, Any] = {}
        self._populated = False

    # -- registration ---------------------------------------------------
    def register(self, name: str | None = None) -> Callable[[Any], Any]:
        """Decorator: ``@REG.register("name")`` or ``@REG.register()``
        (falls back to the object's ``name`` attribute, then __name__)."""

        def deco(obj: Any) -> Any:
            key = name or getattr(obj, "name", None) or getattr(obj, "__name__", None)
            if not key or not isinstance(key, str):
                raise ValueError(f"cannot infer a registry name for {obj!r}")
            existing = self._items.get(key)
            if existing is not None and existing is not obj:
                # Re-registration of the same component (module reload,
                # re-run notebook cell) overwrites; a *different*
                # component claiming a taken name is an error.
                def _origin(o: Any) -> tuple[str, str]:
                    t = o if isinstance(o, type) else type(o)
                    return (t.__qualname__, t.__module__)

                same = _origin(existing) == _origin(obj) and (
                    isinstance(obj, type) or repr(existing) == repr(obj)
                )
                if not same:
                    raise ValueError(
                        f"duplicate {self.kind} registration {key!r} "
                        f"({existing!r} vs {obj!r})"
                    )
            self._items[key] = obj
            return obj

        return deco

    # -- lookup ---------------------------------------------------------
    def _populate(self) -> None:
        if self._populated:
            return
        for mod in _PROVIDERS.get(self.kind, ()):
            importlib.import_module(mod)
        self._populated = True

    def build(self, name: str, *args: Any, **kwargs: Any) -> Any:
        """Instantiate the registered class ``name`` with the given args."""
        return self[name](*args, **kwargs)

    def names(self) -> list[str]:
        self._populate()
        return sorted(self._items)

    # -- Mapping protocol ----------------------------------------------
    def __getitem__(self, name: str) -> Any:
        self._populate()
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; available: {sorted(self._items)}"
            ) from None

    def __iter__(self) -> Iterator[str]:
        self._populate()
        return iter(self._items)

    def __len__(self) -> int:
        self._populate()
        return len(self._items)

    def __contains__(self, name: object) -> bool:
        self._populate()
        return name in self._items

    def __setitem__(self, name: str, obj: Any) -> None:
        """Legacy dict-style registration (``STRATEGIES["mine"] = Cls``) —
        plain-dict semantics, i.e. silent overwrite (the ``register``
        decorator path keeps the strict duplicate check)."""
        self._items[name] = obj

    def __delitem__(self, name: str) -> None:
        del self._items[name]

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {sorted(self._items)})"


STRATEGY_REGISTRY = Registry("strategy")
AGGREGATOR_REGISTRY = Registry("aggregator")
CLIENT_MODE_REGISTRY = Registry("client_mode")
TASK_REGISTRY = Registry("task")
PRESET_REGISTRY = Registry("preset")
STALENESS_REGISTRY = Registry("staleness")

# The capability-flag ↔ method pairs the mask-gated backends dispatch
# on (see repro/core/strategies.py and the tracecheck AST twin of this
# check, repro/analysis/rules/capability_flags.py).
_CAPABILITY_PAIRS: tuple[tuple[str, str], ...] = (
    ("supports_compiled_selection", "select_mask_jax"),
    ("supports_traced_selection", "select_mask_traced"),
)


def _validate_strategy_capabilities(obj: Any) -> None:
    """Import-time guard: a capability flag without its method crashes
    the first compiled/fused round using the strategy; a method defined
    in a class whose flag is False is silently dead code.  An inherited
    method under an explicit ``flag = False`` is the sanctioned opt-out
    (``FedLECCAdaptive``), so only own-body definitions contradict."""
    if not isinstance(obj, type):
        return
    for flag, method in _CAPABILITY_PAIRS:
        enabled = bool(getattr(obj, flag, False))
        defined = callable(getattr(obj, method, None))
        if enabled and not defined:
            raise TypeError(
                f"strategy {obj.__name__!r} sets {flag} = True but defines "
                f"no {method}(); the mask-gated backends would crash on "
                f"their first round — define {method} or set the flag False"
            )
        if not enabled and method in vars(obj):
            raise TypeError(
                f"strategy {obj.__name__!r} defines {method}() in its own "
                f"body but {flag} is False; the backends will never call "
                f"it — set {flag} = True or drop the method"
            )


def register_strategy(name: str | None = None) -> Callable[[Any], Any]:
    """``STRATEGY_REGISTRY.register`` plus the capability-consistency
    guard — strategies with mismatched ``supports_*`` flags fail at
    class-definition (import) time, not mid-experiment."""
    inner = STRATEGY_REGISTRY.register(name)

    def deco(obj: Any) -> Any:
        _validate_strategy_capabilities(obj)
        return inner(obj)

    return deco


register_aggregator = AGGREGATOR_REGISTRY.register
register_client_mode = CLIENT_MODE_REGISTRY.register
register_task = TASK_REGISTRY.register
register_staleness = STALENESS_REGISTRY.register


def list_staleness_discounts() -> list[str]:
    return STALENESS_REGISTRY.names()


def list_strategies() -> list[str]:
    return STRATEGY_REGISTRY.names()


def list_aggregators() -> list[str]:
    return AGGREGATOR_REGISTRY.names()


def list_client_modes() -> list[str]:
    return CLIENT_MODE_REGISTRY.names()


def list_tasks() -> list[str]:
    return TASK_REGISTRY.names()


def mask_selection_strategies() -> list[str]:
    """Names of registered strategies with a jit-compatible selection
    (``supports_compiled_selection``) — the ones the mask-gated backends
    (``compiled`` / ``scaleout``) can run.  Lives here (stdlib-only) so
    ``FLConfig`` validation never drags in the training stack."""
    return [
        n for n in STRATEGY_REGISTRY.names()
        if getattr(STRATEGY_REGISTRY[n], "supports_compiled_selection", False)
    ]


def traced_selection_strategies() -> list[str]:
    """Names of strategies whose per-round selection runs fully traced
    (``select_mask_traced`` — randomness on the JAX PRNG stream), the
    requirement for ``FLConfig.fuse_rounds > 0`` (DESIGN.md §8.6)."""
    return [
        n for n in STRATEGY_REGISTRY.names()
        if getattr(STRATEGY_REGISTRY[n], "supports_traced_selection", False)
    ]
