"""Named experiment presets — the paper's method table as registry entries.

A preset pins the four orthogonal axes (selection strategy, client
mode, aggregator, task) plus their hyperparameters for one named
method, so benchmarks, examples, and ad-hoc scripts all build identical
configs:

    cfg = get_preset("fedlecc").make_config(n_clients=100, rounds=150)
    engine = make_engine(cfg, train, test, n_classes=10)

These replace the hard-coded METHODS tuple table that previously lived
in ``benchmarks/fl_common.py``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from types import MappingProxyType
from typing import Mapping

from repro.engine.config import FLConfig
from repro.engine.registry import PRESET_REGISTRY

__all__ = [
    "ExperimentPreset",
    "register_preset",
    "get_preset",
    "list_presets",
]


@dataclass(frozen=True)
class ExperimentPreset:
    """One named method cell of Table II/III."""

    name: str
    strategy: str
    client_mode: str = "plain"
    aggregator: str = "fedavg"
    mu: float = 0.0
    strategy_kwargs: Mapping = field(default_factory=dict)
    task: str = "classification"        # any registered task
    task_kwargs: Mapping = field(default_factory=dict)
    description: str = ""
    fast: bool = False   # in the quick benchmark subset?

    def make_config(self, **overrides) -> FLConfig:
        """Build an ``FLConfig`` for this method; kwargs override any
        experiment-level field (n_clients, rounds, seed, backend, ...)."""
        base = dict(
            strategy=self.strategy,
            client_mode=self.client_mode,
            aggregator=self.aggregator,
            mu=self.mu,
            strategy_kwargs=dict(self.strategy_kwargs),
            task=self.task,
            task_kwargs=dict(self.task_kwargs),
        )
        base.update(overrides)
        return FLConfig(**base)


def register_preset(preset: ExperimentPreset) -> ExperimentPreset:
    PRESET_REGISTRY.register(preset.name)(preset)
    return preset


def get_preset(name: str) -> ExperimentPreset:
    return PRESET_REGISTRY[name]


def list_presets(fast_only: bool = False) -> list[str]:
    return [
        n for n in PRESET_REGISTRY.names()
        if not fast_only or PRESET_REGISTRY[n].fast
    ]


def _p(**kw) -> ExperimentPreset:
    kw["strategy_kwargs"] = MappingProxyType(dict(kw.get("strategy_kwargs", {})))
    kw["task_kwargs"] = MappingProxyType(dict(kw.get("task_kwargs", {})))
    return register_preset(ExperimentPreset(**kw))


_p(name="fedavg", strategy="random", fast=True,
   description="FedAvg: uniform random selection, plain local SGD")
_p(name="fedprox", strategy="random", client_mode="fedprox", mu=0.01,
   description="FedProx: random selection + proximal local term")
_p(name="fednova", strategy="random", aggregator="fednova",
   description="FedNova: random selection + tau-normalized aggregation")
_p(name="feddyn", strategy="random", client_mode="feddyn",
   aggregator="feddyn", mu=0.1,
   description="FedDyn: random selection + dynamic regularization")
_p(name="haccs", strategy="haccs",
   description="HACCS: histogram clusters, latency-efficient pick")
_p(name="fedcls", strategy="fedcls",
   description="FedCLS: greedy label-coverage selection")
_p(name="fedcor", strategy="fedcor",
   description="FedCor (lightweight): GP posterior variance-reduction")
_p(name="poc", strategy="poc", fast=True,
   description="Power-of-Choice: d candidates ~ p_i, top-m by loss")
# J=10 (z=1: one client per label-mode cluster) is the tuned setting on
# the shards partition (J sweep in EXPERIMENTS §Claims; the paper's §VII
# sensitivity caveat reproduced: J=5 froze on a degenerate partition)
_p(name="fedlecc", strategy="fedlecc", strategy_kwargs={"J": 10}, fast=True,
   description="FedLECC: OPTICS clusters + Algorithm 1 (paper, J=10)")
# beyond-paper: adaptive J (the paper's stated future work)
_p(name="fedlecc_adaptive", strategy="fedlecc_adaptive",
   description="FedLECC with per-round adaptive J (beyond-paper)")
# beyond-paper: the LM task cell — FedLECC's histogram-Hellinger
# clustering over token histograms, reduced xlstm-125m clients (the
# benchmark runner swaps in token-stream data for task="lm" presets)
_p(name="fedlecc_lm", strategy="fedlecc", task="lm",
   strategy_kwargs={"J": 3},
   task_kwargs={"overrides": {"d_model": 64, "vocab": 128}},
   description="FedLECC on the federated-LM task (token-histogram clusters)")
