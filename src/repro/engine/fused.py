"""FusedEngine — whole round chunks device-resident (DESIGN.md §8.6).

The eager round loop — even fully compiled — pays per-round host costs:
poll losses to numpy, run the strategy, re-upload the mask, dispatch
three separate jits, and copy the params pytree on every aggregation.
``FLConfig.fuse_rounds > 0`` removes all of it for the compiled backend:
chunks of up to ``fuse_rounds`` rounds run as **one** jitted
``lax.scan`` whose carry is ``(params, prng_key)`` and whose per-step
body is the canonical round —

    poll_losses → select_mask_traced → cohort gather+train → fedavg

with selection *fully traced*: the strategy's ``select_mask_traced``
hook (``supports_traced_selection``) expresses the per-round decision in
jax ops, drawing any randomness from the JAX PRNG stream, so no host
synchronization happens between rounds.  The carry arguments are
**donated** (``donate_argnums``), so the params pytree is updated in
place across the chunk instead of being copied once per round.

Chunk boundaries respect the absolute ``eval_every`` cadence: a chunk
always ends at an evaluation round (and at the configured terminal
round, and at any checkpoint save point — DESIGN.md §12), so evaluation
and saves see exactly the params the eager loop would have committed —
``rounds()`` still streams one frozen ``RoundResult`` per round by
unpacking the scanned per-round outputs (masks + cohort losses), and
chunked ``rounds()`` calls stay equivalent to one contiguous call.  Each distinct chunk length compiles once and is
cached; with an aligned ``fuse_rounds``/``eval_every`` there are at most
three lengths in play (the round-0 chunk, the steady-state chunk, the
tail).

PRNG discipline is unchanged (§8.3): the carry key splits 3-ways per
scan step exactly like the eager loop, and per-client training keys are
``fold_in``-derived by client index — so for strategies whose selection
is deterministic given losses (``fedlecc``, ``lossonly``, ``haccs``)
a fused run reproduces the eager compiled run round for round.
``clusterrandom`` draws its random scores from a key folded off the
poll key (a stream the eager path never consumes), making fused runs
self-consistent but intentionally not host-lockstep.

Consumption contract: state (params, round counter, comm ledger, PRNG
carry) commits at *chunk* granularity — abandoning the ``rounds()``
iterator mid-chunk leaves the engine at the chunk boundary, not at the
last yielded round.  Donation has teeth: every chunk *consumes* the
buffers behind ``engine.params`` and the PRNG carry, so (1) a reference
to ``engine.params`` taken before a ``rounds()`` call raises ``Array
has been deleted`` on first access afterwards — snapshot with
``jax.device_get(engine.params)`` (or ``jax.tree.map(jnp.copy, ...)``)
instead of aliasing; (2) an exception that lands between a chunk
dispatch and its commit (e.g. ``KeyboardInterrupt``) can leave the
engine's params already donated — treat an interrupted fused engine as
dead and rebuild it.  The eager backends share neither hazard.
"""

from __future__ import annotations

from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import cohort_indices, selection_weights
from repro.engine.base import RoundResult, _mean_loss
from repro.engine.compiled import CompiledEngine
from repro.engine.config import fused_aggregator_error, fused_strategy_error

__all__ = ["FusedEngine"]


class FusedEngine(CompiledEngine):
    """CompiledEngine semantics with scan-fused, donated round chunks."""

    backend = "compiled"  # fused is an execution mode of the compiled backend

    def __init__(self, cfg, train, test, n_classes: int, partition_labels=None):
        super().__init__(cfg, train, test, n_classes,
                         partition_labels=partition_labels,
                         cohort_gather=True)
        # defense in depth behind the up-front FLConfig validation
        if not getattr(self.strategy, "supports_traced_selection", False):
            raise ValueError(fused_strategy_error(cfg.strategy))
        if cfg.aggregator != "fedavg":
            raise ValueError(fused_aggregator_error(cfg.aggregator))
        self._chunk_cache: dict[int, Callable] = {}
        self._build_fused_round_body()

    # ------------------------------------------------------------------
    def _build_fused_round_body(self) -> None:
        from repro.federated.aggregation import fedavg

        cfg = self.cfg
        K = cfg.n_clients
        m = min(self.m_eff, K)
        strategy = self.strategy
        needs_losses = strategy.needs_losses
        sizes = self._sizes_j
        xs, ys, dmask = self.xs, self.ys, self.mask
        poll = self._poll_losses
        cohort_train = self._cohort_train_raw
        systems = self._systems is not None
        faults = self._faults is not None
        fruntime = self._faults
        defended = faults and fruntime.defended
        compress = cfg.compress_bits
        if compress:
            from functools import partial

            from repro.federated.compression import compressed_fedavg

            compressed = partial(compressed_fedavg, bits=compress)

        def _round_body(carry, inputs):
            params, key = carry
            # identical key discipline to Engine.rounds(): one 3-way
            # split per round off the persisted carry
            key, k_poll, k_train = jax.random.split(key, 3)
            if needs_losses:
                losses = poll(params, xs, ys, dmask, k_poll)
            else:
                losses = jnp.zeros((K,), jnp.float32)
            # the availability / deadline traces (DESIGN.md §10) and the
            # fault-axis admission + injection decisions (§14) are all
            # exogenous host-precomputed scan inputs; the -inf gate below
            # is the same one the eager loop applies (_gated_losses)
            gate = None
            if systems:
                gate = inputs["avail"]
            if faults:
                gate = (
                    inputs["admit"] if gate is None
                    else gate & inputs["admit"]
                )
            if gate is not None:
                losses = jnp.where(gate, losses, -jnp.inf)
            # selection randomness rides a stream the eager path never
            # consumes (fold tag K ≥ any client index), so deterministic
            # strategies stay bit-compatible with the eager loop
            mask = strategy.select_mask_traced(
                losses, jax.random.fold_in(k_poll, K)
            )
            # survivors: offline-at-dispatch and past-deadline clients
            # keep their static cohort slot but aggregate at weight zero
            final = mask
            if systems:
                final = final & inputs["avail"] & inputs["arrived"]
            if faults:
                final = final & inputs["admit"]
            arrivals = final  # pre-flag: the updates reaching the server
            idx = cohort_indices(mask, m)
            stacked, sel_losses = cohort_train(params, idx, k_train)
            if faults:
                # faults are upload properties: only rows whose upload
                # reaches the server are injected (a zero-weight NaN row
                # would still poison the mask-gated sum)
                arrived_rows = jnp.take(arrivals, idx)
                kind_rows = jnp.where(
                    arrived_rows, jnp.take(inputs["fkind"], idx), -1
                )
                u_rows = jnp.take(inputs["fu"], idx)
                stacked = fruntime.apply_traced(
                    stacked, params, kind_rows, u_rows
                )
                if defended:
                    stacked, flagged_rows, _ = fruntime.validate_traced(
                        stacked, params, arrived_rows
                    )
                    # quarantine takes effect at weight exactly zero
                    flag_full = (
                        jnp.zeros((K,), bool).at[idx].max(flagged_rows)
                    )
                    final = final & ~flag_full
            w = jnp.take(selection_weights(final, sizes), idx)
            if compress:
                new_params, _ = compressed(
                    stacked, params, w, self._quant_key(k_train, K)
                )
            else:
                new_params = fedavg(stacked, w)
            if systems or faults:
                # nobody uploaded (or everyone was flagged) → the global
                # model stands still (the all-zero weight vector would
                # otherwise zero the params)
                any_up = final.any()
                new_params = jax.tree.map(
                    lambda n, o: jnp.where(any_up, n, o), new_params, params
                )
            outs = (mask, final, sel_losses)
            if faults:
                outs = outs + (arrivals,)
            return (new_params, key), outs

        self._round_body = _round_body

    def _chunk_step(self, length: int) -> Callable:
        """The jitted chunk runner for one static chunk length — compiled
        once per distinct length, carry buffers donated.  With a systems
        config the chunk additionally takes the (length, K) availability
        and deadline-arrival traces as (undonated) scan inputs — their
        shapes depend only on the chunk length, so the cache key is
        unchanged and nothing retraces."""
        fn = self._chunk_cache.get(length)
        if fn is None:
            body = self._round_body
            if self._systems is not None or self._faults is not None:
                def run(params, key, inputs):
                    (params, key), out = jax.lax.scan(
                        body, (params, key), inputs, length=length
                    )
                    return params, key, *out
            else:
                def run(params, key):
                    (params, key), out = jax.lax.scan(
                        body, (params, key), None, length=length
                    )
                    return params, key, *out

            fn = jax.jit(run, donate_argnums=(0, 1))
            self._chunk_cache[length] = fn
        return fn

    def _chunk_len(self, rnd: int, end: int) -> int:
        """Rounds to fuse starting at absolute round ``rnd``: capped by
        ``fuse_rounds`` and clipped so the chunk ends exactly at the next
        ``eval_every``-cadence round, the configured terminal round, the
        call's final round, or the next checkpoint save point — so
        evaluation always sees chunk-boundary params, and a save policy
        with a round trigger always fires on committed chunk-boundary
        state.  Apart from the ``end`` clamp, the boundary is a pure
        function of the absolute round index, so a run resumed from a
        save point replays the identical chunk pattern (DESIGN.md §12)."""
        cfg = self.cfg
        ev = cfg.eval_every
        next_eval = rnd if rnd % ev == 0 else (rnd // ev + 1) * ev
        boundary = min(next_eval, end - 1)
        if rnd <= cfg.rounds - 1:
            boundary = min(boundary, cfg.rounds - 1)
        if (self.checkpointer is not None
                and self.checkpointer.policy.every_rounds is not None):
            n = self.checkpointer.policy.every_rounds
            next_save = (rnd // n + 1) * n - 1  # min r >= rnd, (r+1) % n == 0
            boundary = min(boundary, next_save)
        return max(1, min(cfg.fuse_rounds, boundary - rnd + 1))

    # -- the fused round loop ------------------------------------------
    def rounds(
        self,
        n_rounds: int | None = None,
        callback=None,
    ) -> Iterator[RoundResult]:
        """Stream one ``RoundResult`` per round, computed chunk-at-a-time
        on device.  Same record semantics as ``Engine.rounds()``; state
        commits per chunk (see module docstring)."""
        cfg = self.cfg
        if n_rounds is None:
            n_rounds = max(cfg.rounds - self._round, 0)
        key = self._carry_key()
        start = self._round
        end = start + n_rounds
        rnd = start
        while rnd < end:
            length = self._chunk_len(rnd, end)
            step = self._chunk_step(length)
            fkind = fu = None
            inputs: dict[str, np.ndarray] = {}
            if self._systems is not None:
                # exogenous availability / deadline-arrival traces for
                # the chunk (host-deterministic per round, so the fused
                # run sees exactly what the eager backends see)
                inputs["avail"] = np.stack(
                    [self._systems.available(rnd + i) for i in range(length)]
                )
                inputs["arrived"] = np.stack(
                    [self._systems.arrived(rnd + i) for i in range(length)]
                )
            if self._faults is not None:
                # per-round fault decisions are host-deterministic too;
                # the admission gate is evaluated against the health
                # ledger at *chunk start* — a fault flagged mid-chunk
                # starts its quarantine at the next chunk boundary
                # (eager runs quarantine one round earlier; DESIGN.md
                # §14 documents the chunk-granular lag)
                inputs["admit"] = np.stack(
                    [self._faults.health.admitted(rnd + i) for i in range(length)]
                )
                decisions = [self._faults.decide(rnd + i) for i in range(length)]
                fkind = np.stack([k for k, _ in decisions])
                fu = np.stack([u for _, u in decisions])
                inputs["fkind"] = fkind
                inputs["fu"] = fu
            if inputs:
                outs = step(
                    self.params, key,
                    {k: jnp.asarray(v) for k, v in inputs.items()},
                )
            else:
                outs = step(self.params, key)
            if self._faults is not None:
                params, key, masks, finals, sel_losses, arrivals = outs
                arrivals = np.asarray(arrivals)
            else:
                params, key, masks, finals, sel_losses = outs
                arrivals = None
            # commit the chunk before yielding anything from it
            self.params, self._key = params, key
            self._round = rnd + length
            masks = np.asarray(masks)
            finals = np.asarray(finals)
            sel_losses = np.asarray(sel_losses)
            results = []
            for i in range(length):
                r = rnd + i
                sel = np.where(masks[i])[0]
                surv = np.where(finals[i])[0]
                n_faulty = n_quarantined = 0
                uploaded: float | None = None
                if self._faults is not None:
                    # per-round ledger replay off the scanned outputs:
                    # arrivals feed the health record, the host-side
                    # decisions give ground-truth fault counts + the
                    # partial-upload byte fractions
                    arr = np.where(arrivals[i])[0]
                    flagged = np.where(arrivals[i] & ~finals[i])[0]
                    self._faults.health.record(r, arr, flagged)
                    kind_r = np.where(arrivals[i], fkind[i], -1)
                    n_faulty = int((kind_r >= 0).sum())
                    n_quarantined = self._faults.health.n_quarantined(r)
                    uploaded = float(
                        self._faults.upload_fractions(
                            kind_r[arr], fu[i][arr]
                        ).sum()
                    )
                if self._systems is not None:
                    # same accounting core as the eager loop's outcome()
                    out = self._systems.outcome_from_mask(r, masks[i])
                    self.comm_mb += self.comm.round_mb(
                        out.n_reached, self.strategy.needs_losses,
                        m_uploaded=(
                            len(surv) if uploaded is None else uploaded
                        ),
                    )
                    self.sim_clock += out.sim_time
                    sim_time, n_dropped = out.sim_time, out.n_dropped
                    keep = finals[i][sel]  # survivor slots in cohort order
                    mean_loss = _mean_loss(sel_losses[i][keep])
                elif self._faults is not None:
                    self.comm_mb += self.comm.round_mb(
                        len(sel), self.strategy.needs_losses,
                        m_uploaded=uploaded,
                    )
                    sim_time, n_dropped = 0.0, 0
                    keep = finals[i][sel]
                    mean_loss = _mean_loss(sel_losses[i][keep])
                else:
                    self.comm_mb += self.comm.round_mb(
                        len(sel), self.strategy.needs_losses
                    )
                    sim_time, n_dropped = 0.0, 0
                    mean_loss = _mean_loss(sel_losses[i])
                test_loss = test_acc = metrics = None
                # same absolute cadence as Engine.rounds(): eval-due
                # rounds are always chunk-final (see _chunk_len), so the
                # committed params are exactly the eager loop's
                if i == length - 1 and (
                    r % cfg.eval_every == 0 or r == cfg.rounds - 1
                ):
                    test_loss, test_acc = self.evaluate()
                    metrics = self.eval_metrics()
                results.append(RoundResult(
                    round=r,
                    selected=tuple(int(j) for j in surv),
                    mean_selected_loss=mean_loss,
                    comm_mb=float(self.comm_mb),
                    test_loss=test_loss,
                    test_acc=test_acc,
                    sim_time=float(sim_time),
                    sim_clock=float(self.sim_clock),
                    n_dropped=int(n_dropped),
                    metrics=metrics,
                    params_version=r + 1,
                    n_faulty=int(n_faulty),
                    n_quarantined=int(n_quarantined),
                ))
            rnd += length
            for i, result in enumerate(results):
                # checkpoints only at the chunk-final round: the engine
                # state committed above is the *chunk-end* state, so a
                # mid-chunk save would pair end-of-chunk params with a
                # truncated history.  _chunk_len aligns round-trigger
                # save points to chunk boundaries, so no save is lost.
                self._emit(result, callback,
                           allow_save=(i == len(results) - 1))
                yield result
