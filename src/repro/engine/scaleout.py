"""ScaleoutEngine — the pod-scale mesh round behind the engine protocol.

This closes the loop ROADMAP follow-up (c) describes: the production
``make_scaleout_round`` path (clients ↔ pods, shard_map + mask-gated
psum, ``repro.federated.scaleout``) no longer bypasses the canonical
``poll_losses → select → local_train → aggregate → evaluate`` round —
``ScaleoutEngine`` drives exactly that protocol and streams the same
frozen ``RoundResult``s as the host and compiled backends.

Mapping (DESIGN.md §3b):

- the ``pod`` mesh axis is *manual* (``jax_compat.shard_map``); the K
  clients are blocked over the pods (K/P clients per pod, vmapped
  locally), so one pod process == one block of independently evolving
  client replicas;
- the round enters with per-client parameter stacks
  (``stack_for_clients``) sharded ``P("pod")`` — the same contract as
  the production transformer round;
- selection runs through the shared ``MaskSelectionMixin`` path: the
  strategy's jit-compatible ``select_mask_jax`` produces the
  participation mask, ``selection_weights`` turns it into the weight
  vector, and **aggregation is the weighted psum over the pod axis** —
  "only m of K clients upload" ≡ "the all-reduce carries zero weight
  for unselected clients".

Because every client trains every round with ``fold_in``-derived keys
and zero-weight clients contribute exact zeros to the psum, a
``ScaleoutEngine`` round is numerically equivalent to the ``host`` and
``compiled`` rounds for the same config — the cross-backend conformance
suite asserts this for every mask-capable strategy.

``make_scaleout_round`` — the engine-API entry for the production
*transformer* mesh round used by ``repro.launch.dryrun --federated`` —
lives here too (moved from ``repro.engine.compiled``, which keeps a
delegating re-export).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.selection import selection_weights
from repro.engine.base import Engine, MaskSelectionMixin

__all__ = ["ScaleoutEngine", "make_scaleout_round"]


class ScaleoutEngine(MaskSelectionMixin, Engine):
    backend = "scaleout"
    requires_fedavg_aggregator = True  # aggregation IS the psum

    def __init__(self, cfg, train, test, n_classes: int, mesh=None,
                 partition_labels=None):
        super().__init__(cfg, train, test, n_classes,
                         partition_labels=partition_labels)
        self._check_mask_backend()
        self.mesh = mesh if mesh is not None else self._default_mesh(cfg.n_clients)
        if "pod" not in self.mesh.shape:
            raise ValueError(
                f"scaleout mesh must carry a 'pod' (client) axis; got axes "
                f"{tuple(self.mesh.shape)} — build it with "
                f"make_host_mesh(pod=...) or make_production_mesh(multi_pod=True)"
            )
        self.n_pods = int(self.mesh.shape["pod"])
        if cfg.n_clients % self.n_pods:
            raise ValueError(
                f"n_clients={cfg.n_clients} must be divisible by the pod axis "
                f"({self.n_pods}) so clients block evenly over pods"
            )
        self._sizes_j = jnp.asarray(self.sizes, jnp.float32)
        # aggregate() installs host (device_get) params every round; start
        # from host params too, or the round-0 poll/evaluate compile for a
        # committed single-device Array and round 1 retraces for numpy
        self.params = jax.device_get(self.params)
        self._build_scaleout_round()

    @staticmethod
    def _default_mesh(n_clients: int):
        """Largest pod axis that divides n_clients and fits the local
        devices (1 on a single-device host — the conformance regime)."""
        from repro.launch.mesh import make_host_mesh

        n_dev = jax.device_count()
        pods = max(p for p in range(1, n_dev + 1) if n_clients % p == 0)
        return make_host_mesh(pod=pods)

    # ------------------------------------------------------------------
    def _build_scaleout_round(self) -> None:
        from repro.federated.client import local_train
        from repro.federated.scaleout import stack_for_clients
        from repro.jax_compat import shard_map

        self._stack_for_clients = stack_for_clients

        cfg = self.cfg
        apply_fn, loss_fn = self._apply_fn, self._loss_fn

        def _one_client(start, x, y, mask, tau, key):
            return local_train(
                apply_fn, loss_fn, start, x, y, mask, tau, key,
                lr=cfg.lr, max_steps=self.max_steps, batch_size=cfg.batch_size,
                mode="plain", mu=cfg.mu, h_state=None,
            )

        # per-pod block of K/P clients, each starting from its stack row
        vmapped = jax.vmap(_one_client, in_axes=(0, 0, 0, 0, 0, 0))

        def body(stacked, xs, ys, mask, taus, keys, w):
            ends, losses = vmapped(stacked, xs, ys, mask, taus, keys)
            # mask-gated weighted partial sum over the local client block,
            # then the all-reduce over pods: θ ← psum_pod Σ_block w_i θ_i.
            # Unselected clients (w=0) contribute exact zeros but still
            # receive the aggregated model (psum is replicated over pod).
            agg = jax.tree.map(
                lambda s: jax.lax.psum(
                    jnp.tensordot(w, s.astype(jnp.float32), axes=1), "pod"
                ).astype(s.dtype),
                ends,
            )
            return agg, losses

        pod = P("pod")
        pspec = jax.tree.map(lambda _: pod, self.params)
        rspec = jax.tree.map(lambda _: P(), self.params)
        self._round_fn = jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=(pspec, pod, pod, pod, pod, pod, pod),
                out_specs=(rspec, pod),
                axis_names={"pod"},
                check_vma=False,
            ),
            donate_argnums=(),
        )

    # -- hooks (select comes from MaskSelectionMixin) --------------------
    def local_train(self, rnd: int, sel: np.ndarray, key: jax.Array,
                    survivors: np.ndarray | None = None):
        """One fused mesh round: every client trains from its stack row;
        the selection-weighted psum aggregates in the same compiled call.
        Returns the aggregated params as the payload.  Under a systems
        deadline the psum weights carry only the *survivors* — dropped
        cohort members contribute exact zeros, like unselected clients."""
        K = self.cfg.n_clients
        keys = self._client_keys(key, jnp.arange(K))
        weight_idx = sel if survivors is None else survivors
        mask = jnp.zeros((K,), jnp.bool_).at[jnp.asarray(weight_idx)].set(True)
        w = selection_weights(mask, self._sizes_j)
        new_params, losses = self._round_fn(
            self._stack_for_clients(self.params, K),
            self.xs, self.ys, self.mask, jnp.asarray(self.taus), keys, w,
        )
        return new_params, np.asarray(losses)[sel]

    def aggregate(self, rnd: int, sel: np.ndarray, payload,
                  survivors: np.ndarray | None = None) -> None:
        # Aggregation already happened inside the mesh round (the psum);
        # install the replicated result.  Pull to host so downstream jits
        # (poll/evaluate) never mix mesh-committed and uncommitted args.
        if survivors is not None and len(survivors) == 0:
            return  # all-zero psum (nobody uploaded): keep the old model
        self.params = jax.device_get(payload)


def make_scaleout_round(model_cfg, mesh, lr: float, local_steps: int = 4,
                        compress_bits: int = 0):
    """Engine-API entry for the production transformer mesh round
    (clients ↔ pods).

    Thin wrapper over ``repro.federated.scaleout.make_federated_round`` —
    the mesh round is the mask-gated-backend semantics at pod scale:
    every pod trains, and the strategy-produced ``selection_weights``
    vector gates the all-reduce.  Imported lazily so ``repro.engine``
    stays light.
    """
    from repro.federated.scaleout import make_federated_round

    return make_federated_round(
        model_cfg, mesh, lr=lr, local_steps=local_steps,
        compress_bits=compress_bits,
    )
