"""repro.engine — the pluggable federated engine API.

One API, four orthogonal axes, three backends:

- ``registry``     — ``@register_strategy`` / ``@register_aggregator`` /
                     ``@register_client_mode`` / ``@register_task``
                     decorators + lookups
- ``config``       — ``FLConfig`` with validation, ``backend`` and
                     ``task`` switches, and ``to_dict``/``from_dict``
                     round-tripping
- ``base``         — ``Engine`` round protocol (poll_losses → select →
                     local_train → aggregate → evaluate), streaming
                     ``rounds()`` iterator of frozen ``RoundResult``s,
                     plus ``MaskSelectionMixin`` (the shared
                     ``select_mask_jax`` selection path)
- ``host``         — ``HostEngine``: numpy selection + vmapped cohort
- ``compiled``     — ``CompiledEngine``: jitted selection/round with the
                     participation mask gating aggregation (scale-out
                     semantics on one device); trains only the gathered
                     m-client cohort (static shapes via ``jnp.take``)
- ``fused``        — ``FusedEngine``: the compiled semantics with whole
                     round chunks as one donated ``lax.scan``
                     (``FLConfig.fuse_rounds > 0``; selection fully
                     traced via ``select_mask_traced``)
- ``scaleout``     — ``ScaleoutEngine``: the mesh round (clients blocked
                     over the ``pod`` axis, shard_map + selection-
                     weighted psum), plus ``make_scaleout_round`` for
                     the production transformer mesh
- ``aggregators``  — FedAvg / FedNova / FedDyn as stateful objects
- ``client_modes`` — plain / FedProx / FedDyn gradient modifiers
- ``tasks``        — the federated workload: ``classification`` (paper
                     MLP, label histograms — the default) and ``lm``
                     (transformer LM, token histograms); a ``Task``
                     owns model init, loss, eval metric, and the
                     clustering feature
- ``presets``      — named method cells (Table II/III) via
                     ``get_preset(name).make_config(...)``

Strategy × backend support matrix, identical for both tasks (mask-gated
backends need a jit-compatible ``select_mask_jax``; ``fuse_rounds > 0``
additionally needs a fully-traced ``select_mask_traced``; FLConfig
validation enforces both up front):

    strategy          host   compiled   scaleout   fuse_rounds
    ----------------  ----   --------   --------   -----------
    fedlecc            ✓        ✓          ✓            ✓
    fedlecc_adaptive   ✓        ✓          ✓            —
    poc                ✓        ✓          ✓            ✓ (jax rng)
    lossonly           ✓        ✓          ✓            ✓
    clusterrandom      ✓        ✓          ✓            ✓ (jax rng)
    haccs              ✓        ✓          ✓            ✓
    random             ✓        ✓          ✓            ✓ (jax rng)
    fedcls             ✓        —          —            —
    fedcor             ✓        —          —            —

(``compiled``/``scaleout`` additionally require ``client_mode="plain"``;
``scaleout`` aggregates inside the mesh round and ``fuse_rounds``/
``compress_bits`` aggregate inside the compiled round, so those three
require ``aggregator="fedavg"``.)

The async runtime (``FLConfig.async_mode``, DESIGN.md §13) layers a
FedBuff-style event loop over the host/compiled hooks: the server
aggregates the first-``buffer_k`` arrivals per step with
staleness-discounted weights while further cohorts stay in flight
(``AsyncConfig(dispatch="sync")`` is the degenerate lock-step form,
bit-identical to the plain engines).

The fault axis (``FLConfig.faults``, ``repro.faults``, DESIGN.md §14)
injects per-client faults (NaN updates, exploding/sign-flipped/label-
flipped deltas, stale replays, truncated uploads) on a dedicated child
rng stream and defends with a server-side validation gate, robust
aggregators (``trimmed_mean`` / ``coordinate_median``), and the
``ClientHealth`` quarantine ledger — on the host/compiled paths
(eager, fused, and async); ``faults=None`` is bit-identical to an
engine without the subsystem.

The population axis (``FLConfig.population``, ``repro.population``,
DESIGN.md §15) scales the host/compiled engines to cross-device client
counts: the packed client stacks stay host-side behind a
``ClientStore``, a shard-level Algorithm 1 (``HierarchicalSelector``)
picks the round's resident shards, and only resident rows are ever
polled, gathered to device, or charged to the comm ledger — per-round
cost becomes cohort-proportional.  ``PopulationConfig(n_shards=1)`` (and
``population=None``) are bit-identical to the flat engines.

The systems axis (``FLConfig.systems``, ``repro.systems``, DESIGN.md
§10) is orthogonal to all of the above: a ``SystemsConfig`` adds device
profiles, an availability trace, simulated wall-clock per round
(``RoundResult.sim_time``/``sim_clock``), and deadline/over-selection
semantics (stragglers dropped, survivors reweighted) on every backend::

    from repro.engine import FLConfig, SystemsConfig, make_engine

    cfg = FLConfig(strategy="fedlecc", backend="compiled",
                   systems=SystemsConfig(profile="mobile_mix",
                                         availability="markov",
                                         deadline_s=30.0, over_select=1.3))

Typical use::

    from repro.engine import FLConfig, make_engine

    cfg = FLConfig(strategy="fedlecc", backend="scaleout", rounds=30)
    engine = make_engine(cfg, train, test, n_classes=10)
    for result in engine.rounds():
        ...  # result: RoundResult(round, selected, losses, metrics, MB)

    # federated LM: same strategies, same backends, token streams
    cfg = FLConfig(task="lm", strategy="fedlecc", backend="scaleout")

The engines are imported lazily (module ``__getattr__``) so that
registering a component never drags in the training stack.
"""

from repro.engine.config import BACKENDS, FLConfig
from repro.engine.registry import (
    AGGREGATOR_REGISTRY,
    CLIENT_MODE_REGISTRY,
    PRESET_REGISTRY,
    STRATEGY_REGISTRY,
    TASK_REGISTRY,
    Registry,
    list_aggregators,
    list_client_modes,
    list_strategies,
    list_tasks,
    mask_selection_strategies,
    register_aggregator,
    register_client_mode,
    register_strategy,
    register_task,
)

__all__ = [
    "BACKENDS",
    "FLConfig",
    "Registry",
    "STRATEGY_REGISTRY",
    "AGGREGATOR_REGISTRY",
    "CLIENT_MODE_REGISTRY",
    "TASK_REGISTRY",
    "PRESET_REGISTRY",
    "register_strategy",
    "register_aggregator",
    "register_client_mode",
    "register_task",
    "list_strategies",
    "list_aggregators",
    "list_client_modes",
    "list_tasks",
    "Task",
    "build_task",
    "Engine",
    "MaskSelectionMixin",
    "RoundResult",
    "mask_selection_strategies",
    "rounds_to_accuracy",
    "HostEngine",
    "CompiledEngine",
    "FusedEngine",
    "ScaleoutEngine",
    "make_scaleout_round",
    "ExperimentPreset",
    "get_preset",
    "list_presets",
    "register_preset",
    "make_engine",
    "SystemsConfig",
    "PopulationConfig",
    "FaultConfig",
    "AsyncConfig",
    "AsyncHostEngine",
    "AsyncCompiledEngine",
    "CheckpointPolicy",
    "Checkpointer",
    "JsonlTracker",
    "MetricsTracker",
]

_LAZY = {
    "Task": ("repro.engine.tasks", "Task"),
    "build_task": ("repro.engine.tasks", "build_task"),
    "Engine": ("repro.engine.base", "Engine"),
    "MaskSelectionMixin": ("repro.engine.base", "MaskSelectionMixin"),
    "RoundResult": ("repro.engine.base", "RoundResult"),
    "rounds_to_accuracy": ("repro.engine.base", "rounds_to_accuracy"),
    "HostEngine": ("repro.engine.host", "HostEngine"),
    "CompiledEngine": ("repro.engine.compiled", "CompiledEngine"),
    "FusedEngine": ("repro.engine.fused", "FusedEngine"),
    "ScaleoutEngine": ("repro.engine.scaleout", "ScaleoutEngine"),
    "make_scaleout_round": ("repro.engine.scaleout", "make_scaleout_round"),
    "SystemsConfig": ("repro.systems.config", "SystemsConfig"),
    "PopulationConfig": ("repro.population.config", "PopulationConfig"),
    "FaultConfig": ("repro.faults.config", "FaultConfig"),
    "AsyncConfig": ("repro.engine.async_config", "AsyncConfig"),
    "AsyncHostEngine": ("repro.engine.async_engine", "AsyncHostEngine"),
    "AsyncCompiledEngine": ("repro.engine.async_engine", "AsyncCompiledEngine"),
    "ExperimentPreset": ("repro.engine.presets", "ExperimentPreset"),
    "get_preset": ("repro.engine.presets", "get_preset"),
    "list_presets": ("repro.engine.presets", "list_presets"),
    "register_preset": ("repro.engine.presets", "register_preset"),
    "CheckpointPolicy": ("repro.checkpoint.policy", "CheckpointPolicy"),
    "Checkpointer": ("repro.checkpoint.policy", "Checkpointer"),
    "JsonlTracker": ("repro.checkpoint.tracker", "JsonlTracker"),
    "MetricsTracker": ("repro.checkpoint.tracker", "MetricsTracker"),
}


def __getattr__(name):
    try:
        mod, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro.engine' has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(mod), attr)
    globals()[name] = value
    return value


def make_engine(cfg: FLConfig, train, test, n_classes: int, *,
                resume=None, checkpointer=None, tracker=None, **kwargs):
    """Build the engine selected by ``cfg.backend``
    ("host" | "compiled" | "scaleout").

    ``train``/``test`` are the task's datasets (``repro.data.Dataset``:
    image features + class labels for ``task="classification"``, token /
    next-token sequences for ``task="lm"``); ``n_classes`` is the label
    cardinality (the vocab size for LM).

    Checkpointing / observability (DESIGN.md §12):

    - ``resume=``       — path to a checkpoint written by
      ``Engine.save`` (or a directory of them: the latest is picked);
      the built engine restores it before returning, so the next
      ``rounds()`` call continues the run.  The stored config
      fingerprint must match ``cfg``.
    - ``checkpointer=`` — a ``repro.checkpoint.Checkpointer`` (or a
      directory path, which builds one with the default every-round
      policy); attached as ``engine.checkpointer`` so its policy is
      consulted after every committed round.
    - ``tracker=``      — a ``repro.checkpoint.MetricsTracker`` (or list
      of them) appended to ``engine.trackers``; every streamed
      ``RoundResult`` is logged durably.

    Extra kwargs pass through to the backend constructor:

    - ``mesh=``             — (scaleout only) a mesh with a ``pod`` axis
      replacing the auto-sized default
      (``make_host_mesh(pod=...)`` / ``make_production_mesh``).
    - ``partition_labels=`` — (all backends) task-data override: a (N,)
      integer array the non-IID partitioner splits on instead of the
      task's derived labels (e.g. ground-truth topic ids for LM
      corpora — see ``examples/federated_lm.py``).
    - ``cohort_gather=``    — (compiled only) ``False`` restores the
      legacy every-client-trains path (the scale-out-semantics
      reference); the default gathers and trains just the m-client
      cohort.  Ignored when ``cfg.fuse_rounds > 0`` (fused chunks
      always gather).

    ``cfg.fuse_rounds > 0`` selects the scan-fused execution mode of the
    compiled backend (``FusedEngine``, DESIGN.md §8.6).

    ``cfg.async_mode`` selects the asynchronous runtime (DESIGN.md §13):
    the host/compiled hooks driven by an event loop that buffers the
    first-``k`` arrivals per aggregation step with staleness-discounted
    weights (``AsyncHostEngine`` / ``AsyncCompiledEngine``).
    """
    engine = _build_engine(cfg, train, test, n_classes, **kwargs)
    if checkpointer is not None:
        if isinstance(checkpointer, str):
            from repro.checkpoint import Checkpointer

            checkpointer = Checkpointer(checkpointer)
        engine.checkpointer = checkpointer
    if tracker is not None:
        engine.trackers.extend(
            tracker if isinstance(tracker, (list, tuple)) else [tracker]
        )
    if resume is not None:
        import os

        path = resume
        if os.path.isdir(path):
            # Walk the directory newest-first: a truncated / corrupt
            # latest file (detected loudly as CheckpointError by the
            # serializer) falls back to the previous valid checkpoint
            # with a warning instead of aborting the resume.  Config /
            # structure mismatches stay fatal — falling back would
            # silently change the experiment.
            import warnings

            from repro.checkpoint import CheckpointError, checkpoint_paths

            candidates = checkpoint_paths(path)
            if not candidates:
                raise FileNotFoundError(
                    f"resume directory {path!r} holds no round_*.ckpt files"
                )
            for i, cand in enumerate(candidates):
                try:
                    engine.restore(cand)
                    break
                except CheckpointError as e:
                    if i == len(candidates) - 1:
                        raise CheckpointError(
                            f"no valid checkpoint in {path!r} — every "
                            f"round_*.ckpt file is corrupt (last error: {e})"
                        ) from e
                    warnings.warn(
                        f"skipping corrupt checkpoint {cand!r} "
                        f"({e}); falling back to "
                        f"{candidates[i + 1]!r}",
                        stacklevel=2,
                    )
        else:
            engine.restore(path)
    return engine


def _build_engine(cfg: FLConfig, train, test, n_classes: int, **kwargs):
    if cfg.async_mode is not None:
        # the async runtime wraps the host/compiled hooks with an
        # event-driven loop (DESIGN.md §13); FLConfig validation already
        # rejected incompatible backends/modes
        if cfg.backend == "compiled":
            from repro.engine.async_engine import AsyncCompiledEngine

            return AsyncCompiledEngine(cfg, train, test, n_classes, **kwargs)
        from repro.engine.async_engine import AsyncHostEngine

        return AsyncHostEngine(cfg, train, test, n_classes, **kwargs)
    if cfg.backend == "compiled":
        if cfg.fuse_rounds > 0:
            from repro.engine.fused import FusedEngine

            kwargs.pop("cohort_gather", None)  # fused always gathers
            return FusedEngine(cfg, train, test, n_classes, **kwargs)
        from repro.engine.compiled import CompiledEngine

        return CompiledEngine(cfg, train, test, n_classes, **kwargs)
    if cfg.backend == "scaleout":
        from repro.engine.scaleout import ScaleoutEngine

        return ScaleoutEngine(cfg, train, test, n_classes, **kwargs)
    from repro.engine.host import HostEngine

    return HostEngine(cfg, train, test, n_classes, **kwargs)
