"""Backend-agnostic federated engine: the typed round protocol.

``Engine`` owns everything every backend shares — the non-IID partition,
the packed client tensors, the selection strategy, the comm ledger —
and drives one canonical round loop:

    poll_losses → select → local_train → aggregate → evaluate

Everything *workload*-specific (model init, per-example loss, eval
metric, the client feature used for clustering) is owned by the
registered ``Task`` selected via ``FLConfig.task``
(``repro.engine.tasks``): ``classification`` is the paper's MLP over
label-skewed images, ``lm`` is a transformer language model over
token streams with topic skew.  The engine itself never names a model.

Backends implement the hooks:

- ``HostEngine``     (``repro.engine.host``)     — numpy selection +
  vmapped cohort training (the paper-faithful simulation).
- ``CompiledEngine`` (``repro.engine.compiled``) — selection, training,
  and mask-gated aggregation as jitted computations (the scale-out
  semantics where every client computes and the participation mask
  gates aggregation).
- ``ScaleoutEngine`` (``repro.engine.scaleout``) — the same mask-gated
  semantics at mesh scale: clients sharded over the ``pod`` axis via
  shard_map, aggregation as the selection-weighted psum.
- ``FusedEngine``    (``repro.engine.fused``)    — the compiled
  semantics with whole round *chunks* device-resident: one scanned jit
  per chunk, selection fully traced (``FLConfig.fuse_rounds``,
  DESIGN.md §8.6).

``CompiledEngine`` and ``ScaleoutEngine`` share one selection path,
``MaskSelectionMixin`` — strategy-produced jit-compatible masks
(``select_mask_jax``) instead of host-side index lists.

``rounds()`` is a streaming iterator yielding one frozen ``RoundResult``
per round (plus an optional callback), so consumers — examples,
benchmarks, schedulers — observe training without owning the loop.
``run()`` is the legacy consumer, producing the same history dict that
``FederatedSimulation.run()`` always returned.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm_model import CommModel, count_params
from repro.engine.aggregators import get_aggregator
from repro.engine.client_modes import get_client_mode
from repro.engine.config import (
    FLConfig,
    mask_backend_aggregator_error,
    mask_backend_client_mode_error,
    mask_backend_strategy_error,
)
from repro.engine.registry import STRATEGY_REGISTRY, mask_selection_strategies

__all__ = [
    "Engine",
    "MaskSelectionMixin",
    "RoundResult",
    "mask_selection_strategies",
    "rounds_to_accuracy",
]


def _mean_loss(sel_losses) -> float:
    """Mean local-training loss over the cohort; ``nan`` (without numpy's
    ``RuntimeWarning``) when a strategy selected nobody this round."""
    ls = np.asarray(sel_losses)
    return float(ls.mean()) if ls.size else float("nan")


@dataclass(frozen=True)
class RoundResult:
    """One completed federated round (frozen; the streaming record type
    of ``engine.rounds()`` on every backend and every task).

    Fields:

    - ``round``              — 0-based absolute round index (stable
      across chunked ``rounds()`` calls).
    - ``selected``           — sorted tuple of the participating client
      indices this round.
    - ``mean_selected_loss`` — mean *local training* loss over the
      selected cohort (averaged over each client's executed steps).
    - ``comm_mb``            — cumulative communication ledger in MB up
      to and including this round (model up/down for the cohort, loss
      polls, one-time histograms — ``repro.core.comm_model``).
    - ``test_loss``/``test_acc`` — global-model evaluation on the held-
      out set; the metric is task-defined (classification accuracy, or
      next-token accuracy for the LM task).  ``None`` on rounds where
      evaluation was skipped (``eval_every`` cadence).
    - ``sim_time``/``sim_clock`` — simulated wall-clock seconds of this
      round / cumulative since round 0, from the systems layer
      (``FLConfig.systems``, DESIGN.md §10).  0.0 when no systems
      config is active (the frictionless engine has no clock).
    - ``n_dropped``          — dispatched-but-not-aggregated clients
      this round (offline at dispatch, or stragglers past the systems
      deadline).  ``selected`` always lists the *survivors* — the
      clients whose updates were actually aggregated.
    - ``metrics``            — optional task-defined extra evaluation
      metrics (e.g. the LM task's held-out perplexity, total and per
      topic cluster); ``None`` on unevaluated rounds and for tasks
      without extras.  Energy-tracking runs (``SystemsConfig.
      track_energy``, ROADMAP (q)) additionally carry the round's
      cohort battery spend (``energy_mah`` / ``energy_total_mah`` /
      ``n_depleted``) here on *every* round.
    - ``staleness``          — mean staleness (in params versions) of
      the updates aggregated this round.  Always 0.0 on the lock-step
      engines (every update trains against the current params); > 0
      only under the async runtime (DESIGN.md §13).
    - ``params_version``     — server params version after this round's
      aggregation.  The lock-step engines bump once per round
      (``round + 1``); the async runtime's version lags the step index
      whenever a step's buffer was empty or fully stale.
    - ``n_faulty``/``n_quarantined`` — fault axis (``FLConfig.faults``,
      DESIGN.md §14): updates that arrived carrying an injected fault
      this round, and clients serving a quarantine after it.  Inert
      zeros when no fault config is active.
    """

    round: int
    selected: tuple[int, ...]
    mean_selected_loss: float
    comm_mb: float
    test_loss: float | None = None
    test_acc: float | None = None
    sim_time: float = 0.0
    sim_clock: float = 0.0
    n_dropped: int = 0
    metrics: dict | None = None
    staleness: float = 0.0
    params_version: int = 0
    n_faulty: int = 0
    n_quarantined: int = 0

    @property
    def evaluated(self) -> bool:
        return self.test_acc is not None


class Engine:
    """Shared state + the canonical round loop; backends fill in hooks.

    ``partition_labels`` is the task-data override threaded through
    ``make_engine(**kwargs)``: a (N,) integer array replacing the task's
    derived per-example partition labels (e.g. real topic ids for the
    LM task), so callers with ground-truth skew structure control the
    non-IID split without subclassing the task.
    """

    backend = "base"

    def __init__(self, cfg: FLConfig, train, test, n_classes: int,
                 partition_labels=None):
        from repro.data.partition import calibrate_alpha, dirichlet_partition, pack_clients
        from repro.engine.tasks import build_task

        self.cfg = cfg
        self.n_classes = n_classes
        self.rng = np.random.default_rng(cfg.seed)
        self.task = build_task(cfg)

        # --- non-IID partition (calibrated to the paper's HD regime),
        # split on the task's per-example label axis ---
        if partition_labels is None:
            labels = np.asarray(self.task.partition_labels(train))
        else:
            labels = np.asarray(partition_labels)
            if labels.shape != (len(train.x),):
                raise ValueError(
                    f"partition_labels must be ({len(train.x)},); got "
                    f"shape {labels.shape}"
                )
        part_classes = self.task.partition_classes(n_classes)
        if partition_labels is not None and (
            labels.min() < 0 or labels.max() >= part_classes
        ):
            raise ValueError(
                f"partition_labels values must lie in [0, {part_classes}) "
                f"(the task's partition-label space); got range "
                f"[{labels.min()}, {labels.max()}]"
            )
        if cfg.partition == "shards":
            from repro.data.partition import calibrate_shards, shard_partition

            s = calibrate_shards(labels, cfg.n_clients, cfg.target_hd,
                                 part_classes, seed=cfg.seed)
            self.alpha = float(s)  # records shards/client in the alpha slot
            self.client_idx = shard_partition(
                labels, cfg.n_clients, s, seed=cfg.seed
            )
        else:
            alpha = cfg.alpha_dirichlet
            if alpha is None:
                alpha = calibrate_alpha(
                    labels, cfg.n_clients, cfg.target_hd, part_classes,
                    seed=cfg.seed,
                )
            self.alpha = float(alpha)
            self.client_idx = dirichlet_partition(
                labels, cfg.n_clients, self.alpha, seed=cfg.seed
            )
        self.hists = self.task.client_features(train, self.client_idx, n_classes)
        xs, ys, mask = pack_clients(train.x, train.y, self.client_idx)
        self.sizes = np.array([len(ix) for ix in self.client_idx])
        # --- population axis (DESIGN.md §15): with a PopulationConfig the
        # packed stacks stay *host-side* behind a ClientStore — only the
        # rows a round actually touches (the resident shards' poll subset
        # and the dispatched cohort) are ever device-put, so per-round
        # device memory is cohort-proportional.  None = today's
        # device-resident stacks, bit-identical.
        self._store: Any = None       # ClientStore in population mode
        self._population: Any = None  # HierarchicalSelector (built below,
        #                               after the strategy fixes needs_losses)
        if cfg.population is not None:
            from repro.population.store import InMemoryStore

            self._store = InMemoryStore(
                xs, ys, mask, self.sizes, np.asarray(self.hists),
                n_shards=cfg.population.n_shards,
            )
            self.xs = self.ys = self.mask = None
        else:
            self.xs, self.ys, self.mask = (
                jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask),
            )
        self.test_x, self.test_y = jnp.asarray(test.x), jnp.asarray(test.y)
        self._train_data = train  # handed to the task when building fns
        self._test_data = test    # handed to the task for extra eval metrics

        # --- model (task-owned) / optimizer-free local SGD ---
        self.params = self.task.init_params(
            jax.random.PRNGKey(cfg.seed), train, n_classes
        )
        self.n_params = count_params(self.params)

        # --- local step budgets (heterogeneous → FedNova is meaningful) ---
        taus = np.ceil(
            self.sizes * cfg.local_epochs / cfg.batch_size
        ).astype(np.int32)
        self.taus = np.maximum(taus, 1)
        self.max_steps = int(min(cfg.max_steps_cap, self.taus.max()))

        # --- systems layer (device profiles / wall clock / deadline,
        # DESIGN.md §10).  None = the frictionless engine; with a config,
        # the strategy dispatches the over-selected cohort (m_eff) and
        # the deadline policy drops stragglers down to the survivors. ---
        self._systems: Any = None  # SystemsRuntime when cfg.systems is set
        if cfg.systems is not None:
            from repro.systems.runtime import SystemsRuntime

            self._systems = SystemsRuntime(
                cfg.systems,
                n_clients=cfg.n_clients,
                steps=np.minimum(self.taus, self.max_steps),
                n_params=self.n_params,
                upload_bytes_per_param=(
                    cfg.compress_bits / 8.0 if cfg.compress_bits else 4.0
                ),
                seed=cfg.seed,
            )
            self.m_eff = cfg.systems.m_effective(cfg.m, cfg.n_clients)
        else:
            self.m_eff = cfg.m
        self.sim_clock = 0.0

        # --- pluggable components, all via the registries ---
        self.strategy = STRATEGY_REGISTRY.build(
            cfg.strategy, m=self.m_eff, **cfg.strategy_kwargs
        )
        if self._systems is None:
            # legacy setup signature kept working for external strategies
            self.strategy.setup(self.hists, self.sizes, seed=cfg.seed)
        else:
            self.strategy.setup(self.hists, self.sizes, seed=cfg.seed,
                                latency=self._systems.latency_hint())
        # --- hierarchical shard selection (population mode): built after
        # the strategy so ``needs_losses`` decides whether shards rank by
        # running loss estimates or by the dedicated loss-blind stream ---
        if cfg.population is not None:
            from repro.population.hierarchy import HierarchicalSelector

            self._population = HierarchicalSelector(
                cfg.population, self._store, seed=cfg.seed,
                needs_losses=self.strategy.needs_losses,
            )
            shard_sizes = np.sort([
                len(self._store.shard_members(s))
                for s in range(cfg.population.n_shards)
            ])
            worst = int(shard_sizes[:cfg.population.shards_per_round].sum())
            if worst < self.m_eff:
                raise ValueError(
                    f"population.shards_per_round="
                    f"{cfg.population.shards_per_round} resident shards can "
                    f"hold as few as {worst} clients but the round needs "
                    f"m_eff={self.m_eff} — raise shards_per_round or lower "
                    f"n_shards/m"
                )
        self._pop_members: np.ndarray | None = None  # set per round
        self.aggregator = get_aggregator(cfg.aggregator, cfg)
        self.agg_state = self.aggregator.init_state(self.params)
        self.client_mode = get_client_mode(cfg.client_mode)
        self.h_clients = self.client_mode.init_client_state(
            self.params, cfg.n_clients
        )

        # --- communication ledger (histogram traffic is the task's
        # clustering-feature dimension: n_classes for classification,
        # hist_bins for the LM task; quantized uploads shrink the
        # per-round upload bytes) ---
        self.comm = CommModel(
            self.n_params, cfg.n_clients, self.hists.shape[1],
            upload_bytes_per_param=(
                cfg.compress_bits / 8.0 if cfg.compress_bits else None
            ),
        )
        self.comm_mb = self.comm.one_time_mb(self.strategy.needs_histograms)

        # --- fault axis (DESIGN.md §14): injection on a dedicated child
        # rng stream, the server-side validation gate, and the
        # ClientHealth quarantine ledger.  None = bit-identical engine.
        self._faults: Any = None
        if cfg.faults is not None:
            from repro.faults.runtime import FaultRuntime

            self._faults = FaultRuntime(
                cfg.faults,
                n_clients=cfg.n_clients,
                seed=cfg.seed,
                params_template=self.params,
            )

        self._build_shared_jits()
        self._round = 0
        # the rounds() PRNG carry, persisted across calls
        self._key: jax.Array | None = None
        self.history: dict[str, list] = {
            "round": [], "test_acc": [], "test_loss": [], "comm_mb": [],
            "mean_selected_loss": [], "selected": [],
        }
        # observability + durability seams (DESIGN.md §12): trackers get
        # every committed RoundResult; a Checkpointer attached here is
        # consulted after each round via its save policy.
        self.trackers: list[Any] = []
        self.checkpointer: Any = None

    # ------------------------------------------------------------------
    def _build_shared_jits(self) -> None:
        cfg = self.cfg
        # The task's (apply, loss, metric) triple; backends thread
        # apply/loss into local_train unchanged.
        apply_fn, loss_fn, metric_fn = self.task.build_fns(
            self._train_data, self.n_classes
        )
        self._apply_fn, self._loss_fn = apply_fn, loss_fn

        def _poll_losses(params, xs, ys, mask, key):
            """Subsampled local empirical loss of the *global* model on
            every client (Algorithm 1 lines 2–4)."""

            def one(x, y, m, k):
                n = x.shape[0]
                p = m / jnp.maximum(m.sum(), 1e-9)
                idx = jax.random.choice(k, n, shape=(cfg.eval_samples,), p=p)
                out = apply_fn(params, jnp.take(x, idx, axis=0))
                return loss_fn(out, jnp.take(y, idx, axis=0), None)

            keys = jax.random.split(key, xs.shape[0])
            return jax.vmap(one)(xs, ys, mask, keys)

        self._poll_losses = jax.jit(_poll_losses, donate_argnums=())

        if cfg.population is not None:
            K = cfg.n_clients

            def _poll_subset(params, xs, ys, mask, members, key):
                """The flat poll restricted to the resident members.
                Per-client subsample keys come from the *same* K-way
                split ``_poll_losses`` performs, indexed by global client
                id, so with one shard (members = arange(K)) this
                reproduces the flat poll bit for bit."""

                def one(x, y, m, k):
                    n = x.shape[0]
                    p = m / jnp.maximum(m.sum(), 1e-9)
                    idx = jax.random.choice(
                        k, n, shape=(cfg.eval_samples,), p=p
                    )
                    out = apply_fn(params, jnp.take(x, idx, axis=0))
                    return loss_fn(out, jnp.take(y, idx, axis=0), None)

                keys = jnp.take(jax.random.split(key, K), members, axis=0)
                return jax.vmap(one)(xs, ys, mask, keys)

            self._poll_subset = jax.jit(_poll_subset, donate_argnums=())

        def _evaluate(params, x, y):
            out = apply_fn(params, x)
            return loss_fn(out, y, None), metric_fn(out, y)

        self._evaluate = jax.jit(_evaluate, donate_argnums=())

        # Task-defined extra evaluation metrics (None for tasks without
        # any): e.g. the LM task's held-out perplexity, total and per
        # topic cluster (ROADMAP (h)).
        self._eval_extra = self.task.build_eval_extra(
            self._test_data, self.n_classes
        )

    @staticmethod
    def _client_keys(key: jax.Array, indices) -> jax.Array:
        """Per-client PRNG keys derived by client index (``fold_in``), so
        a client's local-training stream is identical whichever backend —
        and whichever cohort — it runs in."""
        return jax.vmap(
            lambda i: jax.random.fold_in(key, i)
        )(jnp.asarray(indices, jnp.int32))

    # -- hooks (backend contract) --------------------------------------
    def poll_losses(self, rnd: int, key: jax.Array) -> np.ndarray:
        """(K,) polled losses — zeros when the strategy never polls.
        Population mode polls only the round's resident members (the
        others stay 0 here and are ``-inf``-gated before selection)."""
        if self._population is not None:
            out = np.zeros(self.cfg.n_clients, np.float32)
            if self.strategy.needs_losses:
                members = self._pop_members
                assert members is not None, "poll before begin_round"
                xs, ys, mask = self._store.gather(members)
                out[members] = np.asarray(
                    self._poll_subset(
                        self.params, xs, ys, mask,
                        jnp.asarray(members), key,
                    )
                )
            return out
        if self.strategy.needs_losses:
            return np.asarray(
                self._poll_losses(self.params, self.xs, self.ys, self.mask, key)
            )
        return np.zeros(self.cfg.n_clients, np.float32)

    def _selection_gate(self, rnd: int) -> np.ndarray | None:
        """(K,) bool admission gate for round ``rnd`` — systems
        availability ∧ fault-ledger health; ``None`` when ungated."""
        gate: np.ndarray | None = None
        if self._systems is not None:
            gate = np.asarray(self._systems.available(rnd), bool)
        if self._faults is not None:
            admit = self._faults.health.admitted(rnd)
            gate = admit if gate is None else gate & admit
        return gate

    def _gated_losses(self, rnd: int, losses: np.ndarray,
                      extra_gate: np.ndarray | None = None) -> np.ndarray:
        """Apply the admission gate to the polled losses as ``-inf`` —
        the single place every selection path (lock-step, async
        dispatch, fused chunk driver) excludes offline or quarantined
        clients before the strategy sees the loss vector (DESIGN.md
        §10/§14).  ``extra_gate`` is a caller-side AND (the async
        engine's not-already-in-flight mask)."""
        gate = self._selection_gate(rnd)
        if extra_gate is not None:
            gate = extra_gate if gate is None else gate & extra_gate
        if gate is None:
            return losses
        return np.where(gate, losses, -np.inf).astype(np.float32)

    def select(self, rnd: int, losses: np.ndarray) -> np.ndarray:
        """Sorted indices of this round's participants."""
        raise NotImplementedError

    def local_train(self, rnd: int, sel: np.ndarray, key: jax.Array,
                    survivors: np.ndarray | None = None):
        """Run local training.  Returns ``(payload, sel_losses)`` where
        ``payload`` is backend-opaque (threaded into ``aggregate``) and
        ``sel_losses`` is a (len(sel),) array of local training losses.
        ``survivors`` (systems runs only) is the subset of ``sel`` whose
        update will actually arrive — backends that aggregate inside the
        round (scaleout's psum) weight by it; the others may ignore it
        (dropped clients still *train*, they just miss the upload)."""
        raise NotImplementedError

    def aggregate(self, rnd: int, sel: np.ndarray, payload,
                  survivors: np.ndarray | None = None) -> None:
        """Fold the payload into ``self.params`` (and any server state).
        ``survivors`` (systems runs only, a subset of ``sel``) restricts
        the aggregation to the updates that beat the deadline — weights
        renormalize over the surviving mass; ``None`` means everyone
        arrived (the frictionless call shape, unchanged from before the
        systems axis)."""
        raise NotImplementedError

    # -- fault seam (backend contract; called only when ``cfg.faults``
    # is active, so backends without faults support never implement it) -
    def _payload_stack(self, payload):
        """The stacked trained-params pytree inside a ``local_train``
        payload (leading axis = rows), handed to fault injection and the
        validation gate."""
        raise NotImplementedError

    def _payload_replace(self, payload, stacked):
        """The same payload with its stacked params swapped for the
        (injected / clipped) replacement."""
        raise NotImplementedError

    def _payload_clients(self, sel: np.ndarray) -> np.ndarray:
        """Client id per row of the payload stack.  Row i of the default
        eager payload was trained by ``sel[i]``; the compiled all-K path
        overrides this with the identity."""
        return np.asarray(sel, np.int64)

    def _aggregate_state(self) -> tuple:
        """References to everything ``aggregate`` rebinds, for the
        optimistic-aggregation undo.  Every backend's ``aggregate``
        updates state *functionally* (new pytrees / new floats bound to
        ``self``), so holding the old references is a complete, free
        snapshot — covering ``params``, ``agg_state``, the host tier's
        per-client state, and the compiled compress path's
        ``last_quant_error``."""
        return (
            self.params,
            self.agg_state,
            getattr(self, "h_clients", None),
            getattr(self, "last_quant_error", None),
        )

    def _restore_aggregate_state(self, saved: tuple) -> None:
        params, agg_state, h_clients, qerr = saved
        self.params = params
        self.agg_state = agg_state
        if h_clients is not None:
            self.h_clients = h_clients
        if qerr is not None:
            self.last_quant_error = qerr

    def evaluate(self) -> tuple[float, float]:
        tl, ta = self._evaluate(self.params, self.test_x, self.test_y)
        return float(tl), float(ta)

    def eval_metrics(self) -> dict | None:
        """Task-defined extra metrics on the held-out set (None when the
        task has none) — computed on the ``eval_every`` cadence only."""
        if self._eval_extra is None:
            return None
        return self._eval_extra(self.params, self.test_x, self.test_y)

    def _carry_key(self) -> jax.Array:
        """The persisted ``rounds()`` PRNG carry.  The stream from round
        0 is unchanged from the pre-persistence implementation (one
        3-way split per round off ``PRNGKey(seed + 17)``); persisting the
        carried key just removes the O(rounds) re-split replay a resumed
        ``rounds()`` call used to pay, and lets the fused backend thread
        the same carry through its scanned chunks."""
        if self._key is None:
            self._key = jax.random.PRNGKey(self.cfg.seed + 17)
            # legacy resume (a deserialized engine with _round planted
            # but no stored key): replay the per-round splits once
            for _ in range(self._round):
                self._key, _, _ = jax.random.split(self._key, 3)
        return self._key

    # -- checkpoint / restore (DESIGN.md §12) ---------------------------
    _STATE_VERSION = 1

    def _state_pytree(self) -> dict:
        """The array-valued half of the round carry, serialized as the
        checkpoint pytree (structure doubles as the restore ``like``):
        params, aggregator server state (FedDyn ``h``), per-client state
        (FedDyn ``h_i``), the jax PRNG carry, and any strategy state."""
        state = {
            "params": self.params,
            "agg_state": self.agg_state,
            "h_clients": self.h_clients,
            "prng_key": self._carry_key(),
            "strategy": self.strategy.state_dict(),
        }
        if self._faults is not None and self._faults.has_stale:
            # stale_replay's per-client replay cache is array-valued
            # round carry — it rides the pytree, not the meta
            state["fault_stale"] = self._faults.stale_state()
        return state

    def _config_fingerprint(self) -> dict:
        from repro.checkpoint.tracker import _to_builtin

        return _to_builtin(self.cfg.to_dict())

    def save(self, path: str) -> None:
        """Serialize the full round carry to ``path`` (atomic + fsync'd
        via ``repro.checkpoint.serializer``): the state pytree plus the
        scalar carry (``_round``, ``comm_mb``, ``sim_clock``), the numpy
        selection-rng bit-generator state, the history dict, and the
        ``FLConfig`` fingerprint that ``restore`` verifies."""
        from repro.checkpoint.serializer import save_checkpoint
        from repro.checkpoint.tracker import _to_builtin

        meta: dict[str, Any] = {
            "state_version": self._STATE_VERSION,
            "backend": self.backend,
            "round": int(self._round),
            "comm_mb": float(self.comm_mb),
            "sim_clock": float(self.sim_clock),
            # PCG64 state holds 128-bit ints msgpack can't carry; json can
            "rng_state": json.dumps(self.rng.bit_generator.state),
            "history": _to_builtin(self.history),
            "config": self._config_fingerprint(),
        }
        if self._systems is not None:
            meta["systems"] = self._systems.state_dict()
        meta.update(self._extra_meta())
        save_checkpoint(path, self._state_pytree(), meta=meta)

    def _extra_meta(self) -> dict:
        """Execution-mode hook: extra scalar-valued meta merged into the
        checkpoint (the async runtime records its ledger structure here
        so ``restore`` can rebuild the ``like`` skeleton before the
        arrays load).  The base contribution is the fault-axis
        ``ClientHealth`` ledger, so kill-and-resume mid-quarantine is
        bit-identical (DESIGN.md §14.3) — plus the population axis's
        shard loss estimates (DESIGN.md §15), the hierarchy's only
        cross-round state."""
        meta: dict[str, Any] = {}
        if self._faults is not None:
            meta["faults"] = self._faults.meta_state()
        if self._population is not None:
            meta["population"] = self._population.state_dict()
        return meta

    def restore(self, path: str) -> dict:
        """Install a checkpoint written by ``save`` into this engine.

        The engine must be freshly constructed from the *same*
        ``FLConfig`` (the stored fingerprint is compared and a mismatch
        is rejected — resuming into a different config would silently
        change the experiment).  Returns the checkpoint meta dict."""
        from repro.checkpoint.serializer import load_checkpoint

        state, meta = load_checkpoint(path, like=self._state_pytree())
        if meta.get("state_version") != self._STATE_VERSION:
            raise ValueError(
                f"engine checkpoint state_version "
                f"{meta.get('state_version')!r} unsupported (expected "
                f"{self._STATE_VERSION}) — was {path!r} written by "
                f"Engine.save?"
            )
        want = self._config_fingerprint()
        got = meta.get("config")
        if got != want:
            keys = sorted(set(want) | set(got or {}))
            diff = [k for k in keys if (got or {}).get(k) != want.get(k)]
            raise ValueError(
                f"checkpoint config does not match this engine's FLConfig "
                f"(differing fields: {diff}) — resuming would change the "
                f"experiment; rebuild the engine with the original config"
            )
        self._install_state(state, meta)
        return meta

    def _install_state(self, state: dict, meta: dict) -> None:
        """Install a verified checkpoint's arrays + scalar carry into
        this engine (split from ``restore`` so execution modes can
        extend the install — the async runtime adds its in-flight
        ledger on top)."""
        self.params = jax.tree.map(jnp.asarray, state["params"])
        self.agg_state = (
            None if state["agg_state"] is None
            else jax.tree.map(jnp.asarray, state["agg_state"])
        )
        self.h_clients = (
            None if state["h_clients"] is None
            else jax.tree.map(jnp.asarray, state["h_clients"])
        )
        self._key = jnp.asarray(state["prng_key"])
        self.strategy.load_state_dict(state["strategy"])
        self._round = int(meta["round"])
        self.comm_mb = float(meta["comm_mb"])
        self.sim_clock = float(meta["sim_clock"])
        self.rng.bit_generator.state = json.loads(meta["rng_state"])
        self.history = {k: list(v) for k, v in meta["history"].items()}
        if self._systems is not None:
            self._systems.load_state_dict(meta.get("systems", {}))
        if self._faults is not None:
            self._faults.load_meta_state(meta["faults"])
            if self._faults.has_stale:
                self._faults.load_stale_state(state["fault_stale"])
        if self._population is not None:
            self._population.load_state_dict(meta["population"])

    # -- per-round emission (history / trackers / checkpoints) ----------
    def _record_history(self, r: RoundResult) -> None:
        """Evaluated rounds land in the in-memory history dict (the
        legacy ``FederatedSimulation.run()`` shape, checkpointed so a
        resumed run's history is contiguous)."""
        if not r.evaluated:
            return
        self.history["round"].append(r.round)
        self.history["test_acc"].append(r.test_acc)
        self.history["test_loss"].append(r.test_loss)
        self.history["comm_mb"].append(r.comm_mb)
        self.history["mean_selected_loss"].append(r.mean_selected_loss)
        self.history["selected"].append(list(r.selected))
        # systems runs gain the simulated clock (time-to-accuracy)
        # and the cumulative drop count; tasks with extra eval
        # metrics (LM perplexity) surface them under their own keys.
        # Keys appear only when active, so the legacy history shape
        # is unchanged for plain runs.
        if self._systems is not None:
            self.history.setdefault("sim_clock", []).append(r.sim_clock)
            self.history.setdefault("n_dropped", []).append(r.n_dropped)
        if self._faults is not None:
            self.history.setdefault("n_faulty", []).append(r.n_faulty)
            self.history.setdefault("n_quarantined", []).append(r.n_quarantined)
        for k, v in (r.metrics or {}).items():
            self.history.setdefault(k, []).append(v)

    def _emit(self, result: RoundResult,
              callback: Callable[[RoundResult], None] | None,
              allow_save: bool = True) -> None:
        """Post-commit fan-out for one round, in durability order:
        history row → callback → trackers → checkpoint policy.  The
        engine state (``_round`` et al.) is already committed when this
        runs, so a checkpoint taken here resumes *after* this round;
        trackers fire before the save (at-least-once delivery — a resume
        may re-log rounds past the last checkpoint).  ``allow_save`` is
        the fused backend's chunk-boundary gate: its state commits per
        chunk, so only chunk-final rounds may trigger a save."""
        self._record_history(result)
        if callback is not None:
            callback(result)
        for t in self.trackers:
            t.log_round(result)
        if allow_save and self.checkpointer is not None:
            self.checkpointer.maybe_save(self, result.round)

    def close_trackers(self) -> None:
        for t in self.trackers:
            t.close()

    # -- the canonical round loop --------------------------------------
    def rounds(
        self,
        n_rounds: int | None = None,
        callback: Callable[[RoundResult], None] | None = None,
    ) -> Iterator[RoundResult]:
        """Stream ``RoundResult`` records, one per federated round.

        ``n_rounds=None`` runs the rounds *remaining* to reach
        ``cfg.rounds`` (so a freshly restored engine finishes the
        configured run); pass an explicit count to run chunks."""
        cfg = self.cfg
        if n_rounds is None:
            n_rounds = max(cfg.rounds - self._round, 0)
        key = self._carry_key()

        start = self._round
        for rnd in range(start, start + n_rounds):
            key, k_poll, k_train = jax.random.split(key, 3)

            # population mode (DESIGN.md §15): pick the round's resident
            # shards first — they bound what gets polled and gathered
            pop_gate = None
            if self._population is not None:
                _, self._pop_members = self._population.begin_round(rnd)
                pop_gate = self._population.resident_mask()

            losses = self.poll_losses(rnd, k_poll)
            if self._population is not None:
                # fold raw polled member losses into the shard estimates
                # *before* any gating zeroes them out
                self._population.observe(losses)
            # admission gate (DESIGN.md §10/§14/§15): offline,
            # quarantined, or non-resident clients enter every selection
            # path as -inf before select
            losses = self._gated_losses(rnd, losses, extra_gate=pop_gate)
            sel = np.asarray(self.select(rnd, losses))

            # deadline / availability outcome of the dispatched cohort:
            # survivors keep their aggregation weight, dropped clients
            # (offline, or stragglers past the deadline) are zeroed
            if self._systems is not None:
                outcome = self._systems.outcome(rnd, sel)
                surv = outcome.survivors
                n_reached = outcome.n_reached
                sim_time, n_dropped = outcome.sim_time, outcome.n_dropped
                payload, sel_losses = self.local_train(
                    rnd, sel, k_train, survivors=surv
                )
            else:
                surv = sel
                n_reached = len(sel)
                sim_time, n_dropped = 0.0, 0
                payload, sel_losses = self.local_train(rnd, sel, k_train)

            n_faulty = n_quarantined = 0
            uploaded: float = float(len(surv))
            if self._faults is not None:
                # quarantined clients picked anyway (loss-blind
                # strategies) are dropped like stragglers, before their
                # update can reach the aggregation
                admit = self._faults.health.admitted(rnd)
                surv = np.asarray(surv, np.int64)
                surv = surv[admit[surv]]
                clients = self._payload_clients(sel)
                arrived = np.isin(clients, surv)
                stacked = self._payload_stack(payload)
                injected, pending = self._faults.process_begin(
                    rnd, clients, arrived, stacked, self.params
                )
                if injected is not stacked:
                    payload = self._payload_replace(payload, injected)
                # Optimistic aggregation (DESIGN.md §14.2): dispatch the
                # aggregation assuming the gate flags nobody — true on
                # every honest round — so it overlaps the gate's flagged
                # read-back instead of serializing behind it.  On the
                # rare flagged round, drop the optimistic result (all
                # aggregate paths rebind state functionally, so the
                # saved refs are the untouched pre-round state) and redo
                # with the true survivors — the exact same call either
                # way, so both orders are bit-identical.
                optimistic = clients[arrived]
                saved = self._aggregate_state()
                self.aggregate(rnd, sel, payload, survivors=optimistic)
                finfo = self._faults.process_finish(pending)
                surv = finfo.survivors
                if len(surv) != len(optimistic):
                    self._restore_aggregate_state(saved)
                    self.aggregate(rnd, sel, payload, survivors=surv)
                n_faulty, n_quarantined = finfo.n_faulty, finfo.n_quarantined
                uploaded = finfo.uploaded
            elif self._systems is not None:
                self.aggregate(rnd, sel, payload, survivors=surv)
            else:
                self.aggregate(rnd, sel, payload)

            # population mode polls only the resident members; everyone
            # else is free on the ledger too
            n_polled = (
                None if self._pop_members is None else len(self._pop_members)
            )
            if self._systems is not None or self._faults is not None:
                # the server observes survivor losses only
                keep = np.isin(sel, surv)
                mean_loss = _mean_loss(np.asarray(sel_losses)[keep])
                self.comm_mb += self.comm.round_mb(
                    n_reached, self.strategy.needs_losses,
                    m_uploaded=uploaded, n_polled=n_polled,
                )
            else:
                mean_loss = _mean_loss(sel_losses)
                self.comm_mb += self.comm.round_mb(
                    len(sel), self.strategy.needs_losses, n_polled=n_polled,
                )
            if self._systems is not None:
                self.sim_clock += sim_time

            # energy ledger (ROADMAP (q)): the dispatched-and-online
            # cohort spends its local-training charge; reported every
            # round (not just evaluated ones) via RoundResult.metrics
            energy = None
            if self._systems is not None and self._systems.tracks_energy:
                energy = self._systems.spend_energy(rnd, sel)

            test_loss = test_acc = metrics = None
            # absolute cadence keyed to the *configured* terminal round,
            # so chunked / resumed rounds() calls evaluate on exactly the
            # schedule one contiguous call would (a per-call final-round
            # force-eval would make resumed histories diverge)
            if rnd % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
                test_loss, test_acc = self.evaluate()
                metrics = self.eval_metrics()
            if energy is not None:
                metrics = {**(metrics or {}), **energy}

            self._round = rnd + 1
            self._key = key
            result = RoundResult(
                round=rnd,
                selected=tuple(int(i) for i in surv),
                mean_selected_loss=mean_loss,
                comm_mb=float(self.comm_mb),
                test_loss=test_loss,
                test_acc=test_acc,
                sim_time=float(sim_time),
                sim_clock=float(self.sim_clock),
                n_dropped=int(n_dropped),
                metrics=metrics,
                params_version=rnd + 1,
                n_faulty=int(n_faulty),
                n_quarantined=int(n_quarantined),
            )
            self._emit(result, callback)
            yield result

    def run(self, rounds: int | None = None, log_every: int = 0) -> dict[str, list]:
        """Legacy consumer: drain ``rounds()`` and return the history
        dict (evaluated rounds only, matching
        ``FederatedSimulation.run()``; the rows themselves are appended
        inside ``rounds()`` so checkpoints capture them too)."""
        for r in self.rounds(rounds):
            if r.evaluated and log_every and (r.round % log_every == 0):
                print(
                    f"[{self.cfg.strategy}] round {r.round:4d} "
                    f"acc={r.test_acc:.4f} loss={r.test_loss:.4f} "
                    f"comm={r.comm_mb:.1f}MB"
                )
        return self.history


class MaskSelectionMixin:
    """Selection hook shared by the mask-gated backends.

    ``select`` asks the strategy for a jit-compatible participation mask
    (``select_mask_jax``); any per-round randomness is drawn host-side
    from ``self.rng`` — the same numpy stream ``HostEngine`` would
    consume — so a host run and a mask-gated run of the same config stay
    in lockstep round by round.  ``_check_mask_backend`` is the
    engine-level guard behind the up-front ``FLConfig`` validation
    (defense in depth for mutated / hand-built configs).
    """

    # backends that aggregate inside the compiled round (the psum) can
    # only realize fedavg semantics; ScaleoutEngine flips this on
    requires_fedavg_aggregator = False

    def _check_mask_backend(self) -> None:
        if not getattr(self.strategy, "supports_compiled_selection", False):
            raise ValueError(
                mask_backend_strategy_error(self.cfg.strategy, self.backend)
            )
        if self.cfg.client_mode != "plain":
            raise ValueError(
                mask_backend_client_mode_error(self.cfg.client_mode, self.backend)
            )
        if self.requires_fedavg_aggregator and self.cfg.aggregator != "fedavg":
            raise ValueError(mask_backend_aggregator_error(self.cfg.aggregator))

    def select(self, rnd: int, losses: np.ndarray) -> np.ndarray:
        mask = np.asarray(self.strategy.select_mask_jax(losses, self.rng))
        return np.where(mask)[0]


def rounds_to_accuracy(history: dict[str, list], target: float) -> int | None:
    """First evaluated round reaching ``target`` test accuracy (Fig 3 / the
    paper's −22%-rounds claim); None if never reached."""
    for rnd, acc in zip(history["round"], history["test_acc"]):
        if acc >= target:
            return rnd
    return None
