"""``AsyncConfig`` — the validated, JSON-safe slot behind
``FLConfig.async_mode`` (DESIGN.md §13).

Like ``SystemsConfig``, everything here survives ``FLConfig.to_dict()``
/ ``from_dict`` round-tripping (plain scalars, strings, kwargs dicts);
the runtime machinery (the in-flight ledger, the event clock) lives in
``repro.engine.async_engine``.

The module also owns the two pure cores of the async server rule, kept
free of engine state so the property suite can drive them directly:

- staleness discounts — registered like aggregators
  (``@register_staleness``): ``constant`` (discount off — the degenerate
  contract), ``polynomial`` (FedBuff's ``(1+s)^-a``), ``exponential``
  (``gamma^s``);
- ``staleness_weights`` — the normalized per-buffer aggregation weights
  (non-negative, unit sum over the surviving mass, permutation-
  equivariant);
- ``arrival_order`` — the event queue's deterministic ordering of
  in-flight uploads, whose survivor set must agree with
  ``RoundClock.round_outcome`` when no deadline truncates it.
"""

from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Callable

import numpy as np

from repro.engine.registry import (
    STALENESS_REGISTRY,
    list_staleness_discounts,
    register_staleness,
)

__all__ = [
    "AsyncConfig",
    "arrival_order",
    "make_staleness_discount",
    "staleness_weights",
]

_DISPATCH_MODES = ("async", "sync")


# ------------------------------------------------------------ discounts
@register_staleness("constant")
def constant_discount(staleness: np.ndarray, *, factor: float = 1.0) -> np.ndarray:
    """d(s) = factor — discount off.  A constant scale cancels in the
    normalized weights, so this is the degenerate-equivalence setting."""
    return np.full_like(np.asarray(staleness, np.float64), float(factor))


@register_staleness("polynomial")
def polynomial_discount(staleness: np.ndarray, *, a: float = 0.5) -> np.ndarray:
    """FedBuff's polynomial discount d(s) = (1 + s)^-a (a=0.5 is the
    paper's 1/sqrt(1+s))."""
    return (1.0 + np.asarray(staleness, np.float64)) ** (-float(a))


@register_staleness("exponential")
def exponential_discount(staleness: np.ndarray, *, gamma: float = 0.5) -> np.ndarray:
    """d(s) = gamma^s — a harsher tail than polynomial."""
    return float(gamma) ** np.asarray(staleness, np.float64)


def make_staleness_discount(name: str, **kwargs) -> Callable[[np.ndarray], np.ndarray]:
    """Bind a registered discount to its kwargs; validates eagerly (the
    bound function is probed on a zero staleness) so a bad kwarg fails
    at config construction, not mid-run."""
    fn = STALENESS_REGISTRY[name]

    def bound(staleness: np.ndarray) -> np.ndarray:
        return fn(staleness, **kwargs)

    probe = np.asarray(bound(np.zeros(1, np.int64)), np.float64)
    if probe.shape != (1,) or not np.isfinite(probe).all() or (probe < 0).any():
        raise ValueError(
            f"staleness discount {name!r} with kwargs {kwargs} must map "
            f"staleness to finite non-negative factors; probe gave {probe}"
        )
    return bound


# ----------------------------------------------------------- pure cores
def staleness_weights(sizes: np.ndarray, staleness: np.ndarray,
                      discount: Callable[[np.ndarray], np.ndarray],
                      max_staleness: int | None = None) -> np.ndarray:
    """Aggregation weights over one popped buffer.

    ``w_i ∝ size_i · d(s_i)``, zeroed where ``s_i > max_staleness`` and
    normalized over the surviving mass — non-negative, summing to 1
    whenever anything survives (all-zero when nothing does), and
    permutation-equivariant in the buffer order (the property suite
    asserts all three for arbitrary arrival permutations).
    """
    sizes = np.asarray(sizes, np.float64)
    staleness = np.asarray(staleness, np.int64)
    if sizes.shape != staleness.shape:
        raise ValueError(
            f"sizes and staleness must share a shape; got {sizes.shape} "
            f"vs {staleness.shape}"
        )
    u = sizes * np.asarray(discount(staleness), np.float64)
    if max_staleness is not None:
        u = np.where(staleness <= int(max_staleness), u, 0.0)
    total = u.sum()
    if total <= 0.0:
        return np.zeros_like(u)
    return u / total


def arrival_order(sel: np.ndarray, reached: np.ndarray,
                  arrival_t: np.ndarray) -> np.ndarray:
    """Deterministic upload ordering of one dispatched cohort: reachable
    clients sorted by ``(arrival time, client index)``; unreachable ones
    never enter the queue.  With no deadline, the resulting survivor set
    equals ``RoundClock.round_outcome``'s (asserted in test_systems.py).
    """
    sel = np.asarray(sel, np.int64)
    reached = np.asarray(reached, bool)
    arrival_t = np.asarray(arrival_t, np.float64)
    if not (sel.shape == reached.shape == arrival_t.shape):
        raise ValueError("sel, reached, and arrival_t must share a shape")
    live = np.flatnonzero(reached)
    order = np.lexsort((sel[live], arrival_t[live]))
    return sel[live[order]]


# --------------------------------------------------------------- config
@dataclass
class AsyncConfig:
    """The asynchronous-runtime axis of one federated experiment
    (FedBuff-style; DESIGN.md §13).

    - ``buffer_k`` — the server aggregates as soon as this many in-
      flight uploads have arrived (``None`` → the dispatched cohort size
      ``m_eff``, the degenerate buffer).
    - ``dispatch`` — ``"async"`` (the server keeps ``concurrency``
      clients in flight and never waits for a full cohort) or ``"sync"``
      (lock-step emulation: one cohort dispatched and fully awaited per
      step — the degenerate configuration that must stay bit-identical
      to the synchronous engine).
    - ``concurrency`` — target number of in-flight clients under
      ``dispatch="async"`` (``None`` → ``max(2·buffer_k, m_eff)``).
      Must cover ``buffer_k``, else an aggregation step could never
      gather a full buffer.
    - ``staleness`` / ``staleness_kwargs`` — registered discount applied
      to an update trained against a params version ``s`` aggregations
      old (``constant`` = off, ``polynomial`` = FedBuff's ``(1+s)^-a``,
      ``exponential`` = ``gamma^s``).
    - ``max_staleness`` — arrivals staler than this are dropped with
      exactly zero weight (``None`` = keep everything).
    """

    buffer_k: int | None = None
    dispatch: str = "async"
    concurrency: int | None = None
    staleness: str = "constant"
    staleness_kwargs: dict = field(default_factory=dict)
    max_staleness: int | None = None

    def __post_init__(self) -> None:
        if self.dispatch not in _DISPATCH_MODES:
            raise ValueError(
                f"dispatch must be one of {_DISPATCH_MODES}, got "
                f"{self.dispatch!r}"
            )
        if self.buffer_k is not None and not (
            isinstance(self.buffer_k, int) and self.buffer_k >= 1
        ):
            raise ValueError(
                f"buffer_k must be a positive int (or None = the cohort "
                f"size), got {self.buffer_k!r}"
            )
        if self.concurrency is not None and not (
            isinstance(self.concurrency, int) and self.concurrency >= 1
        ):
            raise ValueError(
                f"concurrency must be a positive int (or None = "
                f"max(2·buffer_k, m_eff)), got {self.concurrency!r}"
            )
        if self.staleness not in list_staleness_discounts():
            raise ValueError(
                f"unknown staleness discount {self.staleness!r}; "
                f"available: {list_staleness_discounts()}"
            )
        if not isinstance(self.staleness_kwargs, dict):
            raise ValueError("staleness_kwargs must be a dict")
        # bad discount kwargs fail here, not mid-run
        make_staleness_discount(self.staleness, **self.staleness_kwargs)
        if self.max_staleness is not None and not (
            isinstance(self.max_staleness, int) and self.max_staleness >= 0
        ):
            raise ValueError(
                f"max_staleness must be a non-negative int (or None = "
                f"unbounded), got {self.max_staleness!r}"
            )

    # ------------------------------------------------------------------
    def buffer_effective(self, m_eff: int) -> int:
        """Resolved buffer size: ``buffer_k`` or the cohort size."""
        return int(self.buffer_k) if self.buffer_k is not None else int(m_eff)

    def concurrency_effective(self, m_eff: int) -> int:
        """Resolved in-flight target under ``dispatch="async"``."""
        if self.concurrency is not None:
            return int(self.concurrency)
        return max(2 * self.buffer_effective(m_eff), int(m_eff))

    def discount_off(self) -> bool:
        """True when the configured discount is the identity — part of
        the degenerate-equivalence contract."""
        return self.staleness == "constant" and float(
            self.staleness_kwargs.get("factor", 1.0)
        ) == 1.0

    @classmethod
    def from_dict(cls, d: dict) -> "AsyncConfig":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown AsyncConfig keys: {sorted(unknown)}")
        return cls(**d)


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def validate_async_combination(cfg) -> None:
    """Cross-field validation of ``FLConfig.async_mode`` against the rest
    of the config (called from ``FLConfig.__post_init__``; single-sourced
    here so the engine-level guard never drifts from it)."""
    acfg: AsyncConfig = cfg.async_mode
    _require(
        cfg.backend in ("host", "compiled"),
        f"async_mode runs on backend='host' or 'compiled' (the event loop "
        f"drives the eager round hooks); got backend={cfg.backend!r}",
    )
    _require(
        cfg.fuse_rounds == 0,
        "async_mode and fuse_rounds > 0 are mutually exclusive — the "
        "fused scan is a lock-step execution mode; set fuse_rounds=0",
    )
    _require(
        cfg.aggregator == "fedavg",
        f"async_mode aggregates staleness-weighted client deltas (fedavg "
        f"semantics); got aggregator={cfg.aggregator!r}",
    )
    _require(
        cfg.client_mode == "plain",
        f"async_mode supports client_mode='plain' only (per-client state "
        f"has no defined semantics for concurrent in-flight training); "
        f"got {cfg.client_mode!r}",
    )
    _require(
        cfg.compress_bits == 0,
        "async_mode aggregates deltas outside the compiled mask-gated "
        "reduce; compress_bits > 0 is not supported with it",
    )
    _require(
        cfg.systems is not None,
        "async_mode needs the systems axis for arrival times — set "
        "FLConfig.systems (SystemsConfig() is the inert baseline)",
    )
    m_eff = cfg.systems.m_effective(cfg.m, cfg.n_clients)
    if acfg.dispatch == "sync":
        _require(
            acfg.buffer_k is None or acfg.buffer_k == m_eff,
            f"dispatch='sync' awaits the whole dispatched cohort, so "
            f"buffer_k must be None or the cohort size {m_eff}; got "
            f"{acfg.buffer_k}",
        )
    else:
        _require(
            cfg.systems.deadline_s is None,
            "dispatch='async' replaces the round deadline with staleness "
            "discounting (stragglers arrive late instead of being "
            "dropped); set systems.deadline_s=None or use "
            "dispatch='sync'",
        )
        k = acfg.buffer_effective(m_eff)
        conc = acfg.concurrency_effective(m_eff)
        _require(
            conc >= k,
            f"concurrency ({conc}) must cover buffer_k ({k}) — with fewer "
            f"clients in flight than the buffer, an aggregation step "
            f"could never fire",
        )
        _require(
            k <= cfg.n_clients,
            f"buffer_k ({k}) cannot exceed the population "
            f"({cfg.n_clients})",
        )
