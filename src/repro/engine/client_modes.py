"""Local-objective client modes as registered objects.

A client mode is the third orthogonal axis of a federated method (after
selection and aggregation): a gradient transform applied inside each
local SGD step, plus optional per-client state.  ``local_train``
(``repro.federated.client``) looks its mode up here at trace time — the
mode name is a static jit argument, so the dispatch costs nothing in the
compiled step.

    modify_grads(grads, params, global_params, h_state, mu) -> grads
    init_client_state(global_params, n_clients)  -> (K,)+leaf state or None
    update_client_state(h_sel, local_params_end, new_global, mu) -> h_sel

The gradient math lives in ``repro.optim.fedmods``; these classes only
add registration and state-threading.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.engine.registry import CLIENT_MODE_REGISTRY, register_client_mode
from repro.optim.fedmods import feddyn_grads, feddyn_update_state, fedprox_grads

__all__ = [
    "ClientMode",
    "PlainMode",
    "FedProxMode",
    "FedDynMode",
    "get_client_mode",
]


class ClientMode:
    """Base: unmodified local SGD (what FedAvg and every selection-only
    method use)."""

    name = "plain"
    needs_h = False  # per-client correction state (FedDyn)?

    def modify_grads(self, grads, params, global_params, h_state, mu: float):
        return grads

    def init_client_state(self, global_params: Any, n_clients: int) -> Any:
        return None

    def update_client_state(self, h_sel, local_params_end, new_global,
                            mu: float):
        return h_sel


@register_client_mode("plain")
class PlainMode(ClientMode):
    name = "plain"


@register_client_mode("fedprox")
class FedProxMode(ClientMode):
    """FedProx: + (mu/2)·‖θ − θ_g‖² proximal term."""

    name = "fedprox"

    def modify_grads(self, grads, params, global_params, h_state, mu: float):
        return fedprox_grads(grads, params, global_params, mu)


@register_client_mode("feddyn")
class FedDynMode(ClientMode):
    """FedDyn: linear-dual correction ⟨h_i, θ⟩ with per-client h_i state."""

    name = "feddyn"
    needs_h = True

    def modify_grads(self, grads, params, global_params, h_state, mu: float):
        return feddyn_grads(grads, params, global_params, h_state, mu)

    def init_client_state(self, global_params: Any, n_clients: int) -> Any:
        return jax.tree.map(
            lambda p: jnp.zeros((n_clients,) + p.shape, jnp.float32),
            global_params,
        )

    def update_client_state(self, h_sel, local_params_end, new_global,
                            mu: float):
        return jax.vmap(
            lambda h, p: feddyn_update_state(h, p, new_global, mu),
            in_axes=(0, 0),
        )(h_sel, local_params_end)


_INSTANCES: dict[str, ClientMode] = {}


def get_client_mode(name: str) -> ClientMode:
    """Registered client-mode singleton (modes are stateless objects; the
    per-client state is threaded explicitly by the engine)."""
    if name not in _INSTANCES:
        _INSTANCES[name] = CLIENT_MODE_REGISTRY.build(name)
    return _INSTANCES[name]
