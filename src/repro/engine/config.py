"""``FLConfig`` — the one serialized description of a federated experiment.

Moved here from ``repro.federated.simulation`` (which re-exports it for
backward compatibility) and extended with:

- ``backend`` — ``"host"`` (numpy selection + vmapped cohort training,
  the paper-faithful simulation), ``"compiled"`` (selection, training,
  and masked aggregation as jitted computations, mirroring the scale-out
  mesh round where every client computes and the participation mask
  gates the aggregation), or ``"scaleout"`` (the same mask-gated
  semantics driven through the shard_map mesh round: clients blocked
  over the ``pod`` axis, aggregation as the selection-weighted psum).
- ``task`` — the federated workload (fourth registry axis):
  ``"classification"`` (the paper's MLP over label-skewed image
  features, the default) or ``"lm"`` (transformer language model over
  token streams with topic skew); ``task_kwargs`` parameterizes the
  task (JSON-safe values only — e.g. the LM model name / reduced flag /
  ``ModelConfig`` field overrides / histogram bins).
- ``fuse_rounds`` — device-resident fused execution (DESIGN.md §8.6):
  when > 0, the compiled backend runs chunks of up to that many rounds
  as one jitted ``lax.scan`` with a donated ``(params, key)`` carry —
  selection must then run fully traced, so the strategy needs
  ``select_mask_traced`` (``supports_traced_selection``); requires
  ``backend="compiled"`` and ``aggregator="fedavg"``.
- ``compress_bits`` — int8-style delta quantization of the cohort
  upload inside the mask-gated aggregation (0 = off, 8 = int8;
  ``repro.federated.compression``); requires ``backend="compiled"``
  and ``aggregator="fedavg"``, and is counted in the ``CommModel``
  upload ledger.
- ``systems`` — the cross-device realism axis (DESIGN.md §10,
  ``repro.systems``): a ``SystemsConfig`` (or its dict form) selecting
  a device profile, an availability trace, a per-round wall-clock
  deadline, and an over-selection factor.  ``None`` (the default) is
  the frictionless engine — bit-identical to the systems-free round
  loop.  Validated and JSON-round-tripping like ``task_kwargs``.
- ``async_mode`` — the asynchronous runtime (DESIGN.md §13,
  ``repro.engine.async_engine``): an ``AsyncConfig`` (or its dict form)
  selecting buffered FedBuff-style aggregation of the first-``k``
  arrivals with staleness-discounted weights; requires the ``systems``
  axis for arrival times.  ``None`` (the default) keeps the lock-step
  round loop.
- eager validation in ``__post_init__`` — component names (including
  ``task``) are checked against the engine registries, so a typo fails
  at config construction rather than mid-run; mask-gated backends
  additionally reject strategies without a jit-compatible
  ``select_mask_jax`` up front, with an error naming the strategies
  that do support it.
- ``to_dict`` / ``from_dict`` round-tripping, so benchmark caches
  (``results/fl_runs.json``) and checkpointed experiments share one
  serialized format.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field, fields
from typing import Any

__all__ = ["FLConfig", "BACKENDS"]

BACKENDS = ("host", "compiled", "scaleout")
_MASK_BACKENDS = ("compiled", "scaleout")  # selection enters as a jit mask
_PARTITIONS = ("shards", "dirichlet")


# Backend-combination error messages — single-sourced here so the
# up-front validation below and the engine-level defense-in-depth guard
# (``MaskSelectionMixin._check_mask_backend``) never drift apart.
def mask_backend_strategy_error(strategy: str, backend: str) -> str:
    from repro.engine.registry import mask_selection_strategies

    return (
        f"strategy {strategy!r} has no jit-compatible selection "
        f"(select_mask_jax), required by backend={backend!r}; either use "
        f"backend='host' or one of the strategies that support it: "
        f"{mask_selection_strategies()}"
    )


def mask_backend_client_mode_error(client_mode: str, backend: str) -> str:
    return (
        f"backend={backend!r} supports client_mode='plain' only (got "
        f"{client_mode!r}); per-client state for unselected clients has "
        f"no scale-out analog"
    )


def mask_backend_aggregator_error(aggregator: str) -> str:
    return (
        "backend='scaleout' aggregates inside the mesh round as the "
        f"mask-gated psum (fedavg semantics); got aggregator={aggregator!r} "
        "— use backend='host' or 'compiled' for other server rules"
    )


def fused_strategy_error(strategy: str) -> str:
    from repro.engine.registry import traced_selection_strategies

    return (
        f"fuse_rounds > 0 runs selection fully traced inside one scanned "
        f"round chunk, which strategy {strategy!r} does not support "
        f"(no select_mask_traced); set fuse_rounds=0 or use one of: "
        f"{traced_selection_strategies()}"
    )


def fused_backend_error(backend: str) -> str:
    return (
        f"fuse_rounds > 0 is a compiled-backend execution mode (the round "
        f"chunk is one jitted lax.scan); got backend={backend!r} — use "
        f"backend='compiled' or set fuse_rounds=0"
    )


def fused_aggregator_error(aggregator: str) -> str:
    return (
        "fuse_rounds > 0 aggregates inside the scanned round chunk "
        f"(mask-gated fedavg semantics); got aggregator={aggregator!r} — "
        "use aggregator='fedavg' or set fuse_rounds=0"
    )


def compress_backend_error(backend: str, aggregator: str) -> str:
    return (
        "compress_bits > 0 quantizes cohort deltas inside the compiled "
        "mask-gated fedavg aggregation; it requires backend='compiled' "
        f"and aggregator='fedavg' (got backend={backend!r}, "
        f"aggregator={aggregator!r})"
    )


def faults_backend_error(backend: str) -> str:
    return (
        "FLConfig.faults injects and screens client updates through the "
        "host/compiled round paths (eager, fused, and async); "
        f"backend={backend!r} has no fault seam — use backend='host' or "
        "'compiled', or set faults=None"
    )


def stale_fused_error() -> str:
    return (
        "fault model 'stale_replay' replays from a host-side cross-round "
        "cache, which the fused scan chunk cannot consult; set "
        "fuse_rounds=0 or drop 'stale_replay' from FaultConfig.models"
    )


def population_backend_error(backend: str) -> str:
    return (
        "FLConfig.population gathers per-round cohorts from a host-side "
        "client store (DESIGN.md §15), which the mesh-resident scaleout "
        f"round cannot consult; backend={backend!r} has no store seam — "
        "use backend='host' or 'compiled', or set population=None"
    )


def population_fused_error() -> str:
    return (
        "FLConfig.population picks resident shards host-side each round "
        "(the shard-level Algorithm 1), which the fused scan chunk cannot "
        "consult mid-scan; set fuse_rounds=0 or population=None"
    )


def population_async_error() -> str:
    return (
        "FLConfig.population assumes the lock-step round loop (resident "
        "shards are chosen per aggregation round); the async runtime's "
        "event clock has no round-resident notion yet — set "
        "async_mode=None or population=None"
    )


def population_client_mode_error(client_mode: str) -> str:
    return (
        "FLConfig.population keeps per-round state cohort-proportional; "
        f"client_mode={client_mode!r} carries a per-client params-shaped "
        "state array (O(K·P), population-proportional by construction) — "
        "use client_mode='plain' or set population=None"
    )


def energy_mode_error(what: str) -> str:
    return (
        "SystemsConfig.track_energy accounts battery spend from each "
        "round's dispatched cohort on the host-side round loop, which "
        f"{what} cannot consult; disable track_energy or drop {what}"
    )


@dataclass
class FLConfig:
    n_clients: int = 100
    m: int = 10                    # participants per round
    rounds: int = 150
    local_epochs: int = 1
    batch_size: int = 64
    lr: float = 0.005              # paper: SGD lr=0.005
    strategy: str = "fedlecc"
    strategy_kwargs: dict = field(default_factory=dict)
    aggregator: str = "fedavg"     # any registered aggregator
    aggregator_kwargs: dict = field(default_factory=dict)  # rule params
                                   # (e.g. trimmed_mean trim_frac)
    client_mode: str = "plain"     # any registered client mode
    mu: float = 0.0                # fedprox mu / feddyn alpha
    partition: str = "shards"      # shards | dirichlet (see partition.py:
                                   # shards = the paper's balanced severe-
                                   # skew regime; dirichlet at matched HD
                                   # degenerates into stub clients)
    alpha_dirichlet: float | None = None   # dirichlet: None → calibrate
    target_hd: float = 0.9
    eval_samples: int = 128        # per-client loss-poll subsample
    max_steps_cap: int = 50
    eval_every: int = 5
    seed: int = 0
    hidden: tuple[int, ...] = (200, 200)   # paper MLP (classification task)
    backend: str = "host"          # host | compiled | scaleout
    task: str = "classification"   # any registered task (classification | lm)
    task_kwargs: dict = field(default_factory=dict)  # JSON-safe task params
    fuse_rounds: int = 0           # >0: scan-fuse round chunks (compiled only)
    compress_bits: int = 0         # >0: quantized cohort-delta aggregation
    systems: Any = None  # SystemsConfig | dict | None (repro.systems)
    async_mode: Any = None  # AsyncConfig | dict | None (DESIGN.md §13)
    faults: Any = None  # FaultConfig | dict | None (DESIGN.md §14)
    population: Any = None  # PopulationConfig | dict | None (DESIGN.md §15)

    # ------------------------------------------------------------------
    def __post_init__(self) -> None:
        self.hidden = tuple(self.hidden)
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}"
            )
        if self.partition not in _PARTITIONS:
            raise ValueError(
                f"partition must be one of {_PARTITIONS}, got {self.partition!r}"
            )
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {self.n_clients}")
        if not 1 <= self.m <= self.n_clients:
            raise ValueError(
                f"m must be in [1, n_clients={self.n_clients}], got {self.m}"
            )
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.eval_every < 1:
            raise ValueError(f"eval_every must be >= 1, got {self.eval_every}")
        if not isinstance(self.strategy_kwargs, dict):
            raise ValueError("strategy_kwargs must be a dict")
        if not isinstance(self.task_kwargs, dict):
            raise ValueError("task_kwargs must be a dict")
        if not isinstance(self.aggregator_kwargs, dict):
            raise ValueError("aggregator_kwargs must be a dict")
        # Component names resolve against the registries (lazy provider
        # import — this is the single lookup path for all four axes).
        from repro.engine.registry import (
            AGGREGATOR_REGISTRY,
            CLIENT_MODE_REGISTRY,
            STRATEGY_REGISTRY,
            TASK_REGISTRY,
        )

        for reg, name in (
            (STRATEGY_REGISTRY, self.strategy),
            (AGGREGATOR_REGISTRY, self.aggregator),
            (CLIENT_MODE_REGISTRY, self.client_mode),
            (TASK_REGISTRY, self.task),
        ):
            if name not in reg:
                raise ValueError(
                    f"unknown {reg.kind} {name!r}; available: {reg.names()}"
                )
        # task_kwargs validate eagerly too: constructing the task is
        # cheap (no model params are materialized), and it surfaces bad
        # kwargs / unsupported model configs (e.g. a non-token LM) here
        # rather than at engine build.
        from repro.engine.tasks import build_task

        try:
            build_task(self)
        except (TypeError, KeyError) as e:  # bad kwarg / unknown model name
            raise ValueError(
                f"invalid task_kwargs for task {self.task!r}: {e}"
            ) from None
        # aggregator_kwargs validate eagerly too: building the aggregator
        # is cheap (no state is materialized) and surfaces unknown /
        # out-of-range rule kwargs here rather than at engine build.
        from repro.engine.aggregators import get_aggregator

        get_aggregator(self.aggregator, self)
        # Mask-gated backends need a jit-compatible selection: reject the
        # combination at construction (previously this surfaced only when
        # the engine was built) with the list of strategies that qualify.
        if self.backend in _MASK_BACKENDS:
            cls = STRATEGY_REGISTRY[self.strategy]
            if not getattr(cls, "supports_compiled_selection", False):
                raise ValueError(
                    mask_backend_strategy_error(self.strategy, self.backend)
                )
            if self.client_mode != "plain":
                raise ValueError(
                    mask_backend_client_mode_error(self.client_mode, self.backend)
                )
        if self.backend == "scaleout" and self.aggregator != "fedavg":
            raise ValueError(mask_backend_aggregator_error(self.aggregator))
        # Fused execution: round chunks run as one scanned jit, so the
        # strategy's per-round decision must itself be traceable and the
        # aggregation must be the in-chunk mask-gated fedavg.
        if self.fuse_rounds < 0:
            raise ValueError(
                f"fuse_rounds must be >= 0 (0 = off), got {self.fuse_rounds}"
            )
        if self.fuse_rounds > 0:
            if self.backend != "compiled":
                raise ValueError(fused_backend_error(self.backend))
            if not getattr(
                STRATEGY_REGISTRY[self.strategy],
                "supports_traced_selection", False,
            ):
                raise ValueError(fused_strategy_error(self.strategy))
            if self.aggregator != "fedavg":
                raise ValueError(fused_aggregator_error(self.aggregator))
        # Systems axis: normalize the dict form (from_dict / JSON caches)
        # to a validated SystemsConfig; SystemsConfig.__post_init__ does
        # the name/range validation itself.
        if self.systems is not None:
            from repro.systems.config import SystemsConfig

            if isinstance(self.systems, dict):
                self.systems = SystemsConfig.from_dict(self.systems)
            elif not isinstance(self.systems, SystemsConfig):
                raise ValueError(
                    f"systems must be a SystemsConfig, its dict form, or "
                    f"None; got {type(self.systems).__name__}"
                )
        if self.compress_bits:
            if not 2 <= self.compress_bits <= 8:
                raise ValueError(
                    f"compress_bits must be 0 (off) or in [2, 8], got "
                    f"{self.compress_bits}"
                )
            if self.backend != "compiled" or self.aggregator != "fedavg":
                raise ValueError(
                    compress_backend_error(self.backend, self.aggregator)
                )
        # Async runtime (DESIGN.md §13): normalize the dict form to a
        # validated AsyncConfig, then cross-check it against the rest of
        # the config (backend / aggregator / systems interplay lives in
        # validate_async_combination, single-sourced in async_config).
        if self.async_mode is not None:
            from repro.engine.async_config import (
                AsyncConfig,
                validate_async_combination,
            )

            if isinstance(self.async_mode, dict):
                self.async_mode = AsyncConfig.from_dict(self.async_mode)
            elif not isinstance(self.async_mode, AsyncConfig):
                raise ValueError(
                    f"async_mode must be an AsyncConfig, its dict form, or "
                    f"None; got {type(self.async_mode).__name__}"
                )
            validate_async_combination(self)
        # Fault axis (DESIGN.md §14): normalize the dict form to a
        # validated FaultConfig, then cross-check against the execution
        # mode — every fault seam lives on the host/compiled paths, and
        # stale_replay's replay cache is host-tier.
        if self.faults is not None:
            from repro.faults.config import FaultConfig

            if isinstance(self.faults, dict):
                self.faults = FaultConfig.from_dict(self.faults)
            elif not isinstance(self.faults, FaultConfig):
                raise ValueError(
                    f"faults must be a FaultConfig, its dict form, or "
                    f"None; got {type(self.faults).__name__}"
                )
            if self.backend not in ("host", "compiled"):
                raise ValueError(faults_backend_error(self.backend))
            if self.fuse_rounds > 0 and "stale_replay" in self.faults.models:
                raise ValueError(stale_fused_error())
        # Population axis (DESIGN.md §15): normalize the dict form to a
        # validated PopulationConfig, then cross-check — the client store
        # and the per-round resident-shard pick live on the host round
        # loop, so the mesh, fused-scan, and async execution modes reject
        # the axis up front (the same shape as the fault axis).
        if self.population is not None:
            from repro.population.config import PopulationConfig

            if isinstance(self.population, dict):
                self.population = PopulationConfig.from_dict(self.population)
            elif not isinstance(self.population, PopulationConfig):
                raise ValueError(
                    f"population must be a PopulationConfig, its dict form, "
                    f"or None; got {type(self.population).__name__}"
                )
            if self.backend not in ("host", "compiled"):
                raise ValueError(population_backend_error(self.backend))
            if self.fuse_rounds > 0:
                raise ValueError(population_fused_error())
            if self.async_mode is not None:
                raise ValueError(population_async_error())
            if self.client_mode != "plain":
                raise ValueError(
                    population_client_mode_error(self.client_mode)
                )
            if self.population.n_shards > self.n_clients:
                raise ValueError(
                    f"population.n_shards={self.population.n_shards} "
                    f"exceeds n_clients={self.n_clients}"
                )
        # Energy accounting (ROADMAP (q)) rides the systems axis; its
        # battery ledger is selection-dependent cross-round state the
        # fused scan and the async event loop cannot carry.
        if self.systems is not None and self.systems.track_energy:
            if self.fuse_rounds > 0:
                raise ValueError(energy_mode_error("fuse_rounds > 0"))
            if self.async_mode is not None:
                raise ValueError(energy_mode_error("async_mode"))

    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-safe dict (tuples become lists; round-trips via from_dict)."""
        d = asdict(self)
        d["hidden"] = list(self.hidden)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FLConfig":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FLConfig keys: {sorted(unknown)}")
        kw = dict(d)
        if "hidden" in kw:
            kw["hidden"] = tuple(kw["hidden"])
        return cls(**kw)
