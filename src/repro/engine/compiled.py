"""CompiledEngine — selection inside the compiled computation.

Mirrors the scale-out mesh round (``repro.federated.scaleout``): every
client runs local training every round — as pods on the production mesh
always do — and *selection enters as a weight vector*: the FedLECC mask
(``fedlecc_select_jax``) is turned into aggregation weights
(``selection_weights``) that zero out unselected clients, exactly the
mask-gated psum of DESIGN.md §3b, here realized as a mask-gated weighted
sum over the stacked client axis.

Because per-client PRNG keys are derived by client index (``fold_in``,
see ``Engine._client_keys``) and zero-weight clients contribute exact
zeros to the aggregation, a ``CompiledEngine`` round is numerically
identical to the ``HostEngine`` round for the same config — the
cross-backend equivalence test asserts this.

Requirements: the strategy must provide a jit-compatible selection
(``supports_compiled_selection`` — the FedLECC family), and
``client_mode`` must be ``"plain"`` (per-client FedDyn state for
unselected clients has no scale-out analog yet).

``make_scaleout_round`` re-exports the production mesh round
(clients ↔ pods, shard_map + psum) as the engine-API entry point used by
``repro.launch.dryrun --federated``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import selection_weights
from repro.engine.base import Engine
from repro.federated.client import local_train

__all__ = ["CompiledEngine", "make_scaleout_round"]


class CompiledEngine(Engine):
    backend = "compiled"

    def __init__(self, cfg, train, test, n_classes: int):
        super().__init__(cfg, train, test, n_classes)
        if not getattr(self.strategy, "supports_compiled_selection", False):
            raise ValueError(
                f"strategy {cfg.strategy!r} has no jit-compatible selection; "
                f"use backend='host' (compiled selection: the fedlecc family)"
            )
        if cfg.client_mode != "plain":
            raise ValueError(
                "backend='compiled' supports client_mode='plain' only "
                f"(got {cfg.client_mode!r})"
            )
        self._taus_j = jnp.asarray(self.taus)
        self._sizes_j = jnp.asarray(self.sizes, jnp.float32)
        self._build_compiled_jits()

    # ------------------------------------------------------------------
    def _build_compiled_jits(self) -> None:
        cfg = self.cfg
        apply_fn, loss_fn = self._apply_fn, self._loss_fn
        K = cfg.n_clients

        def _one_client(global_params, x, y, mask, tau, key):
            return local_train(
                apply_fn, loss_fn, global_params, x, y, mask, tau, key,
                lr=cfg.lr, max_steps=self.max_steps, batch_size=cfg.batch_size,
                mode="plain", mu=cfg.mu, h_state=None,
            )

        vmapped = jax.vmap(_one_client, in_axes=(None, 0, 0, 0, 0, 0))

        def _train_all(params, xs, ys, mask, taus, key):
            keys = self._client_keys(key, jnp.arange(K))
            return vmapped(params, xs, ys, mask, taus, keys)

        self._train_all = jax.jit(_train_all)

        def _masked_weights(mask):
            return selection_weights(mask, self._sizes_j)

        self._masked_weights = jax.jit(_masked_weights)

    # -- hooks ----------------------------------------------------------
    def select(self, rnd: int, losses: np.ndarray) -> np.ndarray:
        mask = np.asarray(self.strategy.select_mask_jax(losses))
        return np.where(mask)[0]

    def local_train(self, rnd: int, sel: np.ndarray, key: jax.Array):
        stacked, losses = self._train_all(
            self.params, self.xs, self.ys, self.mask, self._taus_j, key
        )
        return stacked, np.asarray(losses)[sel]

    def aggregate(self, rnd: int, sel: np.ndarray, payload) -> None:
        stacked = payload
        mask = jnp.zeros((self.cfg.n_clients,), jnp.bool_).at[
            jnp.asarray(sel)
        ].set(True)
        w = self._masked_weights(mask)
        new_params = self.aggregator.aggregate(
            stacked, self.params, w, jnp.asarray(self.taus, jnp.float32),
            self.agg_state, n_selected=len(sel),
        )
        self.agg_state = self.aggregator.update_state(
            self.agg_state, stacked, self.params, w, n_selected=len(sel)
        )
        self.params = new_params


def make_scaleout_round(model_cfg, mesh, lr: float, local_steps: int = 4,
                        compress_bits: int = 0):
    """Engine-API entry for the production mesh round (clients ↔ pods).

    Thin wrapper over ``repro.federated.scaleout.make_federated_round`` —
    the mesh round is the ``CompiledEngine`` semantics at pod scale:
    every pod trains, and the FedLECC ``selection_weights`` vector gates
    the all-reduce.  Imported lazily so ``repro.engine`` stays light.
    """
    from repro.federated.scaleout import make_federated_round

    return make_federated_round(
        model_cfg, mesh, lr=lr, local_steps=local_steps,
        compress_bits=compress_bits,
    )
