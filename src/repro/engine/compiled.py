"""CompiledEngine — selection inside the compiled computation.

Mirrors the scale-out mesh round (``repro.federated.scaleout``):
*selection enters as a weight vector* — the strategy's jit-compatible
mask (``select_mask_jax``) is turned into aggregation weights
(``selection_weights``) that zero out unselected clients, exactly the
mask-gated psum of DESIGN.md §3b realized on one device.

Per-round compute is proportional to the **cohort**, not the
population: since ``cfg.m`` is static, the round gathers the m selected
client stacks with ``jnp.take`` (static shapes — the traced values are
just the indices, so nothing retraces), trains only those m clients,
and aggregates the cohort stack with the cohort slice of the mask-gated
weight vector.  Unselected clients contribute exactly what they did in
the ungathered all-K path — zero-weighted terms — so the result is
numerically identical (the conformance suite locks it against the host
and scaleout backends); what changes is that their ~(K−m)/K share of
the training FLOPs is no longer spent.  ``cohort_gather=False``
(``make_engine`` passthrough) keeps the legacy every-client-trains
path, retained as the scale-out-semantics reference and as the
benchmark baseline (``benchmarks/bench_rounds.py --wallclock``).

Because per-client PRNG keys are derived by client index (``fold_in``,
see ``Engine._client_keys``), a client's local-training stream is
identical whichever cohort it runs in, and a ``CompiledEngine`` round is
numerically identical to the ``HostEngine`` round for the same config —
the cross-backend equivalence test asserts this.

``FLConfig.compress_bits > 0`` swaps the fedavg aggregation for
``compressed_fedavg`` (``repro.federated.compression``): each selected
client's delta is stochastically quantized to ``compress_bits`` before
the weighted reduce, modeling the quantized upload counted by the
``CommModel`` ledger.  The quantization PRNG stream derives from the
round's train key (``fold_in(key, K)`` — client fold_ins use 0..K−1,
so the tag never collides), which keeps it reproducible and shared with
the fused backend.

Requirements: the strategy must provide a jit-compatible selection
(``supports_compiled_selection``), and ``client_mode`` must be
``"plain"`` (per-client FedDyn state for unselected clients has no
scale-out analog yet) — both rejected up front by ``FLConfig``
validation and re-checked here.  Selection is the shared
``MaskSelectionMixin`` path, identical to ``ScaleoutEngine``'s.

``make_scaleout_round`` (the production transformer mesh round) moved to
``repro.engine.scaleout``; the re-export here is kept for backward
compatibility.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import selection_weights
from repro.engine.base import Engine, MaskSelectionMixin
from repro.federated.client import local_train

__all__ = ["CompiledEngine", "make_scaleout_round"]


class CompiledEngine(MaskSelectionMixin, Engine):
    backend = "compiled"

    def __init__(self, cfg, train, test, n_classes: int, partition_labels=None,
                 cohort_gather: bool = True):
        super().__init__(cfg, train, test, n_classes,
                         partition_labels=partition_labels)
        self._check_mask_backend()
        self.cohort_gather = bool(cohort_gather)
        if cfg.population is not None and not self.cohort_gather:
            raise ValueError(
                "FLConfig.population keeps the client stacks host-side, so "
                "the legacy every-client-trains path (cohort_gather=False) "
                "has nothing device-resident to train on — use "
                "cohort_gather=True or set population=None"
            )
        self._taus_j = jnp.asarray(self.taus)
        self._sizes_j = jnp.asarray(self.sizes, jnp.float32)
        self._build_compiled_jits()

    # ------------------------------------------------------------------
    def _build_compiled_jits(self) -> None:
        cfg = self.cfg
        apply_fn, loss_fn = self._apply_fn, self._loss_fn
        K = cfg.n_clients

        def _one_client(global_params, x, y, mask, tau, key):
            return local_train(
                apply_fn, loss_fn, global_params, x, y, mask, tau, key,
                lr=cfg.lr, max_steps=self.max_steps, batch_size=cfg.batch_size,
                mode="plain", mu=cfg.mu, h_state=None,
            )

        vmapped = jax.vmap(_one_client, in_axes=(None, 0, 0, 0, 0, 0))

        def _train_all(params, xs, ys, mask, taus, key):
            keys = self._client_keys(key, jnp.arange(K))
            return vmapped(params, xs, ys, mask, taus, keys)

        self._train_all = jax.jit(_train_all, donate_argnums=())

        def _cohort_train(params, idx, key):
            """Train just the m-client cohort: ``idx`` is traced but its
            shape is static (m = cfg.m), so the gathers and the vmap keep
            one compiled graph across rounds — the no-retrace guard test
            pins this."""
            keys = self._client_keys(key, idx)
            return vmapped(
                params,
                jnp.take(self.xs, idx, axis=0),
                jnp.take(self.ys, idx, axis=0),
                jnp.take(self.mask, idx, axis=0),
                jnp.take(self._taus_j, idx),
                keys,
            )

        # raw body reused inside the fused round chunk (repro.engine.fused)
        self._cohort_train_raw = _cohort_train
        self._train_cohort = jax.jit(_cohort_train, donate_argnums=())

        def _train_gathered(params, xs, ys, mask, taus, idx, key):
            """Population mode (DESIGN.md §15): the cohort stacks arrive
            from the host-side ClientStore instead of the device-resident
            all-K stacks ``_cohort_train`` closes over.  Keys still
            derive *inside* the jit by global client index, exactly like
            ``_cohort_train``, so the same cohort trains bit-identically
            either way."""
            keys = self._client_keys(key, idx)
            return vmapped(params, xs, ys, mask, taus, keys)

        self._train_gathered = jax.jit(_train_gathered, donate_argnums=())

        def _masked_weights(mask):
            return selection_weights(mask, self._sizes_j)

        self._masked_weights = jax.jit(_masked_weights, donate_argnums=())

        if cfg.compress_bits:
            from repro.federated.compression import compressed_fedavg

            self._compressed_agg = jax.jit(
                partial(compressed_fedavg, bits=cfg.compress_bits),
                donate_argnums=(),
            )
        self.last_quant_error: float | None = None

    @staticmethod
    def _quant_key(train_key: jax.Array, n_clients: int) -> jax.Array:
        """The stochastic-rounding stream for compressed aggregation —
        derived from the round's train key with tag K (client fold_ins
        use 0..K−1, so this never collides with a client stream)."""
        return jax.random.fold_in(train_key, n_clients)

    # -- hooks (select comes from MaskSelectionMixin) --------------------
    def local_train(self, rnd: int, sel: np.ndarray, key: jax.Array,
                    survivors: np.ndarray | None = None):
        del survivors  # static-shape cohort always trains; drops are zeroed
        if self.cfg.compress_bits:
            self._qkey = self._quant_key(key, self.cfg.n_clients)
        if self._population is not None:
            xs, ys, mask = self._store.gather(sel)
            stacked, losses = self._train_gathered(
                self.params, xs, ys, mask,
                jnp.asarray(self.taus[sel]),
                jnp.asarray(sel, jnp.int32), key,
            )
            return stacked, np.asarray(losses)
        if self.cohort_gather:
            stacked, losses = self._train_cohort(
                self.params, jnp.asarray(sel, jnp.int32), key
            )
            return stacked, np.asarray(losses)
        stacked, losses = self._train_all(
            self.params, self.xs, self.ys, self.mask, self._taus_j, key
        )
        return stacked, np.asarray(losses)[sel]

    # -- fault seam (DESIGN.md §14): the payload *is* the stack ---------
    def _payload_stack(self, payload):
        return payload

    def _payload_replace(self, payload, stacked):
        return stacked

    def _payload_clients(self, sel: np.ndarray) -> np.ndarray:
        if self.cohort_gather:
            return np.asarray(sel, np.int64)
        # legacy all-K path: row i of the payload is client i
        return np.arange(self.cfg.n_clients, dtype=np.int64)

    def aggregate(self, rnd: int, sel: np.ndarray, payload,
                  survivors: np.ndarray | None = None) -> None:
        stacked = payload
        sel_j = jnp.asarray(sel)
        # The weight mask carries only the *survivors* (systems deadline
        # drops, DESIGN.md §10): dropped cohort members keep their static
        # payload slot but aggregate with exact weight zero — the same
        # mask-gating mechanism that makes unselected clients free.
        weight_idx = sel if survivors is None else survivors
        if survivors is not None and len(survivors) == 0:
            return  # nobody uploaded: the global model stands still
        mask = jnp.zeros((self.cfg.n_clients,), jnp.bool_).at[
            jnp.asarray(weight_idx)
        ].set(True)
        w_full = self._masked_weights(mask)

        if self.cfg.compress_bits:
            # Quantization models the *cohort's* upload, so the reduce
            # always runs over the m selected stacks (extracted from the
            # all-K payload when cohort_gather is off).
            if self.cohort_gather:
                cohort = stacked
            else:
                cohort = jax.tree.map(
                    lambda s: jnp.take(s, sel_j, axis=0), stacked
                )
            new_params, qerr = self._compressed_agg(
                cohort, self.params, jnp.take(w_full, sel_j), self._qkey
            )
            self.last_quant_error = float(qerr)
            self.params = new_params
            return

        if self.cohort_gather:
            w = jnp.take(w_full, sel_j)
            taus = jnp.asarray(self.taus[sel], jnp.float32)
        else:
            w = w_full
            taus = jnp.asarray(self.taus, jnp.float32)
        n_agg = len(weight_idx)
        new_params = self.aggregator.aggregate(
            stacked, self.params, w, taus, self.agg_state, n_selected=n_agg,
        )
        self.agg_state = self.aggregator.update_state(
            self.agg_state, stacked, self.params, w, n_selected=n_agg
        )
        self.params = new_params


def make_scaleout_round(model_cfg, mesh, lr: float, local_steps: int = 4,
                        compress_bits: int = 0):
    """Deprecated location — moved to ``repro.engine.scaleout`` alongside
    ``ScaleoutEngine``.  Thin delegation kept for backward compatibility."""
    from repro.engine.scaleout import make_scaleout_round as _impl

    return _impl(model_cfg, mesh, lr=lr, local_steps=local_steps,
                 compress_bits=compress_bits)
