"""CompiledEngine — selection inside the compiled computation.

Mirrors the scale-out mesh round (``repro.federated.scaleout``): every
client runs local training every round — as pods on the production mesh
always do — and *selection enters as a weight vector*: the FedLECC mask
(``fedlecc_select_jax``) is turned into aggregation weights
(``selection_weights``) that zero out unselected clients, exactly the
mask-gated psum of DESIGN.md §3b, here realized as a mask-gated weighted
sum over the stacked client axis.

Because per-client PRNG keys are derived by client index (``fold_in``,
see ``Engine._client_keys``) and zero-weight clients contribute exact
zeros to the aggregation, a ``CompiledEngine`` round is numerically
identical to the ``HostEngine`` round for the same config — the
cross-backend equivalence test asserts this.

Requirements: the strategy must provide a jit-compatible selection
(``supports_compiled_selection``), and ``client_mode`` must be
``"plain"`` (per-client FedDyn state for unselected clients has no
scale-out analog yet) — both rejected up front by ``FLConfig``
validation and re-checked here.  Selection is the shared
``MaskSelectionMixin`` path, identical to ``ScaleoutEngine``'s.

``make_scaleout_round`` (the production transformer mesh round) moved to
``repro.engine.scaleout``; the re-export here is kept for backward
compatibility.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import selection_weights
from repro.engine.base import Engine, MaskSelectionMixin
from repro.federated.client import local_train

__all__ = ["CompiledEngine", "make_scaleout_round"]


class CompiledEngine(MaskSelectionMixin, Engine):
    backend = "compiled"

    def __init__(self, cfg, train, test, n_classes: int, partition_labels=None):
        super().__init__(cfg, train, test, n_classes,
                         partition_labels=partition_labels)
        self._check_mask_backend()
        self._taus_j = jnp.asarray(self.taus)
        self._sizes_j = jnp.asarray(self.sizes, jnp.float32)
        self._build_compiled_jits()

    # ------------------------------------------------------------------
    def _build_compiled_jits(self) -> None:
        cfg = self.cfg
        apply_fn, loss_fn = self._apply_fn, self._loss_fn
        K = cfg.n_clients

        def _one_client(global_params, x, y, mask, tau, key):
            return local_train(
                apply_fn, loss_fn, global_params, x, y, mask, tau, key,
                lr=cfg.lr, max_steps=self.max_steps, batch_size=cfg.batch_size,
                mode="plain", mu=cfg.mu, h_state=None,
            )

        vmapped = jax.vmap(_one_client, in_axes=(None, 0, 0, 0, 0, 0))

        def _train_all(params, xs, ys, mask, taus, key):
            keys = self._client_keys(key, jnp.arange(K))
            return vmapped(params, xs, ys, mask, taus, keys)

        self._train_all = jax.jit(_train_all)

        def _masked_weights(mask):
            return selection_weights(mask, self._sizes_j)

        self._masked_weights = jax.jit(_masked_weights)

    # -- hooks (select comes from MaskSelectionMixin) --------------------
    def local_train(self, rnd: int, sel: np.ndarray, key: jax.Array):
        stacked, losses = self._train_all(
            self.params, self.xs, self.ys, self.mask, self._taus_j, key
        )
        return stacked, np.asarray(losses)[sel]

    def aggregate(self, rnd: int, sel: np.ndarray, payload) -> None:
        stacked = payload
        mask = jnp.zeros((self.cfg.n_clients,), jnp.bool_).at[
            jnp.asarray(sel)
        ].set(True)
        w = self._masked_weights(mask)
        new_params = self.aggregator.aggregate(
            stacked, self.params, w, jnp.asarray(self.taus, jnp.float32),
            self.agg_state, n_selected=len(sel),
        )
        self.agg_state = self.aggregator.update_state(
            self.agg_state, stacked, self.params, w, n_selected=len(sel)
        )
        self.params = new_params


def make_scaleout_round(model_cfg, mesh, lr: float, local_steps: int = 4,
                        compress_bits: int = 0):
    """Deprecated location — moved to ``repro.engine.scaleout`` alongside
    ``ScaleoutEngine``.  Thin delegation kept for backward compatibility."""
    from repro.engine.scaleout import make_scaleout_round as _impl

    return _impl(model_cfg, mesh, lr=lr, local_steps=local_steps,
                 compress_bits=compress_bits)
