"""Asynchronous federated runtime — FedBuff-style buffered aggregation
(DESIGN.md §13).

The lock-step engines wait out every dispatched cohort (or a deadline)
before aggregating; here the server instead keeps a target number of
clients *in flight* and aggregates as soon as the first ``buffer_k``
uploads arrive:

- **dispatch** — whenever in-flight capacity frees up, the strategy
  selects a fresh cohort among clients that are online *and not already
  in flight* (both enter selection as the ``-inf`` gate every strategy
  already understands); the cohort fetches the current params version
  and its per-client arrival instants (``sim_clock +`` the systems
  layer's simulated round times) are recorded in the in-flight ledger.
- **aggregate** — each step pops the first ``buffer_k`` pending arrivals
  in ``(arrival time, client index)`` order and applies the delta rule

      params ← params + Σ_i w_i · (trained_i − fetched_i)

  with ``w_i ∝ size_i · d(s_i)`` (``staleness_weights``), where the
  staleness ``s_i`` is the number of server aggregations since client i
  fetched; arrivals staler than ``max_staleness`` are dropped with
  exactly zero weight.  The params version bumps once per aggregation
  that actually applies an update.
- **event clock** — ``sim_clock`` advances to the last popped arrival's
  instant (monotone; ``RoundResult.sim_time`` is the step's advance),
  not to deadline boundaries.  Systems lookups (availability, times)
  stay indexed by the integer step — see
  ``SystemsRuntime.state_dict``'s contract.

``AsyncConfig.dispatch = "sync"`` is the degenerate configuration: the
round loop delegates verbatim to the lock-step ``Engine.rounds`` body,
so it is bit-identical to the synchronous engine by construction (the
backend-conformance suite enforces it against a plain sync engine —
params, selections, history, comm ledger, ``sim_clock``).

PRNG discipline: every *dispatch* consumes one ``(key, k_poll,
k_train)`` 3-way split off the persisted round carry — exactly the
per-round split of the sync loop, just taken per dispatch event — and
per-client training keys remain ``fold_in(k_train, client)``, so a
client's local stream never depends on who shares its cohort.

Checkpointing: the in-flight ledger (cohort indices, arrival times,
pending flags, trained stacks, and each cohort's fetched params) rides
in the checkpoint pytree; the ledger's *structure* (group sizes,
fetched versions, dispatch instants) rides in the meta so ``restore``
can rebuild the ``like`` skeleton before the arrays load.  A killed
run resumed mid-buffer replays bit-identically.

Comm accounting is additive through the same ``CommModel``: downloads
(+ the loss poll) are paid at dispatch, uploads when arrivals are
popped — the same per-event split the lock-step loop pays per round.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.async_config import (
    make_staleness_discount,
    staleness_weights,
)
from repro.engine.base import RoundResult, _mean_loss
from repro.engine.compiled import CompiledEngine
from repro.engine.host import HostEngine

__all__ = ["AsyncHostEngine", "AsyncCompiledEngine"]


@dataclass
class _InflightGroup:
    """One dispatched cohort in the in-flight ledger."""

    sel: np.ndarray        # (g,) dispatched client indices
    version: int           # params version the cohort fetched
    dispatch_round: int    # aggregation-step index at dispatch
    dispatch_t: float      # sim_clock at dispatch
    arrival_t: np.ndarray  # (g,) float64 absolute arrival instants
    pending: np.ndarray    # (g,) bool — dispatched, not yet popped
    losses: np.ndarray     # (g,) float32 local training losses
    stacked: Any           # trained client params, leading axis g
    fetched: Any           # the params pytree the cohort trained against
    # fault axis (DESIGN.md §14; arrays only when FLConfig.faults is
    # set): the injected fault per slot (−1 honest) and its parameter —
    # checkpointed with the ledger so a resumed run replays identically.
    fault_kind: np.ndarray | None = None  # (g,) int64
    fault_u: np.ndarray | None = None     # (g,) float32
    # per-slot (norm, finite) of stacked − fetched, computed lazily for
    # the validation gate; a cache, never checkpointed (deterministic
    # recompute from stacked/fetched)
    norms: Any = None


class AsyncRounds:
    """Mixin installing the async round loop + ledger checkpointing on
    top of a lock-step backend (``HostEngine`` / ``CompiledEngine``).
    The backend hooks (``poll_losses`` / ``select`` / ``local_train``)
    are reused unchanged; only the *loop* differs."""

    def __init__(self, cfg, train, test, n_classes: int, **kwargs):
        super().__init__(cfg, train, test, n_classes, **kwargs)
        acfg = cfg.async_mode
        if acfg is None:
            raise ValueError(
                "async engines require FLConfig.async_mode to be set"
            )
        self.async_cfg = acfg
        self._buffer_k = acfg.buffer_effective(self.m_eff)
        self._concurrency = acfg.concurrency_effective(self.m_eff)
        self._discount = make_staleness_discount(
            acfg.staleness, **acfg.staleness_kwargs
        )
        self._version = 0
        self._ledger: list[_InflightGroup] = []

    # -- backend payload adapter ---------------------------------------
    def _dispatch_stack(self, payload):
        """Extract the (g, ...) trained-params stack from the backend's
        ``local_train`` payload."""
        raise NotImplementedError

    # -- the async event loop ------------------------------------------
    def rounds(
        self,
        n_rounds: int | None = None,
        callback: Callable[[RoundResult], None] | None = None,
    ) -> Iterator[RoundResult]:
        if self.async_cfg.dispatch == "sync":
            # Degenerate configuration: the lock-step loop, verbatim —
            # bit-identity with the sync engine holds by construction
            # (the ledger stays empty; checkpoints carry its absence).
            yield from super().rounds(n_rounds, callback)
            return
        yield from self._async_rounds(n_rounds, callback)

    def _inflight_mask(self) -> np.ndarray:
        """(K,) bool — clients with a pending in-flight upload."""
        m = np.zeros(self.cfg.n_clients, bool)
        for g in self._ledger:
            m[g.sel[g.pending]] = True
        return m

    def _n_inflight(self) -> int:
        return sum(int(g.pending.sum()) for g in self._ledger)

    def _fill_inflight(self, rnd: int, key: jax.Array) -> jax.Array:
        """Dispatch fresh cohorts until the in-flight target is met or
        the dispatchable population (online ∧ idle) runs dry.  Each
        dispatch consumes one 3-way split of the round carry."""
        while self._n_inflight() + self.m_eff <= self._concurrency:
            # admission (systems availability ∧ fault-ledger health — a
            # quarantined client is simply not re-dispatched until its
            # backoff expires, which *is* the bounded async retry) plus
            # the async-only idle gate: nobody is dispatched twice
            idle = ~self._inflight_mask()
            gate = self._selection_gate(rnd)
            gate = idle if gate is None else gate & idle
            if not gate.any():
                break
            key, k_poll, k_train = jax.random.split(key, 3)
            losses = self.poll_losses(rnd, k_poll)
            losses = self._gated_losses(rnd, losses, extra_gate=idle)
            sel = np.asarray(self.select(rnd, losses))
            # strategies return m_eff indices even when supply is short;
            # busy/offline clients cannot be dispatched twice
            sel = sel[gate[sel]]
            if sel.size == 0:
                break
            payload, sel_losses = self.local_train(rnd, sel, k_train)
            stacked = self._dispatch_stack(payload)
            fault_kind = fault_u = None
            if self._faults is not None:
                # faults are upload properties: corrupt at dispatch so
                # the poisoned stack rides the ledger — and therefore the
                # checkpoint — making a killed run resumed mid-buffer
                # replay bit-identically
                stacked, fault_kind, fault_u = self._faults.inject_eager(
                    rnd, sel, np.ones(sel.size, bool), stacked, self.params
                )
            times = np.asarray(self._systems.times(rnd), np.float64)[sel]
            self._ledger.append(_InflightGroup(
                sel=np.asarray(sel, np.int64),
                version=int(self._version),
                dispatch_round=int(rnd),
                dispatch_t=float(self.sim_clock),
                arrival_t=np.asarray(self.sim_clock + times, np.float64),
                pending=np.ones(sel.size, bool),
                losses=np.asarray(sel_losses, np.float32),
                stacked=stacked,
                fetched=self.params,
                fault_kind=fault_kind,
                fault_u=fault_u,
            ))
            # downloads + the loss poll are paid at dispatch; uploads
            # are paid when the arrivals are popped
            self.comm_mb += self.comm.round_mb(
                int(sel.size), self.strategy.needs_losses, m_uploaded=0
            )
            if sel.size < self.m_eff:
                break  # partial cohort: the idle population is exhausted
        return key

    def _pending_entries(self) -> list[tuple[float, int, int, int]]:
        """Every pending arrival as ``(arrival_t, client, group_idx,
        slot)``, in deterministic event order."""
        entries = []
        for gi, g in enumerate(self._ledger):
            for si in np.flatnonzero(g.pending):
                entries.append(
                    (float(g.arrival_t[si]), int(g.sel[si]), gi, int(si))
                )
        entries.sort()
        return entries

    def _pop_buffer(self) -> list[tuple[float, int, int, int]]:
        """The first ``buffer_k`` pending arrivals in event order."""
        return self._pending_entries()[: self._buffer_k]

    def _group_norms(self, gi: int):
        g = self._ledger[gi]
        if g.norms is None:
            g.norms = self._faults.entry_norms(g.stacked, g.fetched)
        return g.norms

    def _pop_buffer_validated(self, rnd: int):
        """Fault-axis pop: examine pending arrivals in event order,
        ``buffer_k`` at a time, screening each batch jointly through the
        robust-quantile norm gate.  A flagged arrival is *consumed* —
        pending cleared, upload bytes paid, ledger-recorded — but never
        fills a buffer slot: the next arrival takes its place, so a
        faulty client costs the server wait time, not model mass.  The
        flagged client's health strike starts its quarantine; expiry
        re-admits it at ``_fill_inflight``'s gate (exponential-backoff
        re-dispatch).

        Returns ``(take, scales, consumed, n_faulty, uploaded)`` —
        ``take`` the clean entries (≤ buffer_k) with their clip
        ``scales``, ``consumed`` everything examined (the event clock
        advances over all of it), ``uploaded`` Σ upload fractions."""
        fr = self._faults
        entries = self._pending_entries()
        take: list[tuple[float, int, int, int]] = []
        scales: list[float] = []
        consumed: list[tuple[float, int, int, int]] = []
        flagged_clients: list[int] = []
        pos = 0
        while len(take) < self._buffer_k and pos < len(entries):
            batch = entries[pos: pos + (self._buffer_k - len(take))]
            pos += len(batch)
            consumed.extend(batch)
            if fr.defended:
                norms = np.array(
                    [self._group_norms(gi)[0][si] for (_t, _c, gi, si) in batch]
                )
                finite = np.array(
                    [self._group_norms(gi)[1][si] for (_t, _c, gi, si) in batch]
                )
                flagged, sc, _thr = fr.screen_entry_norms(
                    norms, finite, np.ones(len(batch), bool)
                )
            else:
                flagged = np.zeros(len(batch), bool)
                sc = np.ones(len(batch))
            for e, f, s in zip(batch, flagged, sc):
                if f:
                    flagged_clients.append(e[1])
                    self._ledger[e[2]].pending[e[3]] = False
                else:
                    take.append(e)
                    scales.append(float(s))
        # ground-truth fault count + upload fractions over the consumed
        # entries (the injected kinds ride the ledger)
        kind = np.array(
            [int(self._ledger[gi].fault_kind[si]) for (_t, _c, gi, si) in consumed],
            np.int64,
        )
        u = np.array(
            [float(self._ledger[gi].fault_u[si]) for (_t, _c, gi, si) in consumed],
            np.float32,
        )
        uploaded = float(fr.upload_fractions(kind, u).sum())
        self.comm_mb += self.comm.round_mb(0, False, m_uploaded=uploaded)
        fr.health.record(
            rnd,
            np.array([c for (_t, c, _gi, _si) in consumed], np.int64),
            np.array(flagged_clients, np.int64),
        )
        return take, scales, consumed, int((kind >= 0).sum()), uploaded

    def _aggregate_buffer(self, take, scales=None) -> tuple[np.ndarray, float, int, float]:
        """Apply the staleness-weighted delta rule over the popped
        arrivals.  Returns ``(aggregated_clients, mean_loss, n_dropped,
        mean_staleness)``; bumps ``_version`` iff an update applied."""
        clients = np.array([c for (_t, c, _gi, _si) in take], np.int64)
        stal = np.array(
            [self._version - self._ledger[gi].version
             for (_t, _c, gi, _si) in take],
            np.int64,
        )
        w = staleness_weights(
            self.sizes[clients], stal, self._discount,
            self.async_cfg.max_staleness,
        )
        if scales is not None:
            # the validation gate's norm clip: scaling the delta by s is
            # exactly scaling its weight by s under the delta rule
            w = w * np.asarray(scales, w.dtype)
        kept = w > 0.0
        if self._faults is None:
            # stale uploads still arrived — the ledger pays them either
            # way (with faults active, _pop_buffer_validated already paid
            # every consumed arrival at its upload fraction)
            self.comm_mb += self.comm.round_mb(0, False, m_uploaded=len(take))
        if kept.any():
            delta = None
            # batch the kept entries per group so the tree math runs
            # once per cohort, not once per client
            by_group: dict[int, tuple[list[int], list[float]]] = {}
            for (entry, w_e, k_e) in zip(take, w, kept):
                if not k_e:
                    continue
                slots, ws = by_group.setdefault(entry[2], ([], []))
                slots.append(entry[3])
                ws.append(float(w_e))
            for gi, (slots, ws) in by_group.items():
                g = self._ledger[gi]
                idx = jnp.asarray(np.asarray(slots, np.int64))
                wv = jnp.asarray(np.asarray(ws), jnp.float32)
                contrib = jax.tree.map(
                    lambda st, f, idx=idx, wv=wv: jnp.tensordot(
                        wv,
                        jnp.take(jnp.asarray(st), idx, axis=0)
                        - jnp.asarray(f)[None],
                        axes=1,
                    ),
                    g.stacked, g.fetched,
                )
                delta = (
                    contrib if delta is None
                    else jax.tree.map(jnp.add, delta, contrib)
                )
            self.params = jax.tree.map(
                lambda p, d: p + d, self.params, delta
            )
            self._version += 1
        # mark popped slots served; prune exhausted cohorts
        for (_t, _c, gi, si) in take:
            self._ledger[gi].pending[si] = False
        losses = np.array(
            [self._ledger[gi].losses[si] for (_t, _c, gi, si) in take],
            np.float32,
        )
        self._ledger = [g for g in self._ledger if g.pending.any()]
        agg_clients = np.sort(clients[kept])
        mean_loss = _mean_loss(losses[kept])
        mean_stal = float(stal[kept].mean()) if kept.any() else 0.0
        return agg_clients, mean_loss, int((~kept).sum()), mean_stal

    def _async_rounds(
        self,
        n_rounds: int | None,
        callback: Callable[[RoundResult], None] | None,
    ) -> Iterator[RoundResult]:
        cfg = self.cfg
        if n_rounds is None:
            n_rounds = max(cfg.rounds - self._round, 0)
        key = self._carry_key()

        start = self._round
        for rnd in range(start, start + n_rounds):
            key = self._fill_inflight(rnd, key)
            n_faulty = 0
            if self._faults is not None:
                take, scales, consumed, n_faulty, _up = (
                    self._pop_buffer_validated(rnd)
                )
            else:
                take = self._pop_buffer()
                scales, consumed = None, take
            if consumed:
                # the event clock jumps to the last consumed arrival
                # (monotone: remaining pending arrivals are never
                # earlier than a previously popped buffer's tail) — a
                # flagged arrival costs the server its wait time even
                # though it never fills a buffer slot
                t_agg = max(self.sim_clock, consumed[-1][0])
                sim_time = t_agg - self.sim_clock
                self.sim_clock = t_agg
            else:
                sim_time = 0.0
            if take:
                surv, mean_loss, n_dropped, mean_stal = (
                    self._aggregate_buffer(take, scales)
                )
            else:
                # nobody aggregatable this step: the model stands still
                # (every consumed arrival was flagged, or nobody is in
                # flight and nobody dispatchable)
                surv = np.zeros(0, np.int64)
                mean_loss = float("nan")
                n_dropped, mean_stal = 0, 0.0
                self._ledger = [g for g in self._ledger if g.pending.any()]

            test_loss = test_acc = metrics = None
            if rnd % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
                test_loss, test_acc = self.evaluate()
                metrics = self.eval_metrics()

            self._round = rnd + 1
            self._key = key
            result = RoundResult(
                round=rnd,
                selected=tuple(int(i) for i in surv),
                mean_selected_loss=mean_loss,
                comm_mb=float(self.comm_mb),
                test_loss=test_loss,
                test_acc=test_acc,
                sim_time=float(sim_time),
                sim_clock=float(self.sim_clock),
                n_dropped=int(n_dropped),
                metrics=metrics,
                staleness=float(mean_stal),
                params_version=int(self._version),
                n_faulty=int(n_faulty),
                n_quarantined=(
                    self._faults.health.n_quarantined(rnd)
                    if self._faults is not None else 0
                ),
            )
            self._emit(result, callback)
            yield result

    # -- checkpointing (DESIGN.md §12 + §13) ----------------------------
    def _current_version(self) -> int:
        """Server params version: under ``dispatch="sync"`` aggregation
        fires every round, so the committed round count *is* the
        version; the async loop tracks it explicitly (it lags steps
        with an empty or fully-stale buffer)."""
        if self.async_cfg.dispatch == "sync":
            return self._round
        return self._version

    def _state_pytree(self) -> dict:
        state = super()._state_pytree()
        state["async_groups"] = [
            {
                "sel": np.asarray(g.sel, np.int64),
                "arrival_t": np.asarray(g.arrival_t, np.float64),
                "pending": np.asarray(g.pending, bool),
                "losses": np.asarray(g.losses, np.float32),
                "stacked": g.stacked,
                "fetched": g.fetched,
                # injected-fault slots ride the ledger checkpoint (the
                # stacks are already poisoned — DESIGN.md §14.3) so a
                # resumed pop screens and accounts identically
                **(
                    {
                        "fault_kind": np.asarray(g.fault_kind, np.int64),
                        "fault_u": np.asarray(g.fault_u, np.float32),
                    }
                    if self._faults is not None else {}
                ),
            }
            for g in self._ledger
        ]
        return state

    def _extra_meta(self) -> dict:
        meta = super()._extra_meta()
        meta["async"] = {
            "version": int(self._current_version()),
            "groups": [
                {
                    "version": int(g.version),
                    "dispatch_round": int(g.dispatch_round),
                    "dispatch_t": float(g.dispatch_t),
                    "n": int(g.sel.size),
                }
                for g in self._ledger
            ],
        }
        return meta

    def _skeleton_group(self, info: dict) -> _InflightGroup:
        """An empty ledger group with the checkpointed structure — the
        restore ``like`` shapes (arrays load on top of it)."""
        n = int(info["n"])
        return _InflightGroup(
            sel=np.zeros(n, np.int64),
            version=int(info["version"]),
            dispatch_round=int(info["dispatch_round"]),
            dispatch_t=float(info["dispatch_t"]),
            arrival_t=np.zeros(n, np.float64),
            pending=np.zeros(n, bool),
            losses=np.zeros(n, np.float32),
            stacked=jax.tree.map(
                lambda p: np.zeros(
                    (n,) + np.asarray(p).shape, np.asarray(p).dtype
                ),
                self.params,
            ),
            fetched=jax.tree.map(
                lambda p: np.zeros_like(np.asarray(p)), self.params
            ),
            fault_kind=(
                np.zeros(n, np.int64) if self._faults is not None else None
            ),
            fault_u=(
                np.zeros(n, np.float32) if self._faults is not None else None
            ),
        )

    def restore(self, path: str) -> dict:
        from repro.checkpoint.serializer import load_meta

        info = load_meta(path).get("async")
        if info is None:
            raise ValueError(
                f"checkpoint {path!r} carries no async ledger meta — it "
                f"was not written by an async engine; rebuild without "
                f"FLConfig.async_mode to resume it"
            )
        # the ledger skeleton must exist before the base restore builds
        # its `like` pytree, so the stored arrays have slots to land in
        self._ledger = [self._skeleton_group(g) for g in info["groups"]]
        return super().restore(path)

    def _install_state(self, state: dict, meta: dict) -> None:
        super()._install_state(state, meta)
        self._version = int(meta["async"]["version"])
        for g, arrs in zip(self._ledger, state["async_groups"]):
            g.sel = np.asarray(arrs["sel"], np.int64)
            g.arrival_t = np.asarray(arrs["arrival_t"], np.float64)
            g.pending = np.asarray(arrs["pending"], bool)
            g.losses = np.asarray(arrs["losses"], np.float32)
            g.stacked = jax.tree.map(jnp.asarray, arrs["stacked"])
            g.fetched = jax.tree.map(jnp.asarray, arrs["fetched"])
            if self._faults is not None:
                g.fault_kind = np.asarray(arrs["fault_kind"], np.int64)
                g.fault_u = np.asarray(arrs["fault_u"], np.float32)


class AsyncHostEngine(AsyncRounds, HostEngine):
    """Async runtime over the host backend's hooks."""

    def _dispatch_stack(self, payload):
        stacked, _h_sel = payload  # client_mode="plain" → h_sel is None
        return stacked


class AsyncCompiledEngine(AsyncRounds, CompiledEngine):
    """Async runtime over the compiled backend's hooks.  Always uses the
    gathered-cohort training path (variable dispatch cohorts as static-
    shaped jit entries per distinct size)."""

    def __init__(self, cfg, train, test, n_classes: int,
                 partition_labels=None, cohort_gather: bool = True):
        if not cohort_gather:
            raise ValueError(
                "the async runtime trains dispatched cohorts through the "
                "gathered path; cohort_gather=False is not supported with "
                "FLConfig.async_mode"
            )
        super().__init__(cfg, train, test, n_classes,
                         partition_labels=partition_labels,
                         cohort_gather=True)

    def _dispatch_stack(self, payload):
        return payload
