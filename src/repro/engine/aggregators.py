"""Server aggregation rules as registered objects.

Each aggregator carries its own cross-round server state through three
hooks, so rule-specific bookkeeping (FedDyn's server ``h``) lives here
instead of inside the round loop:

    init_state(global_params)                      -> state (or None)
    aggregate(stacked, global_params, weights,
              taus, state, n_selected)             -> new global params
    update_state(state, stacked, global_params,
                 weights, n_selected)              -> new state

``stacked`` is a pytree with a leading client axis; it may hold just the
selected cohort (host backend) or all K clients with zero weight outside
the selected set (compiled backend) — the rules are weight-gated either
way, so both backends share these objects unchanged.

The pure pytree math stays in ``repro.federated.aggregation``; these
classes only add state-threading and registration.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.engine.registry import AGGREGATOR_REGISTRY, register_aggregator
from repro.federated.aggregation import (
    coordinate_median,
    fedavg,
    feddyn_server,
    feddyn_update_h,
    fednova,
    trimmed_mean,
)

__all__ = [
    "Aggregator",
    "FedAvgAggregator",
    "FedNovaAggregator",
    "FedDynAggregator",
    "TrimmedMeanAggregator",
    "CoordinateMedianAggregator",
    "get_aggregator",
]


class Aggregator:
    """Base aggregator: stateless, must implement ``aggregate``.

    ``kwarg_names`` declares which ``FLConfig.aggregator_kwargs`` keys a
    rule understands; unknown keys fail at construction (``FLConfig``
    builds the aggregator eagerly), not mid-experiment.
    """

    name = "base"
    needs_state = False
    kwarg_names: tuple = ()

    def __init__(self, cfg):
        self.cfg = cfg
        kw = dict(getattr(cfg, "aggregator_kwargs", None) or {})
        unknown = set(kw) - set(self.kwarg_names)
        if unknown:
            raise ValueError(
                f"aggregator {self.name!r} accepts kwargs "
                f"{list(self.kwarg_names)}; unknown: {sorted(unknown)}"
            )
        self.kwargs = kw

    def init_state(self, global_params: Any) -> Any:
        return None

    def aggregate(self, stacked, global_params, weights, taus, state,
                  n_selected: int):
        raise NotImplementedError

    def update_state(self, state, stacked, global_params, weights,
                     n_selected: int):
        return state


@register_aggregator("fedavg")
class FedAvgAggregator(Aggregator):
    """θ ← Σ_i w_i θ_i (weights normalized ∝ N_i over the selected set)."""

    name = "fedavg"

    def aggregate(self, stacked, global_params, weights, taus, state,
                  n_selected: int):
        return fedavg(stacked, weights)


@register_aggregator("fednova")
class FedNovaAggregator(Aggregator):
    """FedNova: τ-normalized client deltas rescaled by τ_eff = Σ w_i τ_i."""

    name = "fednova"

    def aggregate(self, stacked, global_params, weights, taus, state,
                  n_selected: int):
        return fednova(stacked, global_params, weights, taus)


@register_aggregator("feddyn")
class FedDynAggregator(Aggregator):
    """FedDyn server rule with the ``h`` correction as aggregator state.

    The round loop never sees ``h``: ``init_state`` allocates it,
    ``aggregate`` applies θ ← mean_S θ_i − h/α, and ``update_state``
    accumulates h ← h − α·(m/K)·(mean_S θ_i − θ_g).
    """

    name = "feddyn"
    needs_state = True

    def init_state(self, global_params: Any) -> Any:
        return jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), global_params
        )

    def aggregate(self, stacked, global_params, weights, taus, state,
                  n_selected: int):
        theta, mean_params = feddyn_server(
            stacked, weights, state, self.cfg.mu,
            n_selected / self.cfg.n_clients,
        )
        # stash for update_state (called right after in the round loop) so
        # the full-model weighted sum isn't computed twice per round
        self._last_mean = mean_params
        return theta

    def update_state(self, state, stacked, global_params, weights,
                     n_selected: int):
        mean_params = getattr(self, "_last_mean", None)
        if mean_params is None:  # update_state called standalone
            mean_params = fedavg(stacked, weights)
        self._last_mean = None
        return feddyn_update_h(
            state, mean_params, global_params, self.cfg.mu,
            n_selected / self.cfg.n_clients,
        )


@register_aggregator("trimmed_mean")
class TrimmedMeanAggregator(Aggregator):
    """Robust coordinate-wise β-trimmed mean (DESIGN.md §14.2) —
    tolerates up to a ``trim_frac`` fraction of Byzantine participants
    per coordinate.  Host/compiled only (the fused and scale-out paths
    require ``fedavg``)."""

    name = "trimmed_mean"
    kwarg_names = ("trim_frac",)

    def __init__(self, cfg):
        super().__init__(cfg)
        self.trim_frac = float(self.kwargs.get("trim_frac", 0.2))
        if not 0.0 <= self.trim_frac < 0.5:
            raise ValueError(
                f"trim_frac must be in [0, 0.5), got {self.trim_frac}"
            )

    def aggregate(self, stacked, global_params, weights, taus, state,
                  n_selected: int):
        return trimmed_mean(stacked, weights, self.trim_frac)


@register_aggregator("coordinate_median")
class CoordinateMedianAggregator(Aggregator):
    """Robust coordinate-wise median (DESIGN.md §14.2) — the strongest
    per-coordinate breakdown point, at the cost of ignoring client
    weights.  Host/compiled only."""

    name = "coordinate_median"

    def aggregate(self, stacked, global_params, weights, taus, state,
                  n_selected: int):
        return coordinate_median(stacked, weights)


def get_aggregator(name: str, cfg) -> Aggregator:
    """Build a registered aggregator bound to an ``FLConfig``."""
    return AGGREGATOR_REGISTRY.build(name, cfg)
