"""HostEngine — the paper-faithful simulation backend.

Selection is host-side numpy (K scalars per round, DESIGN.md §8.5);
local training vmaps over just the selected cohort inside one jit.  This
is the direct descendant of the old ``FederatedSimulation`` round loop,
with strategy / aggregator / client-mode / task dispatch replaced by the
engine registries and all rule-specific state (FedDyn ``h``) owned by
the registered components.  The workload (model, loss, eval metric)
comes entirely from the task's ``(apply_fn, loss_fn)`` pair — this
backend runs the MLP classification task and the transformer LM task
through the identical hooks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.base import Engine
from repro.federated.client import local_train

__all__ = ["HostEngine"]


class HostEngine(Engine):
    backend = "host"

    def __init__(self, cfg, train, test, n_classes: int, partition_labels=None):
        super().__init__(cfg, train, test, n_classes,
                         partition_labels=partition_labels)
        self._build_host_jits()

    # ------------------------------------------------------------------
    def _build_host_jits(self) -> None:
        cfg = self.cfg
        apply_fn, loss_fn = self._apply_fn, self._loss_fn

        def _one_client(global_params, x, y, mask, tau, key, h):
            return local_train(
                apply_fn, loss_fn, global_params, x, y, mask, tau, key,
                lr=cfg.lr, max_steps=self.max_steps, batch_size=cfg.batch_size,
                mode=cfg.client_mode, mu=cfg.mu, h_state=h,
            )

        h_ax = 0 if self.client_mode.needs_h else None
        self._round_train = jax.jit(
            jax.vmap(_one_client, in_axes=(None, 0, 0, 0, 0, 0, h_ax)),
            donate_argnums=(),
        )

    # -- hooks ----------------------------------------------------------
    def select(self, rnd: int, losses: np.ndarray) -> np.ndarray:
        return self.strategy.select(rnd, losses, self.rng)

    def local_train(self, rnd: int, sel: np.ndarray, key: jax.Array,
                    survivors: np.ndarray | None = None):
        del survivors  # everyone selected trains; drops happen at aggregation
        sel_j = jnp.asarray(sel)
        keys = self._client_keys(key, sel)
        h_sel = (
            jax.tree.map(lambda a: a[sel_j], self.h_clients)
            if self.client_mode.needs_h
            else None
        )
        if self._population is not None:
            # population mode (DESIGN.md §15): cohort rows come from the
            # host-side ClientStore — same values the device gather
            # would produce, so the round is bit-identical
            xs, ys, mask = self._store.gather(sel)
        else:
            xs, ys, mask = self.xs[sel_j], self.ys[sel_j], self.mask[sel_j]
        stacked, local_losses = self._round_train(
            self.params, xs, ys, mask,
            jnp.asarray(self.taus[sel]), keys, h_sel,
        )
        return (stacked, h_sel), np.asarray(local_losses)

    # -- fault seam (DESIGN.md §14): payload rows are the cohort stack --
    def _payload_stack(self, payload):
        return payload[0]

    def _payload_replace(self, payload, stacked):
        return (stacked, payload[1])

    def aggregate(self, rnd: int, sel: np.ndarray, payload,
                  survivors: np.ndarray | None = None) -> None:
        stacked, h_sel = payload
        if survivors is not None and len(survivors) != len(sel):
            # systems deadline/availability drop: only the surviving
            # uploads reach the server — reweight over them (the
            # dropped clients trained locally, but nothing arrived).
            if len(survivors) == 0:
                return  # nobody uploaded: the global model stands still
            keep = np.flatnonzero(np.isin(sel, survivors))
            rows = jnp.asarray(keep)
            stacked = jax.tree.map(lambda a: a[rows], stacked)
            if h_sel is not None:
                h_sel = jax.tree.map(lambda a: a[rows], h_sel)
            sel = np.asarray(sel)[keep]
        w = self.sizes[sel] / self.sizes[sel].sum()
        w_j = jnp.asarray(w, jnp.float32)
        taus_j = jnp.asarray(self.taus[sel], jnp.float32)

        new_params = self.aggregator.aggregate(
            stacked, self.params, w_j, taus_j, self.agg_state,
            n_selected=len(sel),
        )
        self.agg_state = self.aggregator.update_state(
            self.agg_state, stacked, self.params, w_j, n_selected=len(sel)
        )
        self.params = new_params

        if self.client_mode.needs_h:
            h_new = self.client_mode.update_client_state(
                h_sel, stacked, self.params, self.cfg.mu
            )
            sel_j = jnp.asarray(sel)
            self.h_clients = jax.tree.map(
                lambda all_, new: all_.at[sel_j].set(new),
                self.h_clients, h_new,
            )
