"""repro — a JAX federated-learning framework built around FedLECC.

FedLECC (Jimenez-Gutierrez et al., 2026) is a cluster- and loss-guided
client-selection strategy for cross-device FL under label skew.  This
package implements it as a first-class feature of a multi-pod JAX
training/serving framework:

- ``repro.core``       — the paper's contribution (HD, OPTICS, Algorithm 1,
                         baseline selection strategies, comm accounting)
- ``repro.federated``  — FL runtime (vmapped simulation + mesh scale-out)
- ``repro.models``     — composable model zoo (dense/MoE/SSM/hybrid/audio/vlm)
- ``repro.data``       — synthetic datasets + Dirichlet label-skew partitioner
- ``repro.optim``      — SGD/AdamW + FedProx/FedDyn/FedNova
- ``repro.kernels``    — Pallas TPU kernels (hellinger, flash attention,
                         masked aggregation) with pure-jnp oracles
- ``repro.configs``    — assigned architecture configs + paper configs
- ``repro.launch``     — mesh / dry-run / train / serve entry points
"""

__version__ = "0.1.0"
