"""repro — a JAX federated-learning framework built around FedLECC.

FedLECC (Jimenez-Gutierrez et al., 2026) is a cluster- and loss-guided
client-selection strategy for cross-device FL under label skew.  This
package implements it as a first-class feature of a multi-pod JAX
training/serving framework:

- ``repro.core``       — the paper's contribution (HD, OPTICS, Algorithm 1,
                         baseline selection strategies, comm accounting)
- ``repro.engine``     — the pluggable federated engine: strategy /
                         aggregator / client-mode registries, ``FLConfig``
                         (validated, serializable), and the backend-agnostic
                         round protocol (``HostEngine`` | ``CompiledEngine``
                         behind ``FLConfig.backend``) streaming
                         ``RoundResult``s via ``engine.rounds()``
- ``repro.federated``  — FL runtime primitives (client local training,
                         aggregation rules, mesh scale-out round); the old
                         ``FederatedSimulation`` is a deprecated shim over
                         ``repro.engine``
- ``repro.models``     — composable model zoo (dense/MoE/SSM/hybrid/audio/vlm)
- ``repro.data``       — synthetic datasets + Dirichlet label-skew partitioner
- ``repro.optim``      — SGD/AdamW + FedProx/FedDyn/FedNova
- ``repro.kernels``    — Pallas TPU kernels (hellinger, flash attention,
                         masked aggregation) with pure-jnp oracles
- ``repro.configs``    — assigned architecture configs + paper configs
- ``repro.launch``     — mesh / dry-run / train / serve entry points
"""

__version__ = "0.1.0"
