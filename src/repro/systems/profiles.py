"""Device profiles and availability traces (DESIGN.md §10).

A ``DeviceProfile`` is the static per-client hardware description the
``RoundClock`` converts into simulated wall-clock time: local-training
speed in SGD steps per second, and up/down link bandwidth in Mbit/s.
Profiles are built by registered generator presets —

- ``uniform``       — every device identical (the sanity baseline: the
                      round clock is deterministic and deadline-free
                      runs match the frictionless engine round for
                      round).
- ``zipf_compute``  — compute speed follows a Zipf law over a random
                      device ranking (a heavy straggler tail on one
                      axis), uniform bandwidth.
- ``mobile_mix``    — a three-tier phone fleet (high/mid/low-end) with
                      per-device lognormal scatter on both compute and
                      bandwidth; the cross-device regime the FedLECC
                      premise ("strict communication and participation
                      constraints") describes.

Availability is a *trace*: ``AvailabilityModel.mask(t)`` returns the
(K,) on/off state of the fleet at round ``t``, deterministic per
``(seed, t)`` so the host, compiled, scaleout, and fused backends all
consume the identical trace (the fused backend feeds whole chunks of it
into its scanned round as ``lax.scan`` inputs).  Presets:

- ``always``     — everyone online (the default).
- ``bernoulli``  — i.i.d. per round: client i is online w.p. ``p``.
- ``markov``     — per-client two-state chain: on→off w.p. ``p_drop``,
                   off→on w.p. ``p_join``; round-0 states drawn from
                   the stationary distribution.
- ``trace``      — replay a recorded on/off schedule from a CSV or JSON
                   file (ROADMAP (p)): fully deterministic, no rng at
                   all — the seed is ignored.  ``examples/
                   availability_trace.csv`` is a ready-made schedule.

All randomness derives from ``np.random.default_rng`` seeded on a
dedicated child stream of the engine seed — the engine's own selection
rng is never consumed, so enabling a profile does not perturb
selection sequences.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

__all__ = [
    "DeviceProfile",
    "AvailabilityModel",
    "PROFILE_PRESETS",
    "AVAILABILITY_PRESETS",
    "register_profile",
    "register_availability",
    "make_profile",
    "make_availability",
    "list_profiles",
    "list_availability_models",
]

# Child-stream tags: profiles / availability / jitter each ride their own
# rng derived as default_rng([seed, TAG]) so the traces are independent
# of each other and of every PRNG stream the engine already owns.
PROFILE_STREAM = 0x5E3D_0001
AVAILABILITY_STREAM = 0x5E3D_0002
JITTER_STREAM = 0x5E3D_0003


# Per-tier energy defaults (ROADMAP (q)): mAh drawn per local SGD step,
# and battery capacity in mAh — flagship tiers are both more efficient
# per step and carry bigger batteries.  Tiers beyond the table clamp to
# the last row.
_TIER_ENERGY_PER_STEP = (0.010, 0.015, 0.025, 0.040)
_TIER_BATTERY_MAH = (4500.0, 4000.0, 3000.0, 2200.0)


@dataclass(frozen=True)
class DeviceProfile:
    """Static per-client hardware description (all arrays (K,)).

    ``energy_per_step`` / ``battery_mah`` (ROADMAP (q)) default to
    tier-derived values (``_TIER_ENERGY_PER_STEP`` / ``_TIER_BATTERY_MAH``)
    when a preset leaves them ``None`` — so every existing preset gains
    an energy model without changing its signature.  They are inert
    until ``SystemsConfig.track_energy`` turns the battery ledger on.
    """

    compute_speed: np.ndarray   # local SGD steps per simulated second
    down_mbps: np.ndarray       # server → client link, Mbit/s
    up_mbps: np.ndarray         # client → server link, Mbit/s
    tier: np.ndarray            # int device class, 0 = fastest tier
    energy_per_step: np.ndarray | None = None  # mAh per local SGD step
    battery_mah: np.ndarray | None = None      # battery capacity, mAh

    def __post_init__(self) -> None:
        k = self.compute_speed.shape[0]
        if self.energy_per_step is None:
            idx = np.clip(self.tier, 0, len(_TIER_ENERGY_PER_STEP) - 1)
            object.__setattr__(
                self, "energy_per_step",
                np.asarray(_TIER_ENERGY_PER_STEP)[idx].astype(np.float64),
            )
        if self.battery_mah is None:
            idx = np.clip(self.tier, 0, len(_TIER_BATTERY_MAH) - 1)
            object.__setattr__(
                self, "battery_mah",
                np.asarray(_TIER_BATTERY_MAH)[idx].astype(np.float64),
            )
        for name in ("compute_speed", "down_mbps", "up_mbps", "tier",
                     "energy_per_step", "battery_mah"):
            arr = getattr(self, name)
            if arr.shape != (k,):
                raise ValueError(
                    f"DeviceProfile.{name} must be shape ({k},), got {arr.shape}"
                )
        for name in ("compute_speed", "down_mbps", "up_mbps",
                     "energy_per_step", "battery_mah"):
            if not (np.asarray(getattr(self, name)) > 0).all():
                raise ValueError(f"DeviceProfile.{name} must be positive")

    @property
    def n_clients(self) -> int:
        return int(self.compute_speed.shape[0])


PROFILE_PRESETS: dict[str, Callable] = {}
AVAILABILITY_PRESETS: dict[str, type] = {}


def register_profile(name: str):
    def deco(fn):
        PROFILE_PRESETS[name] = fn
        return fn

    return deco


def register_availability(name: str):
    def deco(cls):
        AVAILABILITY_PRESETS[name] = cls
        return cls

    return deco


def list_profiles() -> list[str]:
    return sorted(PROFILE_PRESETS)


def list_availability_models() -> list[str]:
    return sorted(AVAILABILITY_PRESETS)


def make_profile(name: str, n_clients: int, seed: int = 0, **kwargs) -> DeviceProfile:
    """Build the registered profile preset ``name`` for ``n_clients``
    devices, seeded on the profile child stream of ``seed``."""
    if name not in PROFILE_PRESETS:
        raise ValueError(
            f"unknown device profile {name!r}; available: {list_profiles()}"
        )
    rng = np.random.default_rng([int(seed) & 0xFFFF_FFFF, PROFILE_STREAM])
    return PROFILE_PRESETS[name](n_clients, rng, **kwargs)


def make_availability(name: str, n_clients: int, seed: int = 0, **kwargs):
    if name not in AVAILABILITY_PRESETS:
        raise ValueError(
            f"unknown availability model {name!r}; available: "
            f"{list_availability_models()}"
        )
    return AVAILABILITY_PRESETS[name](n_clients, seed=seed, **kwargs)


# ----------------------------------------------------------- generators
@register_profile("uniform")
def uniform_profile(n_clients: int, rng: np.random.Generator, *,
                    speed: float = 25.0, down: float = 50.0,
                    up: float = 25.0) -> DeviceProfile:
    """Every device identical — the sanity baseline: without a deadline
    the simulated round time is a constant and nobody ever straggles."""
    del rng  # deterministic preset
    k = n_clients
    return DeviceProfile(
        compute_speed=np.full(k, float(speed)),
        down_mbps=np.full(k, float(down)),
        up_mbps=np.full(k, float(up)),
        tier=np.zeros(k, np.int64),
    )


@register_profile("zipf_compute")
def zipf_compute_profile(n_clients: int, rng: np.random.Generator, *,
                         exponent: float = 1.1, base_speed: float = 60.0,
                         down: float = 50.0, up: float = 25.0) -> DeviceProfile:
    """Compute speed ∝ 1 / rank^exponent over a random device ranking —
    a heavy straggler tail on the compute axis, uniform links."""
    k = n_clients
    rank = rng.permutation(k) + 1  # 1..K, shuffled
    speed = base_speed / rank.astype(np.float64) ** float(exponent)
    tier = np.clip((4 * (rank - 1)) // max(k, 1), 0, 3)
    return DeviceProfile(
        compute_speed=speed,
        down_mbps=np.full(k, float(down)),
        up_mbps=np.full(k, float(up)),
        tier=tier.astype(np.int64),
    )


# (speed steps/s, down Mbit/s, up Mbit/s) per tier: rough flagship /
# mid-range / low-end phone classes
_MOBILE_TIERS = ((60.0, 150.0, 75.0), (20.0, 50.0, 25.0), (5.0, 10.0, 5.0))


@register_profile("mobile_mix")
def mobile_mix_profile(n_clients: int, rng: np.random.Generator, *,
                       fractions: tuple = (0.2, 0.5, 0.3),
                       scatter: float = 0.25) -> DeviceProfile:
    """Three-tier phone fleet with lognormal per-device scatter — the
    cross-device regime (a ~12× compute spread and a ~15× link spread
    between the best flagship and the worst low-end device)."""
    fr = np.asarray(fractions, np.float64)
    if fr.shape != (3,) or (fr < 0).any() or fr.sum() <= 0:
        raise ValueError(
            f"mobile_mix fractions must be 3 non-negative weights, got {fractions}"
        )
    fr = fr / fr.sum()
    k = n_clients
    tier = rng.choice(3, size=k, p=fr)
    base = np.asarray(_MOBILE_TIERS)[tier]            # (K, 3)
    # mean-1 lognormal scatter per device per attribute
    s = float(scatter)
    noise = rng.lognormal(-0.5 * s * s, s, size=(k, 3)) if s > 0 else 1.0
    vals = base * noise
    return DeviceProfile(
        compute_speed=vals[:, 0],
        down_mbps=vals[:, 1],
        up_mbps=vals[:, 2],
        tier=tier.astype(np.int64),
    )


# --------------------------------------------------------- availability
class AvailabilityModel:
    """Base trace: everyone always online.  ``mask(t)`` is deterministic
    per (seed, t) — the contract every backend's gating relies on."""

    name = "always"

    def __init__(self, n_clients: int, seed: int = 0):
        self.K = int(n_clients)
        self.seed = int(seed) & 0xFFFF_FFFF

    def mask(self, t: int) -> np.ndarray:
        """(K,) bool — client online states at round ``t``."""
        del t
        return np.ones(self.K, bool)

    def _rng(self, t: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, AVAILABILITY_STREAM, int(t)])


register_availability("always")(AvailabilityModel)


@register_availability("bernoulli")
class BernoulliAvailability(AvailabilityModel):
    """i.i.d. per round: client i online w.p. ``p`` (no memory)."""

    name = "bernoulli"

    def __init__(self, n_clients: int, seed: int = 0, *, p: float = 0.9):
        super().__init__(n_clients, seed)
        if not 0.0 < p <= 1.0:
            raise ValueError(f"bernoulli availability needs 0 < p <= 1, got {p}")
        self.p = float(p)

    def mask(self, t: int) -> np.ndarray:
        return self._rng(t).random(self.K) < self.p


@register_availability("markov")
class MarkovAvailability(AvailabilityModel):
    """Per-client two-state on/off chain: on→off w.p. ``p_drop``,
    off→on w.p. ``p_join``; round-0 states from the stationary
    distribution.  The trace is materialized incrementally and cached,
    so ``mask(t)`` is O(1) after the first visit and identical however
    many times (or in whatever chunking) the backends replay it."""

    name = "markov"

    def __init__(self, n_clients: int, seed: int = 0, *,
                 p_drop: float = 0.1, p_join: float = 0.5):
        super().__init__(n_clients, seed)
        for label, p in (("p_drop", p_drop), ("p_join", p_join)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"markov availability {label} must be in [0, 1]")
        if p_drop + p_join <= 0:
            raise ValueError("markov availability needs p_drop + p_join > 0")
        self.p_drop, self.p_join = float(p_drop), float(p_join)
        self._trace: list[np.ndarray] = []

    def mask(self, t: int) -> np.ndarray:
        while len(self._trace) <= t:
            step = len(self._trace)
            rng = self._rng(step)
            if step == 0:
                p_on = self.p_join / (self.p_join + self.p_drop)
                state = rng.random(self.K) < p_on
            else:
                prev = self._trace[-1]
                u = rng.random(self.K)
                state = np.where(prev, u >= self.p_drop, u < self.p_join)
            self._trace.append(state)
        return self._trace[t]


@register_availability("trace")
class TraceAvailability(AvailabilityModel):
    """Replay a recorded per-client on/off schedule from a file —
    measured fleet traces instead of a synthetic process (ROADMAP (p)).

    Formats (chosen by file extension):

    - ``.csv``  — one row per round, ``n_clients`` comma-separated 0/1
      columns; ``#`` lines are comments.
    - ``.json`` — ``{"rounds": [[0/1, ...], ...]}``.

    ``wrap=True`` (default) cycles the schedule past its last row (round
    ``t`` replays row ``t mod T``); ``wrap=False`` holds the final row
    forever.  The trace is fully deterministic — no rng is ever drawn,
    the seed is ignored — so every backend (and a resumed run) replays
    the identical fleet history.
    """

    name = "trace"

    def __init__(self, n_clients: int, seed: int = 0, *,
                 path: str, wrap: bool = True):
        super().__init__(n_clients, seed)
        rows = self._load(str(path))
        sched = np.asarray(rows)
        if sched.ndim != 2 or sched.shape[0] == 0:
            raise ValueError(
                f"availability trace {path!r} must be a non-empty 2-D "
                f"(rounds × clients) schedule, got shape {sched.shape}"
            )
        if sched.shape[1] != self.K:
            raise ValueError(
                f"availability trace {path!r} has {sched.shape[1]} client "
                f"columns but the run has n_clients={self.K}"
            )
        vals = sched.astype(np.float64)
        if not np.isin(vals, (0.0, 1.0)).all():
            raise ValueError(
                f"availability trace {path!r} must contain only 0/1 "
                f"entries"
            )
        self.path = str(path)
        self.schedule = vals.astype(bool)
        self.wrap = bool(wrap)

    @staticmethod
    def _load(path: str):
        if path.endswith(".json"):
            import json

            with open(path) as f:
                doc = json.load(f)
            if not isinstance(doc, dict) or "rounds" not in doc:
                raise ValueError(
                    f"JSON availability trace {path!r} must be an object "
                    f'with a "rounds" key holding the schedule'
                )
            return doc["rounds"]
        if path.endswith(".csv"):
            rows = np.loadtxt(path, delimiter=",", comments="#", ndmin=2)
            return rows
        raise ValueError(
            f"availability trace {path!r} must be a .csv or .json file"
        )

    def mask(self, t: int) -> np.ndarray:
        n = self.schedule.shape[0]
        i = int(t) % n if self.wrap else min(int(t), n - 1)
        return self.schedule[i].copy()
