"""``SystemsConfig`` — the validated, JSON-safe slot behind
``FLConfig.systems`` (DESIGN.md §10).

Like ``task_kwargs``, everything here must survive
``FLConfig.to_dict()`` / ``from_dict`` round-tripping, so the fields
are plain scalars, strings, and kwargs dicts; the heavyweight runtime
objects (profiles, availability traces, the clock) are built by
``repro.systems.runtime.SystemsRuntime`` at engine construction.

Validation is eager: preset names resolve against the profile /
availability registries at config construction, so a typo fails before
any data is touched — the same contract ``FLConfig`` gives the four
component registries.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, fields

__all__ = ["SystemsConfig"]


@dataclass
class SystemsConfig:
    """The systems axis of one federated experiment.

    - ``profile`` / ``profile_kwargs`` — registered device-profile
      preset (``uniform`` | ``zipf_compute`` | ``mobile_mix``) and its
      generator kwargs.
    - ``availability`` / ``availability_kwargs`` — registered on/off
      trace model (``always`` | ``bernoulli`` | ``markov``).  Offline
      clients are ``-inf``-gated out of the loss vector before every
      selection call, and dropped (zero aggregation weight) if a
      loss-blind strategy picks them anyway.
    - ``deadline_s`` — per-round wall-clock deadline in simulated
      seconds; reachable clients slower than this are stragglers and
      their updates are dropped.  ``None`` = the server waits for every
      reachable client.
    - ``over_select`` — over-selection factor ≥ 1: the strategy
      dispatches ``ceil(m · over_select)`` clients so the deadline can
      drop stragglers and still aggregate ~m updates.
    - ``jitter_sigma`` — lognormal sigma of per-round compute-time
      noise (0 = deterministic device times).
    - ``track_energy`` — battery accounting (ROADMAP (q)): each
      dispatched-and-online client spends
      ``steps · profile.energy_per_step`` mAh per round; a drained
      battery makes the client unavailable (the same ``-inf`` admission
      gate availability uses), and ``RoundResult.metrics`` reports the
      cohort spend.  Off by default — the ledger is extra cross-round
      state the fused / async execution modes reject.
    """

    profile: str = "uniform"
    profile_kwargs: dict = field(default_factory=dict)
    availability: str = "always"
    availability_kwargs: dict = field(default_factory=dict)
    deadline_s: float | None = None
    over_select: float = 1.0
    jitter_sigma: float = 0.0
    track_energy: bool = False

    def __post_init__(self) -> None:
        from repro.systems.profiles import (
            list_availability_models,
            list_profiles,
        )

        if self.profile not in list_profiles():
            raise ValueError(
                f"unknown device profile {self.profile!r}; available: "
                f"{list_profiles()}"
            )
        if self.availability not in list_availability_models():
            raise ValueError(
                f"unknown availability model {self.availability!r}; "
                f"available: {list_availability_models()}"
            )
        for name in ("profile_kwargs", "availability_kwargs"):
            if not isinstance(getattr(self, name), dict):
                raise ValueError(f"{name} must be a dict")
        if self.deadline_s is not None and not self.deadline_s > 0:
            raise ValueError(
                f"deadline_s must be positive (or None = no deadline), got "
                f"{self.deadline_s}"
            )
        if not (isinstance(self.over_select, (int, float))
                and math.isfinite(self.over_select) and self.over_select >= 1.0):
            raise ValueError(
                f"over_select must be a finite factor >= 1, got "
                f"{self.over_select!r}"
            )
        self.over_select = float(self.over_select)
        if not self.jitter_sigma >= 0.0:
            raise ValueError(
                f"jitter_sigma must be >= 0, got {self.jitter_sigma}"
            )
        if self.deadline_s is not None:
            self.deadline_s = float(self.deadline_s)
        self.track_energy = bool(self.track_energy)

    def m_effective(self, m: int, n_clients: int) -> int:
        """Dispatched cohort size: ``ceil(m · over_select)``, clipped to
        the population."""
        return min(int(n_clients), max(int(m), math.ceil(m * self.over_select)))

    @classmethod
    def from_dict(cls, d: dict) -> "SystemsConfig":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown SystemsConfig keys: {sorted(unknown)}")
        return cls(**d)
