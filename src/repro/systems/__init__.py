"""repro.systems — cross-device realism for the federated engine.

The engine's round protocol simulates a frictionless world: every client
is always online, trains instantly, and never misses a deadline, so
"rounds-to-accuracy" is the only currency the benchmarks can report.
This package adds the systems axis (DESIGN.md §10) that cross-device FL
actually runs under (Fu et al., 2022 treat availability, stragglers and
deadline-based over-selection as first-class selection inputs):

- ``profiles``  — per-client ``DeviceProfile`` (compute speed, up/down
                  bandwidth, device tier) with registered generator
                  presets (``uniform``, ``zipf_compute``, ``mobile_mix``)
                  and trace-driven availability models (``always``,
                  ``bernoulli``, ``markov`` on–off states, seeded on a
                  dedicated child of the engine seed so every backend
                  sees the identical trace).
- ``clock``     — ``RoundClock`` turns each round into simulated
                  wall-clock seconds (download + local steps /
                  compute_speed + upload over the ``CommModel`` byte
                  ledger) and ``round_outcome`` applies the deadline
                  policy: stragglers past the deadline are dropped and
                  aggregation reweights the survivors.
- ``config``    — ``SystemsConfig``, the JSON-safe, validated slot
                  behind ``FLConfig.systems`` (deadline, over-selection
                  factor, profile / availability presets).
- ``runtime``   — ``SystemsRuntime``, the per-engine object the round
                  loop consults: availability mask per round, per-client
                  round times, and the dispatched-cohort outcome.

Selection stays static-shaped on every backend: the strategy selects
``ceil(m · over_select)`` clients, and dropped clients (offline or past
the deadline) are zeroed in ``selection_weights`` — exactly the
mask-gating mechanism the compiled / fused paths already rely on, so
the no-retrace guarantees carry over unchanged.
"""

from repro.systems.clock import RoundClock, RoundOutcome, round_outcome
from repro.systems.config import SystemsConfig
from repro.systems.profiles import (
    AVAILABILITY_PRESETS,
    PROFILE_PRESETS,
    AvailabilityModel,
    DeviceProfile,
    list_availability_models,
    list_profiles,
    make_availability,
    make_profile,
)
from repro.systems.runtime import SystemsRuntime

__all__ = [
    "AVAILABILITY_PRESETS",
    "PROFILE_PRESETS",
    "AvailabilityModel",
    "DeviceProfile",
    "RoundClock",
    "RoundOutcome",
    "SystemsConfig",
    "SystemsRuntime",
    "list_availability_models",
    "list_profiles",
    "make_availability",
    "make_profile",
    "round_outcome",
]
