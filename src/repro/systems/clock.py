"""Wall-clock round simulation and deadline semantics (DESIGN.md §10).

``RoundClock`` converts a ``DeviceProfile`` plus the engine's byte
ledger into per-client round durations:

    T_i(t) = download_MB·8 / down_mbps_i
           + steps_i · jitter_i(t) / compute_speed_i
           + upload_MB·8 / up_mbps_i

``steps_i`` is the number of local SGD steps the engine actually
executes for client i (``min(tau_i, max_steps)``); ``jitter_i(t)`` is
optional mean-1 lognormal per-round noise on the compute term (thermal
throttling, background load), deterministic per (seed, round) so every
backend sees identical times.

``round_outcome`` applies the deadline policy to a dispatched cohort:

- clients that are offline at dispatch are dropped immediately (the
  server knows it cannot reach them — they cost nothing);
- reachable clients whose ``T_i(t)`` exceeds the deadline are
  *stragglers*: they trained and missed the upload — the server waits
  the full deadline for them;
- the round's simulated duration is the deadline if anyone straggled,
  else the slowest survivor's ``T_i(t)``;
- aggregation reweights the survivors: the dropped clients are zeroed
  in ``selection_weights`` (``repro.core.selection``), which already
  renormalizes over the surviving mass — masks stay static-shaped, so
  the compiled/fused no-retrace guarantees hold.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.systems.profiles import JITTER_STREAM, DeviceProfile

__all__ = ["RoundClock", "RoundOutcome", "round_outcome"]


class RoundClock:
    """Simulated wall-clock per client per round."""

    def __init__(self, profile: DeviceProfile, download_mb: float,
                 upload_mb: float, steps: np.ndarray,
                 jitter_sigma: float = 0.0, seed: int = 0):
        steps = np.asarray(steps, np.float64)
        if steps.shape != (profile.n_clients,):
            raise ValueError(
                f"steps must be ({profile.n_clients},), got {steps.shape}"
            )
        self.profile = profile
        self.jitter_sigma = float(jitter_sigma)
        self.seed = int(seed) & 0xFFFF_FFFF
        # MB → Mbit: ×8 (the CommModel ledger is MB-denominated)
        self._down_s = float(download_mb) * 8.0 / profile.down_mbps
        self._up_s = float(upload_mb) * 8.0 / profile.up_mbps
        self._compute_s = steps / profile.compute_speed

    def base_times(self) -> np.ndarray:
        """(K,) jitter-free round durations — the profile-derived latency
        rank (what HACCS's latency tiebreak consumes)."""
        return self._down_s + self._compute_s + self._up_s

    def times(self, t: int) -> np.ndarray:
        """(K,) round durations at round ``t`` (compute-term jitter
        applied); deterministic per (seed, t)."""
        if self.jitter_sigma <= 0.0:
            return self.base_times()
        s = self.jitter_sigma
        rng = np.random.default_rng([self.seed, JITTER_STREAM, int(t)])
        jitter = rng.lognormal(-0.5 * s * s, s, size=self.profile.n_clients)
        return self._down_s + self._compute_s * jitter + self._up_s


@dataclass(frozen=True)
class RoundOutcome:
    """What the systems layer did to one dispatched cohort."""

    survivors: np.ndarray     # sorted client indices whose update arrived
    n_dispatched: int         # cohort size the strategy selected
    n_reached: int            # dispatched ∧ online (paid the download)
    n_dropped: int            # dispatched − survivors (offline + stragglers)
    sim_time: float           # simulated seconds this round took


def round_outcome(sel: np.ndarray, avail: np.ndarray, times: np.ndarray,
                  deadline_s: float | None) -> RoundOutcome:
    """Apply availability + deadline to the dispatched cohort ``sel``.

    ``avail``/``times`` are full (K,) vectors for the round; ``sel`` is
    the strategy's index list.  With no deadline the server waits for
    every reachable client (offline ones are dropped at dispatch)."""
    sel = np.asarray(sel, np.int64)
    reached = np.asarray(avail, bool)[sel]
    t_sel = np.asarray(times, np.float64)[sel]
    if deadline_s is None:
        arrived = reached
        straggled = np.zeros_like(reached)
    else:
        arrived = reached & (t_sel <= deadline_s)
        straggled = reached & ~arrived
    survivors = np.sort(sel[arrived])
    if straggled.any():
        sim_time = float(deadline_s)
    elif arrived.any():
        sim_time = float(t_sel[arrived].max())
    else:
        sim_time = float(deadline_s or 0.0)
    return RoundOutcome(
        survivors=survivors,
        n_dispatched=int(sel.size),
        n_reached=int(reached.sum()),
        n_dropped=int(sel.size - survivors.size),
        sim_time=sim_time,
    )
