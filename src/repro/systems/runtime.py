"""``SystemsRuntime`` — the per-engine systems state the round loop
consults (DESIGN.md §10).

Built once in ``Engine.__init__`` from the validated ``SystemsConfig``
plus the engine-derived quantities (executed local steps per client,
model payload bytes, the experiment seed).  The round loop asks it
three things:

- ``available(t)``   — the (K,) availability mask at round ``t``
                       (gates the loss vector to ``-inf`` before every
                       selection call, on every backend);
- ``times(t)``       — the (K,) simulated per-client round durations;
- ``outcome(t, sel)`` / ``outcome_from_mask(t, mask)`` — the deadline
                       policy applied to the dispatched cohort: the
                       surviving participants, the drop count, and the
                       round's simulated duration.  The index and mask
                       entry points share one core, so the eager
                       backends and the fused scan unpacker account
                       rounds identically.

Everything is deterministic per (seed, round): host, compiled,
scaleout, and fused runs of one config see bit-identical availability
traces and round times.
"""

from __future__ import annotations

import numpy as np

from repro.systems.clock import RoundClock, RoundOutcome, round_outcome
from repro.systems.config import SystemsConfig
from repro.systems.profiles import make_availability, make_profile

__all__ = ["SystemsRuntime"]

_MB = 1024.0 * 1024.0


class SystemsRuntime:
    def __init__(self, cfg: SystemsConfig, *, n_clients: int,
                 steps: np.ndarray, n_params: int,
                 download_bytes_per_param: float = 4.0,
                 upload_bytes_per_param: float = 4.0, seed: int = 0):
        self.cfg = cfg
        self.profile = make_profile(
            cfg.profile, n_clients, seed=seed, **cfg.profile_kwargs
        )
        self.availability = make_availability(
            cfg.availability, n_clients, seed=seed, **cfg.availability_kwargs
        )
        self.clock = RoundClock(
            self.profile,
            download_mb=n_params * download_bytes_per_param / _MB,
            upload_mb=n_params * upload_bytes_per_param / _MB,
            steps=steps,
            jitter_sigma=cfg.jitter_sigma,
            seed=seed,
        )
        # Battery ledger (ROADMAP (q)): per-client remaining charge in
        # mAh, spent by spend_energy() after each dispatch.  None when
        # tracking is off — every path below stays bit-identical then.
        self._steps = np.asarray(steps)
        self.tracks_energy = bool(cfg.track_energy)
        self.battery_mah: np.ndarray | None = (
            np.asarray(self.profile.battery_mah, np.float64).copy()
            if self.tracks_energy else None
        )
        self.energy_total_mah = 0.0

    # ------------------------------------------------------------------
    def available(self, t: int) -> np.ndarray:
        """(K,) bool online states at round ``t`` — the availability
        trace, AND a non-drained battery when energy tracking is on (a
        depleted client is unavailable through the same admission gate,
        ROADMAP (q))."""
        mask = self.availability.mask(t)
        if self.battery_mah is not None:
            mask = mask & (self.battery_mah > 0.0)
        return mask

    def times(self, t: int) -> np.ndarray:
        """(K,) simulated per-client round durations at round ``t``."""
        return self.clock.times(t)

    def arrived(self, t: int) -> np.ndarray:
        """(K,) bool — would a client's update beat the deadline this
        round?  All-true when no deadline is set.  (The fused backend
        feeds whole chunks of this into its scanned round.)"""
        if self.cfg.deadline_s is None:
            return np.ones(self.profile.n_clients, bool)
        return self.times(t) <= self.cfg.deadline_s

    def latency_hint(self) -> np.ndarray:
        """(K,) expected round seconds — the profile-derived latency
        handed to latency-aware strategies (HACCS) at setup."""
        return self.clock.base_times()

    # ------------------------------------------------------------------
    def outcome(self, t: int, sel: np.ndarray) -> RoundOutcome:
        """Deadline/availability outcome for the dispatched index list."""
        return round_outcome(
            sel, self.available(t), self.times(t), self.cfg.deadline_s
        )

    def outcome_from_mask(self, t: int, sel_mask: np.ndarray) -> RoundOutcome:
        """Same, from a (K,) participation mask (the fused scan output)."""
        return self.outcome(t, np.where(np.asarray(sel_mask, bool))[0])

    # -- energy ledger (ROADMAP (q)) -----------------------------------
    def spend_energy(self, t: int, dispatched: np.ndarray) -> dict:
        """Charge the round's dispatched-and-online clients their local
        training energy (``steps · energy_per_step`` mAh, clipped at
        empty) and return the round's energy metrics.  Spend is gated on
        the *pre-spend* availability — a client that went offline (or
        was already drained) before dispatch never ran its steps."""
        assert self.battery_mah is not None, "spend_energy without track_energy"
        sel = np.asarray(dispatched, np.int64)
        online = self.available(t)
        spenders = sel[online[sel]]
        draw = (
            self._steps[spenders]
            * np.asarray(self.profile.energy_per_step)[spenders]
        )
        spent = float(
            np.minimum(draw, self.battery_mah[spenders]).sum()
        )
        self.battery_mah[spenders] = np.maximum(
            self.battery_mah[spenders] - draw, 0.0
        )
        self.energy_total_mah += spent
        return {
            "energy_mah": spent,
            "energy_total_mah": float(self.energy_total_mah),
            "n_depleted": int((self.battery_mah <= 0.0).sum()),
        }

    # -- checkpoint contract (DESIGN.md §12) ---------------------------
    def state_dict(self) -> dict:
        """The runtime's checkpoint carry — **empty by contract**.

        This is not an omission: every systems quantity is a pure
        function of ``(seed, round)``, *including* the markov
        availability chain, which looks stateful (each round's on/off
        mask depends on the previous one) but is materialized lazily
        from its own seeded stream — ``MarkovAvailability.mask(t)``
        extends the trace from the last cached round to ``t``, and any
        prefix recomputed from scratch is bit-identical.  A freshly
        constructed runtime therefore reproduces the exact trace of the
        killed run with no carried state.

        Two things keep this sound, and both are load-bearing for the
        async runtime (DESIGN.md §13):

        - availability/time streams are indexed by the **integer
          aggregation-step index** ``t``, never by ``sim_clock`` — the
          async event clock advances ``sim_clock`` to non-integer
          arrival instants, but systems lookups stay on the step grid,
          so a resumed run re-derives the same masks/times
          (``tests/test_systems.py`` pins a resumed markov trace
          against the contiguous one);
        - the one accumulated scalar, ``engine.sim_clock``, is
          checkpointed by the engine itself in its meta.

        The hooks exist so a *genuinely* stateful runtime slots into the
        same save path — and the energy ledger (ROADMAP (q)) is exactly
        that: battery charge accumulates across rounds as a function of
        the selection history, so with ``track_energy`` on, the carry
        holds the per-client remaining mAh and the cumulative spend.
        With it off the contract above is unchanged (still ``{}``).
        """
        if self.battery_mah is None:
            return {}
        return {
            "battery_mah": [float(b) for b in self.battery_mah],
            "energy_total_mah": float(self.energy_total_mah),
        }

    def load_state_dict(self, state: dict) -> None:
        if self.battery_mah is not None:
            batt = state.get("battery_mah")
            if batt is None or len(batt) != self.battery_mah.shape[0]:
                raise ValueError(
                    f"energy-tracking run but the checkpoint carries "
                    f"{None if batt is None else len(batt)} battery "
                    f"entries, expected {self.battery_mah.shape[0]}"
                )
            self.battery_mah = np.asarray(batt, np.float64)
            self.energy_total_mah = float(state.get("energy_total_mah", 0.0))
            extra = set(state) - {"battery_mah", "energy_total_mah"}
            if extra:
                raise ValueError(
                    f"unknown systems checkpoint keys {sorted(extra)}"
                )
            return
        if state:
            raise ValueError(
                f"SystemsRuntime carries no state for this config but the "
                f"checkpoint has systems state keys {sorted(state)}"
            )
