"""``SystemsRuntime`` — the per-engine systems state the round loop
consults (DESIGN.md §10).

Built once in ``Engine.__init__`` from the validated ``SystemsConfig``
plus the engine-derived quantities (executed local steps per client,
model payload bytes, the experiment seed).  The round loop asks it
three things:

- ``available(t)``   — the (K,) availability mask at round ``t``
                       (gates the loss vector to ``-inf`` before every
                       selection call, on every backend);
- ``times(t)``       — the (K,) simulated per-client round durations;
- ``outcome(t, sel)`` / ``outcome_from_mask(t, mask)`` — the deadline
                       policy applied to the dispatched cohort: the
                       surviving participants, the drop count, and the
                       round's simulated duration.  The index and mask
                       entry points share one core, so the eager
                       backends and the fused scan unpacker account
                       rounds identically.

Everything is deterministic per (seed, round): host, compiled,
scaleout, and fused runs of one config see bit-identical availability
traces and round times.
"""

from __future__ import annotations

import numpy as np

from repro.systems.clock import RoundClock, RoundOutcome, round_outcome
from repro.systems.config import SystemsConfig
from repro.systems.profiles import make_availability, make_profile

__all__ = ["SystemsRuntime"]

_MB = 1024.0 * 1024.0


class SystemsRuntime:
    def __init__(self, cfg: SystemsConfig, *, n_clients: int,
                 steps: np.ndarray, n_params: int,
                 download_bytes_per_param: float = 4.0,
                 upload_bytes_per_param: float = 4.0, seed: int = 0):
        self.cfg = cfg
        self.profile = make_profile(
            cfg.profile, n_clients, seed=seed, **cfg.profile_kwargs
        )
        self.availability = make_availability(
            cfg.availability, n_clients, seed=seed, **cfg.availability_kwargs
        )
        self.clock = RoundClock(
            self.profile,
            download_mb=n_params * download_bytes_per_param / _MB,
            upload_mb=n_params * upload_bytes_per_param / _MB,
            steps=steps,
            jitter_sigma=cfg.jitter_sigma,
            seed=seed,
        )

    # ------------------------------------------------------------------
    def available(self, t: int) -> np.ndarray:
        """(K,) bool online states at round ``t``."""
        return self.availability.mask(t)

    def times(self, t: int) -> np.ndarray:
        """(K,) simulated per-client round durations at round ``t``."""
        return self.clock.times(t)

    def arrived(self, t: int) -> np.ndarray:
        """(K,) bool — would a client's update beat the deadline this
        round?  All-true when no deadline is set.  (The fused backend
        feeds whole chunks of this into its scanned round.)"""
        if self.cfg.deadline_s is None:
            return np.ones(self.profile.n_clients, bool)
        return self.times(t) <= self.cfg.deadline_s

    def latency_hint(self) -> np.ndarray:
        """(K,) expected round seconds — the profile-derived latency
        handed to latency-aware strategies (HACCS) at setup."""
        return self.clock.base_times()

    # ------------------------------------------------------------------
    def outcome(self, t: int, sel: np.ndarray) -> RoundOutcome:
        """Deadline/availability outcome for the dispatched index list."""
        return round_outcome(
            sel, self.available(t), self.times(t), self.cfg.deadline_s
        )

    def outcome_from_mask(self, t: int, sel_mask: np.ndarray) -> RoundOutcome:
        """Same, from a (K,) participation mask (the fused scan output)."""
        return self.outcome(t, np.where(np.asarray(sel_mask, bool))[0])

    # -- checkpoint contract (DESIGN.md §12) ---------------------------
    # The runtime holds no mutable per-round state: availability, round
    # times, and deadline outcomes are pure functions of (seed, round),
    # rebuilt identically at engine construction.  The only clock the
    # simulation accumulates is ``engine.sim_clock``, which the engine
    # checkpoints in its own meta — restoring it puts a resumed run at
    # the exact simulated wall-clock instant the saved run reached.
    # These hooks exist so a future stateful runtime (e.g. trace-driven
    # availability with a cursor) slots into the same save path.
    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, state: dict) -> None:
        if state:
            raise ValueError(
                f"SystemsRuntime is stateless but the checkpoint carries "
                f"systems state keys {sorted(state)}"
            )
