"""Public wrapper: pytree flattening + padding for the FedAvg reduce."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.aggregate.kernel import BN, aggregate_kernel

__all__ = ["masked_weighted_sum_pallas", "aggregate_pytree_pallas"]


@partial(jax.jit, static_argnames=("interpret",))
def masked_weighted_sum_pallas(stacked, weights, interpret: bool = False):
    """(M, N) stacked replicas × (M,) weights → (N,)."""
    m, n = stacked.shape
    pad = (-n) % BN
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    out = aggregate_kernel(
        stacked, jnp.asarray(weights, jnp.float32).reshape(m, 1), interpret=interpret
    )
    return out[0, :n]


def aggregate_pytree_pallas(stacked_params, weights, interpret: bool = False):
    """FedAvg over a stacked parameter pytree (leading client axis) using
    the Pallas reduce per leaf."""
    def one(leaf):
        m = leaf.shape[0]
        flat = leaf.reshape(m, -1)
        out = masked_weighted_sum_pallas(flat, weights, interpret=interpret)
        return out.reshape(leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree.map(one, stacked_params)
