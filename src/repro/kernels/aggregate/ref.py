"""Pure-jnp oracle for the aggregation kernel."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["masked_weighted_sum_ref"]


def masked_weighted_sum_ref(stacked, weights):
    """stacked (M, N), weights (M,) → (N,) = Σ_m w_m · x_m."""
    return jnp.sum(
        stacked.astype(jnp.float32) * weights.astype(jnp.float32)[:, None], axis=0
    )
