from repro.kernels.aggregate.ops import masked_weighted_sum_pallas

__all__ = ["masked_weighted_sum_pallas"]
