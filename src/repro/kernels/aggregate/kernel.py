"""Masked weighted parameter aggregation kernel — the FedAvg reduce.

θ_new[n] = Σ_m w_m · θ_m[n] over M stacked client replicas, where w
carries FedLECC's selection mask (w_m = 0 for unselected clients).  This
is bandwidth-bound: one pass over M×N parameter bytes producing N.

Tiling: grid over parameter columns; each program streams an (M, BN)
panel into VMEM, scales rows by w (SMEM-resident scalars broadcast from
a (M,1) block), reduces over M, writes a (BN,) tile.  BN = 512 fp32
keeps the panel (M·BN·4 B; M ≤ ~64 clients per aggregation wave) well
under VMEM while giving the VPU full 8×128 lanes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 512


def _agg_body(w_ref, x_ref, o_ref):
    x = x_ref[...]                           # (M, BN)
    w = w_ref[...]                           # (M, 1)
    o_ref[...] = jnp.sum(x.astype(jnp.float32) * w, axis=0, keepdims=True)


def aggregate_kernel(
    stacked: jax.Array,    # (M, N) fp32/bf16, N % BN == 0 (ops.py pads)
    weights: jax.Array,    # (M, 1) fp32
    interpret: bool = False,
) -> jax.Array:
    m, n = stacked.shape
    grid = (n // BN,)
    return pl.pallas_call(
        _agg_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, BN), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, BN), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(weights, stacked)
