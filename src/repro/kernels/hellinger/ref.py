"""Pure-jnp oracle for the Hellinger kernel (shared with repro.core)."""

from repro.core.hellinger import hellinger_matrix as hellinger_matrix_ref

__all__ = ["hellinger_matrix_ref"]
