from repro.kernels.hellinger.ops import (
    hellinger_matrix_pallas,
    hellinger_strip_pallas,
)

__all__ = ["hellinger_matrix_pallas", "hellinger_strip_pallas"]
