"""Public wrapper: normalization, sqrt prologue, padding, diagonal fix."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.hellinger.kernel import (
    BK,
    hellinger_kernel,
    hellinger_strip_kernel,
)

__all__ = ["hellinger_matrix_pallas", "hellinger_strip_pallas"]


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


@partial(jax.jit, static_argnames=("interpret",))
def hellinger_matrix_pallas(hists: jax.Array, interpret: bool = False) -> jax.Array:
    """(K, C) histograms → (K, K) Hellinger matrix via the Pallas kernel.

    Rows are normalized and sqrt'd here; K is padded to the 128 tile
    (padded rows are all-zero ⇒ BC=0 ⇒ HD=1, sliced away); C padded with
    zero classes (no effect on the inner product).
    """
    h = jnp.asarray(hists, jnp.float32)
    k = h.shape[0]
    h = h / jnp.maximum(h.sum(-1, keepdims=True), 1e-12)
    r = jnp.sqrt(h)
    r = _pad_to(_pad_to(r, BK, 0), 128, 1)
    d = hellinger_kernel(r, interpret=interpret)[:k, :k]
    return d * (1.0 - jnp.eye(k, dtype=d.dtype))


@partial(jax.jit, static_argnames=("interpret",))
def hellinger_strip_pallas(
    r_block: jax.Array, r: jax.Array, interpret: bool = False
) -> jax.Array:
    """(B, C) x (K, C) *sqrt-histogram* panels → (B, K) HD strip.

    Unlike ``hellinger_matrix_pallas`` the inputs arrive pre-normalized
    and pre-sqrt'd: the blocked driver (``core.hellinger``) prepares the
    full panel once and reuses it for every strip, so redoing the
    prologue here would multiply that cost by K/block.  Padded rows are
    sliced away; padded classes contribute nothing to the inner product.
    No diagonal fix — strips are off-diagonal in general, the caller
    assembling a square matrix owns its diagonal."""
    rb = jnp.asarray(r_block, jnp.float32)
    rf = jnp.asarray(r, jnp.float32)
    b, k = rb.shape[0], rf.shape[0]
    rb = _pad_to(_pad_to(rb, BK, 0), 128, 1)
    rf = _pad_to(_pad_to(rf, BK, 0), 128, 1)
    return hellinger_strip_kernel(rb, rf, interpret=interpret)[:b, :k]
