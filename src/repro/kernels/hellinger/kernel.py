"""Pairwise Hellinger-distance kernel.

HD(i,j) = sqrt(1 − Σ_c sqrt(p_ic) sqrt(p_jc)): with R = sqrt(P) the
Bhattacharyya matrix is R Rᵀ — one MXU matmul per (128×128) output tile
plus an elementwise epilogue.  Inputs arrive pre-normalized and
pre-sqrt'd from ops.py (the cheap elementwise prologue does not deserve
VMEM residency next to the matmul).

Tiling: grid (K/BK, K/BK); each program loads two (BK, C) row panels
into VMEM and writes one (BK, BK) tile.  BK = 128 matches the MXU;
C is padded to a multiple of 128 by ops.py (zero columns contribute
nothing to the inner product).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BK = 128


def _hellinger_tile(r_i_ref, r_j_ref, out_ref):
    ri = r_i_ref[...]                       # (BK, C) fp32
    rj = r_j_ref[...]                       # (BK, C)
    bc = jax.lax.dot_general(
        ri, rj, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (BK, BK) Bhattacharyya
    out_ref[...] = jnp.sqrt(jnp.clip(1.0 - bc, 0.0, 1.0))


def hellinger_kernel(r: jax.Array, interpret: bool = False) -> jax.Array:
    """r: (K, C) sqrt-histograms, K % BK == 0, C % 128 == 0 (ops.py pads)."""
    return hellinger_strip_kernel(r, r, interpret=interpret)


def hellinger_strip_kernel(
    rb: jax.Array, r: jax.Array, interpret: bool = False
) -> jax.Array:
    """Rectangular strip of the HD matrix: (B, C) query panel against the
    (K, C) full panel → (B, K).  The square kernel is the B = K special
    case; the blocked driver (``core.hellinger.hellinger_blocked``) feeds
    row strips here so only O(B·K) of the matrix exists on device at
    once.  B, K % BK == 0 and C % 128 == 0 (ops.py pads)."""
    b, c = rb.shape
    k = r.shape[0]
    grid = (b // BK, k // BK)
    return pl.pallas_call(
        _hellinger_tile,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BK, c), lambda i, j: (i, 0)),
            pl.BlockSpec((BK, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BK, BK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(rb, r)
