"""Flash attention (online softmax) Pallas kernel.

Grid: (batch, heads, num_q_blocks, num_kv_blocks) with the kv axis
innermost; the running max / denominator / accumulator live in VMEM
scratch and persist across the kv iterations of one q block (the
canonical TPU flash pattern).  Causal + optional sliding-window masking
is computed from iota arithmetic — no mask tensors.

Block shapes (BQ×D, BK×D, BQ×BK) are 128-aligned for the MXU; D is the
head dim (≤ 256 for every assigned arch ⇒ a (BQ+2·BK)·D working set of
~0.4 MB fp32 sits comfortably in the ~16 MB VMEM per core).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
_NEG = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale,
                window, is_global, bq, bk, nk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # (BQ, D)
    k = k_ref[0, 0]                                   # (BK, D)
    v = v_ref[0, 0]                                   # (BK, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                         # (BQ, BK)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = kpos <= qpos
    if window > 0:
        ok = ok if is_global > 0 else (ok & (qpos - kpos < window))
    s = jnp.where(ok, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,          # (B, H, S, D)
    k: jax.Array,          # (B, H, S, D)  (kv heads pre-expanded by ops.py)
    v: jax.Array,
    window: int = 0,
    is_global: float = 1.0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    nq, nk = s // bq, s // bk
    assert nq * bq == s and nk * bk == s, (s, bq, bk)
    scale = 1.0 / (d ** 0.5)
    body = functools.partial(
        _flash_body, scale=scale, window=window, is_global=float(is_global),
        bq=bq, bk=bk, nk=nk,
    )
    return pl.pallas_call(
        body,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
