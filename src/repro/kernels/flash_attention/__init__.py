from repro.kernels.flash_attention.ops import flash_attention_pallas

__all__ = ["flash_attention_pallas"]
