"""Pure-jnp oracle: materialized-softmax attention in (B,H,S,D) layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, window: int = 0, is_global: float = 1.0) -> jax.Array:
    """q/k/v (B,H,S,D); causal (+ optional sliding window)."""
    b, h, s, d = q.shape
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = kpos <= qpos
    if window > 0 and not is_global > 0:
        ok = ok & (qpos - kpos < window)
    scores = jnp.where(ok, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
