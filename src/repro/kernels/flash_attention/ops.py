"""Public wrapper: GQA expansion, layout transposition, padding."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel

__all__ = ["flash_attention_pallas"]


@partial(jax.jit, static_argnames=("window", "is_global", "bq", "bk", "interpret"))
def flash_attention_pallas(
    q: jax.Array,          # (B, S, H, D)  — model layout
    k: jax.Array,          # (B, S, KV, D)
    v: jax.Array,
    window: int = 0,
    is_global: float = 1.0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Drop-in for ``repro.models.attention.flash_attention`` on TPU.

    KV heads are expanded to H (GQA handled by repeat — the kernel sees
    MHA layout; the repeat is free on TPU as a broadcast-in-VMEM view at
    lowering time for contiguous groups).
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    o = flash_attention_kernel(
        qt, kt, vt, window=window, is_global=is_global, bq=bq, bk=bk,
        interpret=interpret,
    )
    return jnp.transpose(o, (0, 2, 1, 3))
