"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as a subpackage with three files:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, layout, dtype handling)
  ref.py    — pure-jnp oracle the kernel is tested against

Kernels (DESIGN.md §4):
  hellinger       — K×K pairwise Hellinger distance over label histograms
                    (the paper's only dense compute: sqrt-histogram matmul
                    on the MXU + elementwise epilogue)
  flash_attention — online-softmax attention (local-training hot loop)
  aggregate       — masked weighted parameter aggregation (the FedAvg
                    reduce that FedLECC's selection mask gates)

On this CPU container kernels are validated with ``interpret=True``;
the pjit scale-out path uses the pure-JAX equivalents (Pallas does not
lower to the XLA CPU backend used by the dry-run).
"""

from repro.kernels.hellinger.ops import (
    hellinger_matrix_pallas,
    hellinger_strip_pallas,
)
from repro.kernels.flash_attention.ops import flash_attention_pallas
from repro.kernels.aggregate.ops import masked_weighted_sum_pallas

__all__ = [
    "hellinger_matrix_pallas",
    "hellinger_strip_pallas",
    "flash_attention_pallas",
    "masked_weighted_sum_pallas",
]
