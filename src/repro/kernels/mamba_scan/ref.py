"""Pure-jnp oracle: the naive recurrence over discretized coefficients."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["mamba_scan_ref"]


def mamba_scan_ref(x, dt, bmat, cmat, a_log, d_skip):
    """x/dt (B,S,D), bmat/cmat (B,S,N), a_log (D,N), d_skip (D,) → y (B,S,D)."""
    a_cont = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        a_t = jnp.exp(dt_t[..., None] * a_cont)                  # (B,D,N)
        h = a_t * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.sum(h * c_t[:, None, :], axis=-1)
        return h, y_t

    b, s, d = x.shape
    n = bmat.shape[-1]
    h0 = jnp.zeros((b, d, n), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(bmat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(cmat.astype(jnp.float32), 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * d_skip
    return y.astype(x.dtype)
