"""Selective-scan (Mamba S6) Pallas kernel.

The XLA path materializes the (S, D, N) discretized coefficients and the
scan states in HBM (§Perf hillclimb 2: even after chunk-fusing the C
contraction, traffic is ~O(S·D·N)).  GPU Mamba solves this with a fused
CUDA kernel; the TPU-native equivalent keeps the running state h (D_blk,
N) in VMEM scratch across sequential time blocks and streams only the
O(S·D) inputs/outputs through HBM — an ~N× traffic reduction
(N = 16 for the assigned hymba config).

Grid: (batch, D blocks, time blocks), time innermost — scratch h
persists across the time iterations of one (b, d-block) program.
Per time block the kernel:
  1. discretizes: a = exp(dt·A), drive = dt·(x·Bt)      (VPU elementwise)
  2. runs the T-step recurrence with a fori_loop over rows in VMEM
  3. contracts with C on the fly: y[t] = h_t · C_t + D·x[t]

Block shapes: (BT, BD) with BD a lane multiple (128) and N ≤ 16 keeps
the h scratch (BD × N fp32 = 8 KB) and the (BT, BD, N) temporaries
within VMEM for BT = 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 128
DEFAULT_BD = 128


def _scan_body(x_ref, dt_ref, bmat_ref, cmat_ref, a_log_ref, dskip_ref,
               y_ref, h_ref, *, bt, bd, n, nt):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)           # (BT, BD)
    dt = dt_ref[0].astype(jnp.float32)         # (BT, BD)
    bmat = bmat_ref[0].astype(jnp.float32)     # (BT, N)
    cmat = cmat_ref[0].astype(jnp.float32)     # (BT, N)
    a_cont = -jnp.exp(a_log_ref[...])          # (BD, N)

    def step(t, carry):
        h, y = carry
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]      # (BD,)
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)[0]
        b_t = jax.lax.dynamic_slice_in_dim(bmat, t, 1, 0)[0]     # (N,)
        c_t = jax.lax.dynamic_slice_in_dim(cmat, t, 1, 0)[0]
        a_t = jnp.exp(dt_t[:, None] * a_cont)                    # (BD, N)
        h = a_t * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)                  # (BD,)
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t[None], t, 0)
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros((bt, bd), jnp.float32)
    h_fin, y = jax.lax.fori_loop(0, bt, step, (h0, y0))
    h_ref[...] = h_fin
    y_ref[0] = (y + x * dskip_ref[...][None, :]).astype(y_ref.dtype)


def mamba_scan_kernel(
    x: jax.Array,        # (B, S, D) post-conv, post-silu inputs
    dt: jax.Array,       # (B, S, D) softplus'd step sizes
    bmat: jax.Array,     # (B, S, N)
    cmat: jax.Array,     # (B, S, N)
    a_log: jax.Array,    # (D, N)
    d_skip: jax.Array,   # (D,)
    bt: int = DEFAULT_BT,
    bd: int = DEFAULT_BD,
    interpret: bool = False,
) -> jax.Array:
    b, s, d = x.shape
    n = bmat.shape[-1]
    bt = min(bt, s)
    bd = min(bd, d)
    nt, nd = s // bt, d // bd
    assert nt * bt == s and nd * bd == d, (s, d, bt, bd)
    body = functools.partial(_scan_body, bt=bt, bd=bd, n=n, nt=nt)
    return pl.pallas_call(
        body,
        grid=(b, nd, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b_, j, it: (b_, it, j)),
            pl.BlockSpec((1, bt, bd), lambda b_, j, it: (b_, it, j)),
            pl.BlockSpec((1, bt, n), lambda b_, j, it: (b_, it, 0)),
            pl.BlockSpec((1, bt, n), lambda b_, j, it: (b_, it, 0)),
            pl.BlockSpec((bd, n), lambda b_, j, it: (j, 0)),
            pl.BlockSpec((bd,), lambda b_, j, it: (j,)),
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda b_, j, it: (b_, it, j)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, bmat, cmat, a_log, d_skip)
