"""Public wrapper: padding + dtype handling for the selective-scan kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.kernel import mamba_scan_kernel

__all__ = ["mamba_scan_pallas"]


@partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def mamba_scan_pallas(x, dt, bmat, cmat, a_log, d_skip,
                      bt: int = 128, bd: int = 128, interpret: bool = False):
    """Fused selective scan: y[t] = C_t·h_t + D·x[t], h_t = Ā_t h_{t−1} + ΔB_t x_t.

    Pads S to the time block and D to the lane block; padded time steps
    have dt=0 ⇒ a=1, drive=0 (state passes through unchanged), padded
    channels are sliced away.
    """
    b, s, d = x.shape
    bt_ = min(bt, s)
    pad_s = (-s) % bt_
    pad_d = (-d) % min(bd, d)
    if pad_s or pad_d:
        pads3 = ((0, 0), (0, pad_s), (0, pad_d))
        x = jnp.pad(x, pads3)
        dt = jnp.pad(dt, pads3)
        bmat = jnp.pad(bmat, ((0, 0), (0, pad_s), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad_s), (0, 0)))
        a_log = jnp.pad(a_log, ((0, pad_d), (0, 0)))
        d_skip = jnp.pad(d_skip, ((0, pad_d),))
    y = mamba_scan_kernel(x, dt, bmat, cmat, a_log, d_skip,
                          bt=bt, bd=bd, interpret=interpret)
    return y[:, :s, :d]
