from repro.kernels.mamba_scan.ops import mamba_scan_pallas

__all__ = ["mamba_scan_pallas"]
