"""Deprecated entry point — the simulation moved to ``repro.engine``.

``FederatedSimulation`` is now a thin shim over
``repro.engine.host.HostEngine``: same constructor, same attributes
(``params``, ``strategy``, ``comm``, ``history``, ...), and ``run()``
returns the same history dict — but the round loop, the streaming
``rounds()`` iterator, and the strategy/aggregator/client-mode dispatch
all live in ``repro.engine``.  New code should use::

    from repro.engine import FLConfig, make_engine

    engine = make_engine(FLConfig(backend="host", ...), train, test, n_classes)
    for result in engine.rounds():   # RoundResult stream
        ...

``FLConfig`` and ``rounds_to_accuracy`` are re-exported here for
backward compatibility.
"""

from __future__ import annotations

import warnings

from repro.engine.base import rounds_to_accuracy
from repro.engine.config import FLConfig
from repro.engine.host import HostEngine

__all__ = ["FLConfig", "FederatedSimulation", "rounds_to_accuracy"]


class FederatedSimulation(HostEngine):
    """Deprecated alias of :class:`repro.engine.host.HostEngine`."""

    def __init__(self, cfg: FLConfig, train, test, n_classes: int):
        warnings.warn(
            "FederatedSimulation is deprecated; use repro.engine.make_engine"
            " (engine.rounds() streams RoundResult records; engine.run()"
            " returns the same history dict)",
            DeprecationWarning,
            stacklevel=2,
        )
        super().__init__(cfg, train, test, n_classes)
