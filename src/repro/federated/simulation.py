"""Paper-faithful K-client federated simulation (§V protocol).

One ``FederatedSimulation`` = one experimental cell of Table II/III:
dataset partitioned Dirichlet(alpha) across K clients, MLP trained with
SGD(lr, B=64), a selection strategy picking m clients per round, an
aggregation rule, and the communication ledger running alongside.

Client local training is vmapped over the selected cohort inside one jit
(see ``repro.federated.client``); the selection itself is host-side
numpy (K scalars/round — DESIGN.md §8.5).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm_model import CommModel, count_params
from repro.core.strategies import get_strategy
from repro.data.partition import (
    calibrate_alpha,
    dirichlet_partition,
    label_histograms,
    pack_clients,
)
from repro.data.synthetic import Dataset
from repro.federated.aggregation import fedavg, feddyn_server, feddyn_update_h, fednova
from repro.federated.client import local_train
from repro.models.mlp import accuracy, cross_entropy_loss, init_mlp, mlp_apply
from repro.optim.fedmods import feddyn_update_state

__all__ = ["FLConfig", "FederatedSimulation", "rounds_to_accuracy"]


@dataclass
class FLConfig:
    n_clients: int = 100
    m: int = 10                    # participants per round
    rounds: int = 150
    local_epochs: int = 1
    batch_size: int = 64
    lr: float = 0.005              # paper: SGD lr=0.005
    strategy: str = "fedlecc"
    strategy_kwargs: dict = field(default_factory=dict)
    aggregator: str = "fedavg"     # fedavg | fednova | feddyn
    client_mode: str = "plain"     # plain | fedprox | feddyn
    mu: float = 0.0                # fedprox mu / feddyn alpha
    partition: str = "shards"      # shards | dirichlet (see partition.py:
                                   # shards = the paper's balanced severe-
                                   # skew regime; dirichlet at matched HD
                                   # degenerates into stub clients)
    alpha_dirichlet: float | None = None   # dirichlet: None → calibrate
    target_hd: float = 0.9
    eval_samples: int = 128        # per-client loss-poll subsample
    max_steps_cap: int = 50
    eval_every: int = 5
    seed: int = 0
    hidden: tuple[int, ...] = (200, 200)   # paper MLP


class FederatedSimulation:
    def __init__(
        self,
        cfg: FLConfig,
        train: Dataset,
        test: Dataset,
        n_classes: int,
    ):
        self.cfg = cfg
        self.n_classes = n_classes
        rng = np.random.default_rng(cfg.seed)
        self.rng = rng

        # --- non-IID partition (calibrated to the paper's HD regime) ---
        if cfg.partition == "shards":
            from repro.data.partition import calibrate_shards, shard_partition

            s = calibrate_shards(train.y, cfg.n_clients, cfg.target_hd,
                                 n_classes, seed=cfg.seed)
            self.alpha = float(s)  # records shards/client in the alpha slot
            self.client_idx = shard_partition(
                train.y, cfg.n_clients, s, seed=cfg.seed
            )
        else:
            alpha = cfg.alpha_dirichlet
            if alpha is None:
                alpha = calibrate_alpha(
                    train.y, cfg.n_clients, cfg.target_hd, n_classes, seed=cfg.seed
                )
            self.alpha = float(alpha)
            self.client_idx = dirichlet_partition(
                train.y, cfg.n_clients, self.alpha, seed=cfg.seed
            )
        self.hists = label_histograms(train.y, self.client_idx, n_classes)
        xs, ys, mask = pack_clients(train.x, train.y, self.client_idx)
        self.xs, self.ys, self.mask = jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask)
        self.sizes = np.array([len(ix) for ix in self.client_idx])
        self.test_x, self.test_y = jnp.asarray(test.x), jnp.asarray(test.y)

        # --- model / optimizer-free local SGD ---
        feat = train.x.shape[1]
        self.params = init_mlp(
            jax.random.PRNGKey(cfg.seed), (feat, *cfg.hidden, n_classes)
        )
        self.n_params = count_params(self.params)

        # --- local step budgets (heterogeneous → FedNova is meaningful) ---
        taus = np.ceil(self.sizes * cfg.local_epochs / cfg.batch_size).astype(np.int32)
        self.taus = np.maximum(taus, 1)
        self.max_steps = int(min(cfg.max_steps_cap, self.taus.max()))

        # --- selection strategy + comm ledger ---
        self.strategy = get_strategy(cfg.strategy, m=cfg.m, **cfg.strategy_kwargs)
        self.strategy.setup(self.hists, self.sizes, seed=cfg.seed)
        self.comm = CommModel(self.n_params, cfg.n_clients, n_classes)
        self.comm_mb = self.comm.one_time_mb(self.strategy.needs_histograms)

        # --- FedDyn state ---
        if cfg.aggregator == "feddyn" or cfg.client_mode == "feddyn":
            zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), self.params)
            self.h_server = zeros
            self.h_clients = jax.tree.map(
                lambda p: jnp.zeros((cfg.n_clients,) + p.shape, jnp.float32), self.params
            )
        else:
            self.h_server = self.h_clients = None

        self._build_jits()
        self.history: dict[str, list] = {
            "round": [], "test_acc": [], "test_loss": [], "comm_mb": [],
            "mean_selected_loss": [], "selected": [],
        }

    # ------------------------------------------------------------------
    def _build_jits(self):
        cfg = self.cfg
        apply_fn, loss_fn = mlp_apply, cross_entropy_loss

        def _one_client(global_params, x, y, mask, tau, key, h):
            return local_train(
                apply_fn, loss_fn, global_params, x, y, mask, tau, key,
                lr=cfg.lr, max_steps=self.max_steps, batch_size=cfg.batch_size,
                mode=cfg.client_mode, mu=cfg.mu, h_state=h,
            )

        h_ax = 0 if self.h_clients is not None else None
        self._round_train = jax.jit(
            jax.vmap(_one_client, in_axes=(None, 0, 0, 0, 0, 0, h_ax))
        )

        def _poll_losses(params, xs, ys, mask, key):
            """Subsampled local empirical loss of the *global* model on
            every client (Algorithm 1 lines 2–4)."""

            def one(x, y, m, k):
                n = x.shape[0]
                p = m / jnp.maximum(m.sum(), 1e-9)
                idx = jax.random.choice(k, n, shape=(cfg.eval_samples,), p=p)
                logits = apply_fn(params, jnp.take(x, idx, axis=0))
                return loss_fn(logits, jnp.take(y, idx, axis=0), None)

            keys = jax.random.split(key, xs.shape[0])
            return jax.vmap(one)(xs, ys, mask, keys)

        self._poll_losses = jax.jit(_poll_losses)

        def _evaluate(params, x, y):
            logits = apply_fn(params, x)
            return loss_fn(logits, y, None), accuracy(logits, y)

        self._evaluate = jax.jit(_evaluate)

    # ------------------------------------------------------------------
    def run(self, rounds: int | None = None, log_every: int = 0) -> dict[str, list]:
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        key = jax.random.PRNGKey(cfg.seed + 17)

        for rnd in range(rounds):
            key, k_poll, k_train = jax.random.split(key, 3)

            # (1) loss poll — only if the strategy needs it (comm-accounted)
            if self.strategy.needs_losses:
                losses = np.asarray(
                    self._poll_losses(self.params, self.xs, self.ys, self.mask, k_poll)
                )
            else:
                losses = np.zeros(cfg.n_clients, np.float32)

            # (2) select participants
            sel = self.strategy.select(rnd, losses, self.rng)
            sel_j = jnp.asarray(sel)

            # (3) local training on the selected cohort
            keys = jax.random.split(k_train, len(sel))
            h_sel = (
                jax.tree.map(lambda a: a[sel_j], self.h_clients)
                if self.h_clients is not None
                else None
            )
            stacked, local_losses = self._round_train(
                self.params,
                self.xs[sel_j], self.ys[sel_j], self.mask[sel_j],
                jnp.asarray(self.taus[sel]), keys, h_sel,
            )

            # (4) aggregate
            w = self.sizes[sel] / self.sizes[sel].sum()
            w_j = jnp.asarray(w, jnp.float32)
            if cfg.aggregator == "fedavg":
                self.params = fedavg(stacked, w_j)
            elif cfg.aggregator == "fednova":
                self.params = fednova(
                    stacked, self.params, w_j, jnp.asarray(self.taus[sel], jnp.float32)
                )
            elif cfg.aggregator == "feddyn":
                new_theta, mean_params = feddyn_server(
                    stacked, w_j, self.h_server, cfg.mu, len(sel) / cfg.n_clients
                )
                self.h_server = feddyn_update_h(
                    self.h_server, mean_params, self.params, cfg.mu,
                    len(sel) / cfg.n_clients,
                )
                self.params = new_theta
            else:
                raise ValueError(f"unknown aggregator {cfg.aggregator!r}")

            # FedDyn per-client correction state
            if cfg.client_mode == "feddyn":
                h_new = jax.vmap(
                    lambda h, p: feddyn_update_state(h, p, self.params, cfg.mu),
                    in_axes=(0, 0),
                )(h_sel, stacked)
                self.h_clients = jax.tree.map(
                    lambda all_, new: all_.at[sel_j].set(new), self.h_clients, h_new
                )

            # (5) ledger + periodic eval
            self.comm_mb += self.comm.round_mb(len(sel), self.strategy.needs_losses)
            if rnd % cfg.eval_every == 0 or rnd == rounds - 1:
                tl, ta = self._evaluate(self.params, self.test_x, self.test_y)
                self.history["round"].append(rnd)
                self.history["test_acc"].append(float(ta))
                self.history["test_loss"].append(float(tl))
                self.history["comm_mb"].append(float(self.comm_mb))
                self.history["mean_selected_loss"].append(float(jnp.mean(local_losses)))
                self.history["selected"].append(sel.tolist())
                if log_every and (rnd % log_every == 0):
                    print(
                        f"[{cfg.strategy}] round {rnd:4d} "
                        f"acc={float(ta):.4f} loss={float(tl):.4f} "
                        f"comm={self.comm_mb:.1f}MB"
                    )
        return self.history


def rounds_to_accuracy(history: dict[str, list], target: float) -> int | None:
    """First evaluated round reaching ``target`` test accuracy (Fig 3 / the
    paper's −22%-rounds claim); None if never reached."""
    for rnd, acc in zip(history["round"], history["test_acc"]):
        if acc >= target:
            return rnd
    return None
