"""Federated runtime.

- ``aggregation`` — FedAvg / FedNova / FedDyn server rules over pytrees
- ``client``      — jit/vmap-able local training (SGD minibatch loop with
                    FedProx/FedDyn gradient modifiers)
- ``simulation``  — the paper-faithful K-client simulation (selection
                    strategies from ``repro.core`` plugged in per round)
- ``scaleout``    — mesh-collective federated round for the large
                    architectures (selection mask gates the client-axis
                    all-reduce; see DESIGN.md §3b)
"""

from repro.federated.simulation import FLConfig, FederatedSimulation

__all__ = ["FLConfig", "FederatedSimulation"]
