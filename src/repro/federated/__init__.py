"""Federated runtime.

- ``aggregation`` — FedAvg / FedNova / FedDyn server rules over pytrees
                    (wrapped as stateful objects in ``repro.engine.aggregators``)
- ``client``      — jit/vmap-able local training (SGD minibatch loop with
                    gradient modifiers from the engine client-mode registry)
- ``simulation``  — deprecated shim: ``FederatedSimulation`` →
                    ``repro.engine.host.HostEngine``
- ``scaleout``    — mesh-collective federated round for the large
                    architectures (selection mask gates the client-axis
                    all-reduce; see DESIGN.md §3b); engine entry points:
                    ``repro.engine.scaleout.ScaleoutEngine`` (the round
                    protocol) and
                    ``repro.engine.scaleout.make_scaleout_round``

``FLConfig`` / ``FederatedSimulation`` are lazy re-exports (PEP 562) so
importing a submodule such as ``repro.federated.client`` never pulls in
the full engine stack (and the engine can import submodules here without
a cycle).
"""

__all__ = ["FLConfig", "FederatedSimulation"]


def __getattr__(name):
    if name in __all__:
        from repro.federated import simulation

        return getattr(simulation, name)
    raise AttributeError(f"module 'repro.federated' has no attribute {name!r}")
