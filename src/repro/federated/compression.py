"""Update compression for the aggregation path (beyond-paper, §Perf).

The paper cuts communication by selecting fewer clients; the bytes *per
selected client* are untouched (fp32 model up/down).  This module adds
the orthogonal axis: per-tensor-scaled int8 quantization of client
*deltas* (θ_local − θ_global), with stochastic rounding so the
quantization error is zero-mean across clients and rounds.

In the scale-out regime this shrinks the client-axis all-reduce bytes
4× (fp32) / 2× (bf16); in the cross-device accounting of Table III it
multiplies the per-round model traffic by ~1/4.  Error feedback (EF21-
style residual carry) is provided for the aggressive settings.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_delta", "dequantize_delta", "compressed_fedavg",
           "bytes_per_param"]


class QuantizedTree(NamedTuple):
    q: object        # int8 pytree
    scale: object    # fp32 per-leaf scalar pytree


def bytes_per_param(bits: int = 8) -> float:
    return bits / 8.0


def quantize_delta(delta, key, bits: int = 8) -> QuantizedTree:
    """Per-leaf symmetric quantization with stochastic rounding."""
    qmax = 2 ** (bits - 1) - 1
    leaves, treedef = jax.tree.flatten(delta)
    keys = jax.random.split(key, len(leaves))

    def one(leaf, k):
        x = leaf.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
        y = x / scale
        lo = jnp.floor(y)
        p = y - lo
        rnd = (jax.random.uniform(k, y.shape) < p).astype(jnp.float32)
        q = jnp.clip(lo + rnd, -qmax - 1, qmax).astype(jnp.int8)
        return q, scale

    qs, scales = zip(*(one(l, k) for l, k in zip(leaves, keys)))
    return QuantizedTree(
        q=jax.tree.unflatten(treedef, qs),
        scale=jax.tree.unflatten(treedef, scales),
    )


def dequantize_delta(qt: QuantizedTree):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, qt.q, qt.scale
    )


def compressed_fedavg(stacked_params, global_params, weights, key, bits: int = 8):
    """FedAvg where each client's delta is int8-quantized before the
    weighted reduce: θ ← θ_g + Σ_i w_i · deq(quant(θ_i − θ_g)).

    ``stacked_params`` leaves carry a leading client axis.  Returns
    (new_params, mean_abs_quant_error) — the error metric feeds the
    §Perf log.
    """
    w = jnp.asarray(weights, jnp.float32)
    n = w.shape[0]
    keys = jax.random.split(key, n)

    def one(stacked_leaf, g_leaf):
        deltas = stacked_leaf.astype(jnp.float32) - g_leaf.astype(jnp.float32)[None]
        qmax = 2 ** (bits - 1) - 1

        def quant_one(d, k):
            scale = jnp.maximum(jnp.max(jnp.abs(d)), 1e-12) / qmax
            y = d / scale
            lo = jnp.floor(y)
            rnd = (jax.random.uniform(k, y.shape) < (y - lo)).astype(jnp.float32)
            q = jnp.clip(lo + rnd, -qmax - 1, qmax)
            return q * scale

        deq = jax.vmap(quant_one)(deltas, keys)
        err = jnp.mean(jnp.abs(deq - deltas))
        wexp = w.reshape((-1,) + (1,) * (deltas.ndim - 1))
        agg = jnp.sum(deq * wexp, axis=0)
        return (g_leaf.astype(jnp.float32) + agg).astype(g_leaf.dtype), err

    outs = jax.tree.map(one, stacked_params, global_params)
    new = jax.tree.map(lambda o: o[0], outs, is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.leaves(
        jax.tree.map(lambda o: o[1], outs, is_leaf=lambda x: isinstance(x, tuple))
    )
    return new, jnp.mean(jnp.stack(errs))
