"""Scale-out federated round: FedLECC on the production mesh.

The paper's cross-device loop maps onto the multi-pod mesh as (DESIGN.md
§3b):

- **clients ↔ pods** — the ``pod`` mesh axis is *manual* (shard_map), so
  each pod's parameter replica evolves independently during local steps;
- ``data``/``model`` stay *auto* inside the body — GSPMD runs ordinary
  data/tensor parallelism within each client;
- **aggregation ≡ weighted psum over ``pod``** — the FedLECC selection
  mask enters as the per-client weight vector (0 = not selected), so
  "only m of K clients upload" becomes "the all-reduce carries zero
  weight for unselected clients";
- each client reports its local loss, feeding the next round's
  host-side Algorithm 1.

``make_federated_round`` builds the jit-able round; the dry-run lowers it
as the paper-representative artifact.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.jax_compat import shard_map

from repro.models.transformer import loss_fn

__all__ = ["make_federated_round", "stack_for_clients"]


def stack_for_clients(params, n_clients: int):
    """Replicate global params into per-client stacks (leading axis)."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (n_clients,) + p.shape), params)


def make_federated_round(cfg, mesh, lr: float, local_steps: int = 4,
                         compress_bits: int = 0):
    """Returns ``round_fn(stacked_params, batch, weights) ->
    (new_stacked_params, client_losses)``.

    stacked_params: per-client parameter stacks, leading axis = n_pods,
        sharded P("pod", ...).
    batch: leaves with leading client axis, e.g. tokens
        (n_pods, B_loc, S) sharded P("pod", "data", None).
    weights: (n_pods,) fp32 — FedLECC aggregation weights (sum to 1;
        zero = client not selected this round).
    compress_bits: 0 = exact fp32 psum of weighted params (baseline);
        8 = §Perf hillclimb 3: each client's *delta* is int8-quantized
        (per-leaf scale, deterministic round-to-nearest inside the
        compiled round) and aggregation becomes an int8 all-gather over
        the client axis + local weighted dequant-sum — 8× fewer bytes on
        the pod interconnect than the fp32 ring all-reduce.
    """
    n_pods = mesh.shape["pod"]

    def local_sgd(params, batch):
        def step(p, _):
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, cfg, batch, None)
            p = jax.tree.map(lambda w, gw: (w - lr * gw).astype(w.dtype), p, g)
            return p, l

        params, losses = jax.lax.scan(step, params, None, length=local_steps)
        return params, losses.mean()

    def body(stacked_params, batch, weights):
        # local (manual-over-pod) views carry a leading axis of size 1
        params = jax.tree.map(lambda a: a[0], stacked_params)
        local_batch = jax.tree.map(lambda a: a[0], batch)
        w = weights[0]
        params_end, mean_loss = local_sgd(params, local_batch)
        # FedAvg with the FedLECC participation mask: θ ← Σ_i w_i θ_i.
        # Unselected clients (w=0) contribute nothing but still receive
        # the aggregated model (the psum result is replicated over pod).
        agg = jax.tree.map(
            lambda p: jax.lax.psum((w * p.astype(jnp.float32)), "pod").astype(p.dtype),
            params_end,
        )
        losses = jax.lax.all_gather(mean_loss, "pod")
        return jax.tree.map(lambda a: a[None], agg), losses

    def train_body(stacked_params, batch, weights):
        """Compressed variant: local training only; aggregation happens in
        a second, manual-over-{pod,model} shard_map (quantize_agg) so the
        int8 all-gather moves exactly the per-device shard — GSPMD cannot
        replicate the operand first (§Perf hillclimb 3, iteration 2)."""
        params = jax.tree.map(lambda a: a[0], stacked_params)
        local_batch = jax.tree.map(lambda a: a[0], batch)
        params_end, mean_loss = local_sgd(params, local_batch)
        losses = jax.lax.all_gather(mean_loss, "pod")
        return jax.tree.map(lambda a: a[None], params_end), losses

    qmax = 2 ** (compress_bits - 1) - 1 if compress_bits else 0

    def agg_body(stacked_end, stacked_start, weights):
        p_end = jax.tree.map(lambda a: a[0], stacked_end)
        p_start = jax.tree.map(lambda a: a[0], stacked_start)
        w = weights[0]

        def one(e, s0):
            delta = e.astype(jnp.float32) - s0.astype(jnp.float32)
            # per-shard scale: cheap, local, and finer-grained than a
            # global per-leaf scale (documented algorithm variant)
            scale = jnp.maximum(jnp.max(jnp.abs(delta)), 1e-12) / qmax
            q = jnp.clip(jnp.round(delta / scale), -qmax - 1, qmax).astype(jnp.int8)
            q_all = jax.lax.all_gather(q, "pod")              # int8 on the wire
            s_all = jax.lax.all_gather(scale * w, "pod")      # (n_pods,) fp32
            wexp = s_all.reshape((-1,) + (1,) * delta.ndim)
            agg_delta = jnp.sum(q_all.astype(jnp.float32) * wexp, axis=0)
            return (s0.astype(jnp.float32) + agg_delta).astype(e.dtype)

        agg = jax.tree.map(one, p_end, p_start)
        return jax.tree.map(lambda a: a[None], agg)

    def round_fn(stacked_params, batch, weights):
        p_specs = jax.tree.map(lambda _: P("pod"), stacked_params)
        b_specs = jax.tree.map(lambda _: P("pod"), batch)
        if not compress_bits:
            f = shard_map(
                body,
                mesh=mesh,
                in_specs=(p_specs, b_specs, P("pod")),
                out_specs=(p_specs, P()),
                axis_names={"pod"},
                check_vma=False,
            )
            return f(stacked_params, batch, weights)
        # compressed: train (manual pod, auto data/model), then aggregate
        # (manual pod+model: per-shard int8 quantize + gather + sum)
        f_train = shard_map(
            train_body,
            mesh=mesh,
            in_specs=(p_specs, b_specs, P("pod")),
            out_specs=(p_specs, P()),
            axis_names={"pod"},
            check_vma=False,
        )
        ends, losses = f_train(stacked_params, batch, weights)
        # manual specs for the aggregation: leading pod axis + the storage
        # sharding of every leaf (so shards stay local through the gather)
        from repro.models.transformer import transformer_specs
        from repro.sharding import make_policy

        policy = make_policy(mesh, batch_size=0)
        pspecs_logical = transformer_specs(cfg)
        def is_axes(x):
            return isinstance(x, tuple) and all(
                isinstance(e, (str, tuple, type(None))) for e in x
            )

        flat_l = jax.tree.leaves(pspecs_logical, is_leaf=is_axes)
        flat_p = jax.tree.leaves(stacked_params)
        specs = [
            P("pod", *policy.spec_for(sp, leaf.shape[1:]))
            for sp, leaf in zip(flat_l, flat_p)
        ]
        mspecs = jax.tree.unflatten(jax.tree.structure(stacked_params), specs)
        f_agg = shard_map(
            agg_body,
            mesh=mesh,
            in_specs=(mspecs, mspecs, P("pod")),
            out_specs=mspecs,
            axis_names={"pod", "model"},
            check_vma=False,
        )
        new_stacked = f_agg(ends, stacked_params, weights)
        return new_stacked, losses

    return round_fn
