"""Server-side aggregation rules over parameter pytrees.

All rules consume a *stacked* pytree of client results (leading axis =
participating clients) plus normalized weights, so the same code path
serves the vmapped simulation and — via psum instead of a stacked sum —
the scale-out mesh round.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "fedavg",
    "fednova",
    "feddyn_server",
    "weighted_delta",
    "trimmed_mean",
    "coordinate_median",
]


def _wsum(stacked, weights):
    """Σ_i w_i · leaf_i along the leading (client) axis."""
    w = jnp.asarray(weights, jnp.float32)

    def one(leaf):
        wexp = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(leaf.astype(jnp.float32) * wexp, axis=0).astype(leaf.dtype)

    return jax.tree.map(one, stacked)


def fedavg(stacked_params, weights):
    """θ ← Σ_i w_i θ_i  (weights normalized ∝ N_i over the selected set)."""
    return _wsum(stacked_params, weights)


def weighted_delta(stacked_params, global_params, weights):
    """Σ_i w_i (θ_i − θ_g) — the update FedAvg applies, exposed separately
    because the scale-out round all-reduces deltas, not params."""
    deltas = jax.tree.map(
        lambda s, g: s - g[None].astype(s.dtype), stacked_params, global_params
    )
    return _wsum(deltas, weights)


def fednova(stacked_params, global_params, weights, taus):
    """FedNova (Wang et al., 2021): normalize each client's delta by its
    local step count τ_i, then scale by τ_eff = Σ w_i τ_i."""
    w = jnp.asarray(weights, jnp.float32)
    taus = jnp.asarray(taus, jnp.float32)
    tau_eff = jnp.sum(w * taus)

    def one(s, g):
        delta = s.astype(jnp.float32) - g[None].astype(jnp.float32)
        t = taus.reshape((-1,) + (1,) * (delta.ndim - 1))
        wexp = w.reshape((-1,) + (1,) * (delta.ndim - 1))
        d = jnp.sum(wexp * delta / jnp.maximum(t, 1.0), axis=0)
        return (g.astype(jnp.float32) + tau_eff * d).astype(g.dtype)

    return jax.tree.map(one, stacked_params, global_params)


def feddyn_server(stacked_params, weights, h_server, alpha: float, frac_participating: float):
    """FedDyn server rule (Acar et al., 2021):

        h ← h − α · (participation fraction) · (mean_S θ_i − θ_g)   [folded
            into the h passed in by the caller via client deltas]
        θ ← mean_S θ_i − h / α

    We use the common simplification: h accumulates −α·Δ̄ each round where
    Δ̄ is the weighted mean client delta w.r.t. the previous global params.
    """
    mean_params = _wsum(stacked_params, weights)
    theta = jax.tree.map(
        lambda mp, h: (mp.astype(jnp.float32) - h / alpha).astype(mp.dtype),
        mean_params,
        h_server,
    )
    return theta, mean_params


def trimmed_mean(stacked_params, weights, trim_frac: float):
    """Coordinate-wise β-trimmed weighted mean (Yin et al., 2018).

    Participants are the rows with ``weights > 0`` — the same zero-weight
    gating both backends already use — so the function accepts either the
    host cohort stack or the compiled all-K mask-gated stack unchanged.
    Per coordinate, the ``floor(trim_frac · n)`` largest and smallest
    participant values are dropped and the survivors averaged with
    renormalized weights; ``trim_frac = 0`` reduces to ``fedavg`` (up to
    summation order).  All index arithmetic is traced (static shapes),
    so the rule jits without retracing per cohort composition.
    """
    w = jnp.asarray(weights, jnp.float32)
    valid = w > 0
    nv = jnp.sum(valid.astype(jnp.int32))
    k = jnp.floor(jnp.float32(trim_frac) * nv.astype(jnp.float32)).astype(jnp.int32)

    def one(leaf):
        rows = leaf.shape[0]
        x = leaf.astype(jnp.float32).reshape(rows, -1)
        key = jnp.where(valid[:, None], x, jnp.inf)  # non-participants last
        order = jnp.argsort(key, axis=0)
        xs = jnp.take_along_axis(x, order, axis=0)
        ws = jnp.take_along_axis(jnp.broadcast_to(w[:, None], x.shape), order, axis=0)
        pos = jnp.arange(rows, dtype=jnp.int32)[:, None]
        keep = (pos >= k) & (pos < nv - k)
        wk = jnp.where(keep, ws, 0.0)
        num = jnp.sum(jnp.where(keep, xs * ws, 0.0), axis=0)
        den = jnp.maximum(jnp.sum(wk, axis=0), 1e-12)
        return (num / den).astype(leaf.dtype).reshape(leaf.shape[1:])

    return jax.tree.map(one, stacked_params)


def coordinate_median(stacked_params, weights):
    """Coordinate-wise (unweighted) median over participants — rows with
    ``weights > 0`` (Yin et al., 2018).  Even participant counts average
    the two middle order statistics; the gather indices are traced
    scalars so cohort composition never retraces."""
    w = jnp.asarray(weights, jnp.float32)
    valid = w > 0
    nv = jnp.sum(valid.astype(jnp.int32))
    lo, hi = (nv - 1) // 2, nv // 2

    def one(leaf):
        rows = leaf.shape[0]
        x = leaf.astype(jnp.float32).reshape(rows, -1)
        xs = jnp.sort(jnp.where(valid[:, None], x, jnp.inf), axis=0)
        med = 0.5 * (jnp.take(xs, lo, axis=0) + jnp.take(xs, hi, axis=0))
        return med.astype(leaf.dtype).reshape(leaf.shape[1:])

    return jax.tree.map(one, stacked_params)


def feddyn_update_h(h_server, mean_params, global_params, alpha: float, frac: float):
    return jax.tree.map(
        lambda h, mp, g: h - alpha * frac * (mp.astype(jnp.float32) - g.astype(jnp.float32)),
        h_server,
        mean_params,
        global_params,
    )
