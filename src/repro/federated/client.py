"""Client-side local training — jit/vmap-able, task-agnostic.

``local_train`` runs ``max_steps`` minibatch-SGD steps on one client's
(masked, padded) data, sampling batch (row) indices from the valid
region with replacement inside the scan (statistically equivalent to
shuffled epochs for the paper's regime; lets every client share one
static step count).  Clients whose true step budget τ_i < max_steps
freeze after τ_i steps (``jnp.where`` gating), which is what makes
FedNova's τ-normalization meaningful under heterogeneous dataset sizes.

The workload enters only through the task's ``(apply_fn, loss_fn)``
pair (``repro.engine.tasks``) with the composition contract
``loss_fn(apply_fn(params, batch_x), batch_y, None)`` — ``apply_fn``
may return any pytree (MLP logits for classification; ``(hidden,
head)`` for the transformer LM task), so this loop trains every
registered task unchanged.  Rows are examples: feature vectors for
classification, whole token sequences for LM.

Gradient modifiers (FedProx / FedDyn / any registered client mode) plug
in via ``mode``: the name is a static jit argument resolved against the
``repro.engine`` client-mode registry at trace time, so adding a mode
never touches this loop.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.engine.client_modes import get_client_mode

__all__ = ["local_train", "client_loss"]


def _sample_batch(key, mask, batch_size):
    """Indices of a with-replacement minibatch drawn from valid rows."""
    p = mask / jnp.maximum(mask.sum(), 1e-9)
    return jax.random.choice(key, mask.shape[0], shape=(batch_size,), p=p)


def client_loss(apply_fn: Callable, loss_fn: Callable, params, x, y, mask) -> jax.Array:
    """Local empirical loss over the client's full (masked) dataset —
    what each client reports to the server (Algorithm 1 line 3)."""
    logits = apply_fn(params, x)
    return loss_fn(logits, y, mask)


@partial(
    jax.jit,
    static_argnames=("apply_fn", "loss_fn", "max_steps", "batch_size", "mode"),
)
def local_train(
    apply_fn: Callable,
    loss_fn: Callable,
    global_params: Any,
    x: jax.Array,          # (N_max, ...) padded features
    y: jax.Array,          # (N_max, ...) padded labels
    mask: jax.Array,       # (N_max,) validity
    tau: jax.Array,        # () true local step budget of this client
    key: jax.Array,
    lr: float | jax.Array,
    max_steps: int,
    batch_size: int,
    mode: str = "plain",            # plain | fedprox | feddyn
    mu: float = 0.0,                # fedprox proximal / feddyn alpha
    h_state: Any = None,            # feddyn per-client correction
):
    """Returns (params_end, mean_train_loss_over_executed_steps)."""

    mode_impl = get_client_mode(mode)  # static name → registry, trace-time

    def loss_on_batch(params, bx, by):
        return loss_fn(apply_fn(params, bx), by, None)

    grad_fn = jax.value_and_grad(loss_on_batch)

    def step(carry, inp):
        params, losses_sum = carry
        t, k = inp
        bidx = _sample_batch(k, mask, batch_size)
        bx, by = jnp.take(x, bidx, axis=0), jnp.take(y, bidx, axis=0)
        loss, grads = grad_fn(params, bx, by)
        grads = mode_impl.modify_grads(grads, params, global_params, h_state, mu)
        live = (t < tau).astype(jnp.float32)
        new_params = jax.tree.map(
            lambda p, g: p - lr * live * g.astype(p.dtype), params, grads
        )
        return (new_params, losses_sum + live * loss), None

    keys = jax.random.split(key, max_steps)
    ts = jnp.arange(max_steps)
    (params_end, loss_sum), _ = jax.lax.scan(
        step, (global_params, jnp.zeros((), jnp.float32)), (ts, keys)
    )
    mean_loss = loss_sum / jnp.maximum(jnp.minimum(tau, max_steps).astype(jnp.float32), 1.0)
    return params_end, mean_loss
