"""Serving driver: batched prefill + greedy decode for any registered arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint
from repro.configs import get_config
from repro.configs.inputs import dummy_batch
from repro.models.transformer import decode_step, init_transformer, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_transformer(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        params, meta = load_checkpoint(args.ckpt, params)
        print(f"restored checkpoint ({meta})")

    max_len = args.prompt_len + args.gen
    batch = dummy_batch(cfg, args.batch, args.prompt_len, seed=args.seed)
    batch.pop("labels")

    t0 = time.time()
    pre = jax.jit(
        lambda p, b: prefill(p, cfg, b, max_len=max_len), donate_argnums=()
    )
    logits, cache = pre(params, batch)
    t_prefill = time.time() - t0
    print(f"prefill {args.batch}×{args.prompt_len}: {t_prefill:.2f}s")

    dec = jax.jit(
        lambda p, b, c, pos: decode_step(p, cfg, b, c, pos), donate_argnums=()
    )
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        if cfg.input_mode == "frames":
            # audio decode feeds the embedding of the sampled code
            frame = jnp.take(params["embed"], tok[:, 0], axis=0)[:, None, :]
            logits, cache = dec(params, {"frame": frame}, cache, jnp.int32(args.prompt_len + i))
        else:
            logits, cache = dec(params, {"token": tok}, cache, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
    dt = time.time() - t0
    print(f"decoded {args.gen} tokens × {args.batch} seqs in {dt:.2f}s "
          f"({args.gen*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
