import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape) on the
production mesh, without allocating a single parameter.

For each pair this driver:
  1. builds the full config (long_500k gets the documented SWA variant
     for full-attention archs — DESIGN.md §5),
  2. eval_shape's params (and caches for decode shapes),
  3. assembles in/out shardings from the baseline policy (repro.sharding),
  4. ``jit(step).lower(**ShapeDtypeStructs).compile()``,
  5. records memory_analysis / cost_analysis / per-collective bytes
     (parsed from the compiled HLO) into a JSONL for the roofline pass.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
      --shape train_4k --mesh single --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

NOTE: the XLA_FLAGS line above MUST run before any other import — jax
locks the device count at first init.  Do not import this module from
code that already initialized jax with one device.
"""

import argparse
import json
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_configs
from repro.configs.inputs import decode_specs, input_specs, long_context_variant
from repro.jax_compat import cost_analysis, set_mesh
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import (
    cache_specs,
    decode_step,
    init_cache,
    init_transformer,
    loss_fn,
    prefill,
    transformer_specs,
)
from repro.sharding import make_policy

_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?(?:\.\d+)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output bytes of every collective op in the HLO, by kind.

    Methodology (EXPERIMENTS.md §Dry-run): we count each collective's
    *result* size — for all-gather that is the gathered tensor, for
    all-reduce the reduced tensor, for reduce-scatter the scattered
    shard.  This approximates on-wire traffic to within the ring-factor
    (2(n−1)/n for all-reduce) which we fold into the roofline constant.
    """
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, rhs = line.split("=", 1)
        m = _COLLECTIVE_RE.search(rhs)
        if not m:
            continue
        kind, suffix = m.group(1), m.group(2)
        if suffix == "-done":
            continue  # async pair: the -start result already counted
        # the result type(s) sit between '=' and the op name
        shapes = _SHAPE_RE.findall(rhs[: m.start()])
        total = 0.0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + total
    return out


def _batch_logical_axes(cfg, kind):
    ax = {}
    if cfg.input_mode == "tokens":
        ax["tokens"] = ("batch", "seq_in")
    elif cfg.input_mode == "frames":
        ax["frames"] = ("batch", "seq_in", None)
    else:
        ax["patches"] = ("batch", None, None)
        ax["tokens"] = ("batch", "seq_in")
    if kind == "train":
        ax["labels"] = ("batch", "seq_in")
    return ax


def build_step(cfg, mesh, shape, lr=1e-3, policy_variant: str = "baseline"):
    """Returns (fn, arg_specs, arg_shardings, donate) for the shape kind."""
    if policy_variant == "fsdp" and not cfg.act_shard:
        from dataclasses import replace as _rep
        cfg = _rep(cfg, act_shard="dp_all")
    policy = make_policy(
        mesh, shape.global_batch,
        shard_seq=(shape.kind == "decode" and shape.global_batch == 1),
        variant=policy_variant,
    )
    pshapes = jax.eval_shape(partial(init_transformer, cfg=cfg), jax.random.PRNGKey(0))
    pspecs = transformer_specs(cfg)
    pshard = policy.shardings(pspecs, pshapes)

    if shape.kind == "train":
        batch = input_specs(cfg, shape)
        bspec = _batch_logical_axes(cfg, "train")
        bshard = {
            k: NamedSharding(mesh, policy.spec_for(bspec[k], batch[k].shape)) for k in batch
        }

        def train_step(params, batch):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, batch, mesh
            )
            params = jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype), params, grads)
            return params, loss

        return train_step, (pshapes, batch), ((pshard, bshard), (pshard, NamedSharding(mesh, P()))), (0,)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        bspec = _batch_logical_axes(cfg, "prefill")
        bshard = {
            k: NamedSharding(mesh, policy.spec_for(bspec[k], batch[k].shape)) for k in batch
        }
        cshapes = jax.eval_shape(partial(init_cache, cfg, shape.global_batch, shape.seq_len))
        cspecs = cache_specs(cfg)
        cshard = policy.shardings(cspecs, cshapes)

        def prefill_step(params, batch):
            return prefill(params, cfg, batch, max_len=shape.seq_len, mesh=mesh)

        out_shard = (NamedSharding(mesh, P()), cshard)
        return prefill_step, (pshapes, batch), ((pshard, bshard), out_shard), ()

    # decode
    batch = decode_specs(cfg, shape)
    bshard = {k: NamedSharding(mesh, P()) for k in batch}
    cshapes = jax.eval_shape(partial(init_cache, cfg, shape.global_batch, shape.seq_len))
    cspecs = cache_specs(cfg)
    cshard = policy.shardings(cspecs, cshapes)

    def serve_step(params, batch, cache, pos):
        logits, cache = decode_step(params, cfg, batch, cache, pos, mesh=mesh)
        return logits, cache

    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    arg_specs = (pshapes, batch, cshapes, pos_spec)
    in_shard = (pshard, bshard, cshard, NamedSharding(mesh, P()))
    out_shard = (NamedSharding(mesh, P()), cshard)
    return serve_step, arg_specs, (in_shard, out_shard), (2,)


def _probe_cfg(cfg, shape, n_layers: int):
    """Loop-free cost-probe variant: XLA's cost_analysis counts a while
    body ONCE regardless of trip count, so the production lowering (scan
    over layers + chunked attention/loss scans) under-reports FLOPs.
    Probes remove every data-dependent loop: `n_layers` ∈ {1, 2} with the
    layer scan fully unrolled, attention/loss/ssm chunks = full sequence.
    Roofline totals are reconstructed as
        body = cost(P2) − cost(P1);  outside = cost(P1) − body;
        total = outside + L·body
    (per-layer costs, incl. per-layer FSDP gathers and grad reductions,
    are linear in L; methodology recorded in EXPERIMENTS.md §Dry-run).
    """
    from dataclasses import replace

    s = shape.seq_len
    kw = dict(
        n_layers=n_layers,
        scan_unroll=n_layers,
        attn_chunk=s,
        loss_chunk=s,
        remat=False,
    )
    if cfg.ssm is not None:
        from dataclasses import replace as rep

        if cfg.ssm.family == "xlstm" and s > 8192:
            # full-chunk mLSTM would create an S×S×H intra-chunk temp per
            # layer; cap at 8192 and accept a bounded (≤ S/8192×) undercount
            # of the recurrent-core term (EXPERIMENTS.md §Dry-run note)
            kw["ssm"] = rep(cfg.ssm, chunk=8192)
        else:
            kw["ssm"] = rep(cfg.ssm, chunk=s)
    return replace(cfg, **kw)


def _lower_cost(cfg, mesh, shape, policy_variant: str = "baseline"):
    fn, arg_specs, (in_shard, out_shard), donate = build_step(
        cfg, mesh, shape, policy_variant=policy_variant
    )
    with set_mesh(mesh):
        compiled = (
            jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard,
                    donate_argnums=donate)
            .lower(*arg_specs)
            .compile()
        )
    cost = cost_analysis(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(hlo),
    }


def _combine(outside, body, L):
    def add(a, b, s):
        return a + s * b

    coll = {}
    for k in set(outside["coll"]) | set(body["coll"]):
        coll[k] = outside["coll"].get(k, 0.0) + L * body["coll"].get(k, 0.0)
    return {
        "flops": outside["flops"] + L * body["flops"],
        "bytes": outside["bytes"] + L * body["bytes"],
        "coll": coll,
    }


def probe_costs(cfg, mesh, shape, policy_variant: str = "baseline") -> dict:
    """Loop-corrected cost model from two probe lowers (see _probe_cfg)."""
    p1 = _lower_cost(_probe_cfg(cfg, shape, 1), mesh, shape, policy_variant)
    p2 = _lower_cost(_probe_cfg(cfg, shape, 2), mesh, shape, policy_variant)
    body = {
        "flops": max(p2["flops"] - p1["flops"], 0.0),
        "bytes": max(p2["bytes"] - p1["bytes"], 0.0),
        "coll": {
            k: max(p2["coll"].get(k, 0.0) - p1["coll"].get(k, 0.0), 0.0)
            for k in set(p1["coll"]) | set(p2["coll"])
        },
    }
    outside = {
        "flops": max(p1["flops"] - body["flops"], 0.0),
        "bytes": max(p1["bytes"] - body["bytes"], 0.0),
        "coll": {
            k: max(p1["coll"].get(k, 0.0) - body["coll"].get(k, 0.0), 0.0)
            for k in set(p1["coll"]) | set(body["coll"])
        },
    }
    total = _combine(outside, body, cfg.n_layers)
    return {"per_layer": body, "outside": outside, "total": total}


def run_one(arch: str, shape_name: str, multi_pod: bool, record_hlo: bool = False,
            policy_variant: str = "baseline") -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, arg_specs, (in_shard, out_shard), donate = build_step(
        cfg, mesh, shape, policy_variant=policy_variant
    )
    with set_mesh(mesh):
        jitted = jax.jit(
            fn, in_shardings=in_shard, out_shardings=out_shard, donate_argnums=donate
        )
        lowered = jitted.lower(*arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)
    # loop-corrected cost model (single-pod only: the roofline table reads
    # single-pod records; multi-pod entries prove lowering/sharding)
    probes = None
    if not multi_pod:
        try:
            probes = probe_costs(cfg, mesh, shape, policy_variant)
        except Exception as e:  # probes are best-effort; record why
            probes = {"error": f"{type(e).__name__}: {e}"}
    rec = {
        "arch": arch,
        "config_name": cfg.name,
        "shape": shape_name,
        "policy": policy_variant,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "kind": shape.kind,
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "probes": probes,
        "memory": {
            "argument_size": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_size": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "hlo_len": len(hlo),
    }
    if record_hlo:
        rec["hlo_head"] = hlo[:5000]
    return rec


def run_federated(arch: str, local_steps: int = 4, batch_per_client: int = 128,
                  seq: int = 4096, compress_bits: int = 0) -> dict:
    """Lower + compile the scale-out FedLECC round (DESIGN.md §3b): clients
    = pods, local SGD steps inside shard_map(manual={'pod'}), aggregation
    = selection-weighted psum over 'pod'.  The paper-representative
    dry-run artifact.  Built via the engine API (`repro.engine.scaleout`),
    the same entry `ScaleoutEngine` and every other consumer of the mesh
    round use."""
    from repro.engine.scaleout import make_scaleout_round

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=True)
    n_pods = mesh.shape["pod"]
    policy = make_policy(mesh, batch_per_client * n_pods)
    pshapes = jax.eval_shape(partial(init_transformer, cfg=cfg), jax.random.PRNGKey(0))
    pspecs = transformer_specs(cfg)

    def stacked_spec(axes, shape):
        inner = policy.spec_for(tuple(axes), shape[1:])
        return NamedSharding(mesh, P("pod", *inner))

    def is_axes(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, tuple, type(None))) for e in x
        )

    flat_specs = jax.tree.leaves(pspecs, is_leaf=is_axes)
    flat_shapes = jax.tree.leaves(pshapes)
    stacked_shapes = jax.tree.unflatten(
        jax.tree.structure(pshapes),
        [jax.ShapeDtypeStruct((n_pods,) + s.shape, s.dtype) for s in flat_shapes],
    )
    pshard = jax.tree.unflatten(
        jax.tree.structure(pshapes),
        [stacked_spec(sp, (n_pods,) + sh.shape) for sp, sh in zip(flat_specs, flat_shapes)],
    )
    batch = {
        "tokens": jax.ShapeDtypeStruct((n_pods, batch_per_client, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n_pods, batch_per_client, seq), jnp.int32),
    }
    bshard = {k: NamedSharding(mesh, P("pod", "data", None)) for k in batch}
    w = jax.ShapeDtypeStruct((n_pods,), jnp.float32)
    wshard = NamedSharding(mesh, P("pod"))

    round_fn = make_scaleout_round(cfg, mesh, lr=1e-3, local_steps=local_steps,
                                   compress_bits=compress_bits)
    t0 = time.time()
    with set_mesh(mesh):
        jitted = jax.jit(
            round_fn,
            in_shardings=(pshard, bshard, wshard),
            out_shardings=(pshard, NamedSharding(mesh, P())),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(stacked_shapes, batch, w)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    hlo = compiled.as_text()
    rec = {
        "arch": arch,
        "shape": f"fedround_b{batch_per_client}x{seq}_E{local_steps}_q{compress_bits}",
        "mesh": "multi", "kind": "federated_round",
        "n_devices": 512,
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": collective_bytes(hlo),
        "memory": {
            "argument_size": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size": int(getattr(mem, "temp_size_in_bytes", 0)),
        },
        "t_total_s": round(time.time() - t0, 2),
        "hlo_len": len(hlo),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all (arch × shape) pairs")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--policy", default="baseline", choices=["baseline", "fsdp"])
    ap.add_argument(
        "--federated", action="store_true",
        help="lower the scale-out FedLECC round instead of plain steps",
    )
    args = ap.parse_args()

    if args.federated:
        arch = args.arch or "qwen3-14b"
        rc = 0
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        for bits in (0, 8):
            try:
                rec = run_federated(arch, compress_bits=bits)
                status = "OK"
            except Exception as e:
                rec = {"arch": arch, "shape": f"fedround_q{bits}", "mesh": "multi",
                       "error": f"{type(e).__name__}: {e}"}
                status = "FAIL"
                rc = 1
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            detail = rec.get("error") or (
                f"flops={rec.get('flops', 0):.3e} "
                f"coll={ {k: round(v/1e9,2) for k, v in rec.get('collective_bytes', {}).items()} }GB"
            )
            print(f"[{status}] federated_round {arch} q{bits}: {detail}")
        sys.exit(rc)

    archs = list_configs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if "error" not in r:
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                key = (arch, shape, mesh_kind)
                if key in done:
                    continue
                try:
                    rec = run_one(arch, shape, multi_pod=(mesh_kind == "multi"),
                                  policy_variant=args.policy)
                    status = "OK"
                except Exception as e:  # record failures — they are bugs
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "error": f"{type(e).__name__}: {e}",
                    }
                    status = "FAIL"
                    n_fail += 1
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                msg = rec.get("error", f"compile={rec.get('t_compile_s', '?')}s flops={rec.get('flops', 0):.3e}")
                print(f"[{status}] {arch} × {shape} × {mesh_kind}: {msg}", flush=True)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
