"""Production mesh construction.

Function (not module-level constant) so importing this module never
touches jax device state.  Single pod: 256 chips as (data=16, model=16).
Multi-pod: 2 pods × 256 chips as (pod=2, data=16, model=16) — the "pod"
axis doubles as the FL client axis in the scale-out federated round
(DESIGN.md §3b).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — used by tests
    and CPU examples."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
