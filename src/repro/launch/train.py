"""Training driver — runs any registered architecture on real devices.

On this CPU container it drives the *reduced* configs (the full ones are
dry-run-only); on a TPU slice the same entry point runs the full configs
under the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/x.ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import make_token_stream
from repro.models.transformer import init_transformer, loss_fn
from repro.optim import adamw, clip_by_global_norm, chain, warmup_cosine
from repro.optim.optimizers import apply_updates


def make_train_step(cfg, optimizer, mesh=None):
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, mesh
        )
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, metrics

    # params/opt_state are reassigned from the step's own outputs in the
    # train loop, so their input buffers can be donated.
    return jax.jit(step, donate_argnums=(0, 1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None,
                    help="checkpoint written by a previous --ckpt run; "
                         "restores params + optimizer state and continues "
                         "from the stored step")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.input_mode != "tokens":
        raise SystemExit(
            f"{args.arch} is {cfg.input_mode}-input; use examples/serve_audio_vlm.py"
        )
    params = init_transformer(jax.random.PRNGKey(args.seed), cfg)
    opt = chain(
        clip_by_global_norm(1.0),
        adamw(warmup_cosine(args.lr, 10, args.steps), weight_decay=0.01),
    )
    opt_state = opt.init(params)
    start = 0
    if args.resume:
        # restore into the freshly initialized structures: the serializer
        # verifies treedef/dtype/shape, so an --arch mismatch fails loudly
        (params, opt_state), meta = load_checkpoint(
            args.resume, like=(params, opt_state)
        )
        if meta.get("arch") != cfg.name:
            raise SystemExit(
                f"--resume checkpoint is for arch {meta.get('arch')!r}, "
                f"not {cfg.name!r}"
            )
        start = int(meta.get("step", 0))
        print(f"resumed {cfg.name} from {args.resume} at step {start}")
    step = make_train_step(cfg, opt)

    data = make_token_stream(args.steps * args.batch, args.seq, cfg.vocab, seed=args.seed)
    t0 = time.time()
    for i in range(start, args.steps):
        lo = i * args.batch
        batch = {
            "tokens": jnp.asarray(data.x[lo : lo + args.batch]),
            "labels": jnp.asarray(data.y[lo : lo + args.batch]),
        }
        params, opt_state, loss, metrics = step(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} ce {float(metrics['ce']):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    if args.ckpt:
        save_checkpoint(
            args.ckpt, (params, opt_state),
            meta={"arch": cfg.name, "step": args.steps},
        )
        print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
