"""Mixture-of-Experts: shared + routed top-k experts.

Two compute paths sharing one parameter layout:

- ``moe_dense``     — every expert runs on every token, outputs combined
    with router weights.  Exact (no token dropping).  Used by smoke tests
    and as the oracle for the capacity path (with capacity ≥ tokens·k the
    two agree exactly).
- ``moe_capacity``  — production path: sort-based token dispatch with a
    per-expert capacity, batched per-expert matmuls (MXU-friendly
    (E, C, d) × (E, d, ff)), scatter-add combine.  Designed to run inside
    ``shard_map`` (see ``moe_capacity_sharded``): experts sharded over the
    ``model`` mesh axis, tokens local to the ``data`` shard, partial
    outputs combined with ``psum`` over ``model`` — the TPU-native
    替代 of the GPU all-to-all dispatch (DESIGN.md §4): every token meets
    every expert because activations are replicated over ``model`` in the
    Megatron layout, so no token redistribution collective is needed; the
    psum doubles as the combine.

Router: fp32 softmax over expert logits, top-k, weights renormalized over
the selected k (deepseek-v3 convention).  Aux load-balance loss returned
alongside (Σ_e f_e · p_e · E, the switch-transformer form).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, lecun_init

__all__ = [
    "init_moe", "moe_specs", "moe_dense", "moe_capacity", "moe_capacity_sharded",
]


def init_moe(key, cfg) -> dict:
    d = cfg.d_model
    mc = cfg.moe
    e, fe = mc.n_experts, mc.d_expert
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    p = {
        "router": lecun_init(ks[0], (d, e), jnp.float32),
        "w_gate": lecun_init(ks[1], (e, d, fe), dt),
        "w_up": lecun_init(ks[2], (e, d, fe), dt),
        "w_down": lecun_init(ks[3], (e, fe, d), dt, fan_in=fe),
    }
    if mc.n_shared:
        fs = mc.d_expert * mc.n_shared
        p["shared_gate"] = lecun_init(ks[4], (d, fs), dt)
        p["shared_up"] = lecun_init(ks[5], (d, fs), dt)
        p["shared_down"] = lecun_init(ks[6], (fs, d), dt, fan_in=fs)
    return p


def moe_specs(cfg) -> dict:
    s = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_ff"),
        "w_up": ("experts", "embed", "expert_ff"),
        "w_down": ("experts", "expert_ff", "embed"),
    }
    if cfg.moe.n_shared:
        s["shared_gate"] = ("embed", "ffn")
        s["shared_up"] = ("embed", "ffn")
        s["shared_down"] = ("ffn", "embed")
    return s


def _router(p, cfg, x2d):
    """x2d (T, d) -> top-k (ids (T,k), weights fp32 (T,k), aux loss)."""
    mc = cfg.moe
    logits = x2d.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, mc.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)     # renormalize over k
    # Switch-style load-balance aux: E · Σ_e f_e p̄_e
    e = mc.n_experts
    f = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (
        x2d.shape[0] * mc.top_k
    )
    aux = e * jnp.sum(f * probs.mean(0))
    return ids, w, aux


def _shared_expert(p, cfg, x2d):
    h = activation(cfg.mlp_activation, x2d @ p["shared_up"], x2d @ p["shared_gate"])
    return h @ p["shared_down"]


def _expert_ffn_all(p, cfg, xe):
    """Batched per-expert FFN: xe (E, C, d) -> (E, C, d)."""
    h = activation(
        cfg.mlp_activation,
        jnp.einsum("ecd,edf->ecf", xe, p["w_up"]),
        jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]),
    )
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_dense(p, cfg, x) -> tuple[jax.Array, jax.Array]:
    """Oracle path: all experts on all tokens.  x (B,S,d) -> (out, aux)."""
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    ids, w, aux = _router(p, cfg, x2d)
    e = cfg.moe.n_experts
    # (E, T, d): every expert sees every token
    ye = _expert_ffn_all(p, cfg, jnp.broadcast_to(x2d[None], (e, x2d.shape[0], d)))
    # combine: for each token sum over its k experts
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)       # (T, k, E)
    w_full = jnp.einsum("tke,tk->te", onehot, w)             # (T, E)
    out = jnp.einsum("te,etd->td", w_full.astype(x.dtype), ye)
    if cfg.moe.n_shared:
        out = out + _shared_expert(p, cfg, x2d)
    return out.reshape(b, s, d), aux


def moe_capacity(
    p,
    cfg,
    x2d,
    expert_offset: int = 0,
    n_local_experts: int | None = None,
    include_shared: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Capacity-based dispatch over the local expert range.

    x2d (T, d); experts [offset, offset+E_loc) are computed here.  Router
    runs on the full expert set (weights replicated).  Returns the
    *partial* output (T, d) — caller psums over the expert-sharding axis —
    plus the aux loss (identical on every shard).
    """
    mc = cfg.moe
    t, d = x2d.shape
    e_loc = n_local_experts or mc.n_experts
    ids, w, aux = _router(p, cfg, x2d)                       # global ids
    cap = int(max(1, round(t * mc.top_k * mc.capacity_factor / mc.n_experts)))

    # Flatten (token, slot) assignments, keep only my experts.
    flat_ids = ids.reshape(-1)                               # (T*k,)
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), mc.top_k)
    local = flat_ids - expert_offset
    mine = (local >= 0) & (local < e_loc)
    local = jnp.where(mine, local, e_loc)                    # sentinel bucket
    # Sort by (local expert, -weight): drops lowest-weight tokens on overflow.
    # stop_gradient: the routing *order* is a discrete decision — gradients
    # flow through the gathered weights/activations, not the sort key (also
    # avoids sort-JVP, which this jaxlib build cannot lower).
    key = jax.lax.stop_gradient(local.astype(jnp.float32) + (1.0 - flat_w))
    order = jnp.argsort(key)
    s_local = local[order]
    s_tok = flat_tok[order]
    s_w = jnp.where(mine, flat_w, 0.0)[order]
    # Segment starts via scatter-min over sorted positions.
    npos = s_local.shape[0]
    first = jnp.full((e_loc + 1,), npos, jnp.int32).at[s_local].min(
        jnp.arange(npos, dtype=jnp.int32)
    )
    pos_in_seg = jnp.arange(npos, dtype=jnp.int32) - first[s_local]
    valid = (pos_in_seg < cap) & (s_local < e_loc)
    # Dispatch index (E_loc, cap): entry -> position in sorted stream.
    slot = jnp.where(valid, s_local * cap + pos_in_seg, e_loc * cap)
    stream_of_slot = jnp.full((e_loc * cap + 1,), npos, jnp.int32).at[slot].min(
        jnp.arange(npos, dtype=jnp.int32)
    )[:-1]
    slot_valid = stream_of_slot < npos
    stream_idx = jnp.minimum(stream_of_slot, npos - 1)
    tok_of_slot = jnp.where(slot_valid, s_tok[stream_idx], 0)
    w_of_slot = jnp.where(slot_valid, s_w[stream_idx], 0.0)

    xe = jnp.take(x2d, tok_of_slot, axis=0).reshape(e_loc, cap, d)
    ye = _expert_ffn_all(
        {"w_gate": p["w_gate"], "w_up": p["w_up"], "w_down": p["w_down"]}, cfg, xe
    )
    contrib = ye.reshape(-1, d) * w_of_slot[:, None].astype(ye.dtype)
    out = jnp.zeros((t, d), ye.dtype).at[tok_of_slot].add(contrib)
    if include_shared and cfg.moe.n_shared:
        out = out + _shared_expert(p, cfg, x2d)
    return out, aux


def moe_capacity_sharded(p, cfg, x, mesh_axis: str = "model"):
    """``shard_map``-ready capacity MoE: call inside a shard_map whose
    in_specs give this block the *local* expert slice on ``mesh_axis`` and
    the data-shard-local tokens; output is psum'd over ``mesh_axis``.

    x (B_loc, S, d) with p["w_*"] already sliced to local experts; the
    router and shared-expert weights arrive replicated.  The routed
    partial outputs are psum'd over ``mesh_axis`` (each token's k experts
    live on different shards); the shared expert is added once after the
    psum — activations are replicated over ``mesh_axis`` so every shard
    computes the identical shared contribution.
    """
    b, s, d = x.shape
    e_loc = p["w_gate"].shape[0]
    idx = jax.lax.axis_index(mesh_axis)
    x2d = x.reshape(-1, d)
    out2d, aux = moe_capacity(
        p, cfg, x2d, expert_offset=idx * e_loc, n_local_experts=e_loc,
        include_shared=False,
    )
    out2d = jax.lax.psum(out2d, mesh_axis)
    if cfg.moe.n_shared:
        # replicated over mesh_axis by design — see the §Perf note at the
        # call site in transformer._run_moe (refuted TP hypothesis)
        out2d = out2d + _shared_expert(p, cfg, x2d)
    return out2d.reshape(b, s, d), aux
