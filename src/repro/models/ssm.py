"""State-space / recurrent blocks: Mamba-style selective SSM (hymba's
parallel-SSM heads) and xLSTM (mLSTM + sLSTM).

TPU adaptation (DESIGN.md §4): GPU Mamba fuses the selective scan into a
single kernel; on TPU the natural mapping is a *chunked* linear scan —
``associative_scan`` (log-depth, VPU-friendly) inside fixed-size chunks,
``lax.scan`` carrying state between chunks.  Memory is O(B·chunk·d·N)
instead of O(B·S·d·N), which is what lets long-context shapes lower.

mLSTM prefill uses a flash-attention-style double scan with a running
max over the exponential-gate logits (the stabilizer m_t from the xLSTM
paper) — quadratic compute, O(chunk²) memory.  Decode is the O(1)
recurrent form for all blocks.

Documented deviation: sLSTM here drops the h→gate recurrent feedback so
the recurrence stays linear (associative-scan-able); see DESIGN.md §9.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import lecun_init, rms_norm

__all__ = [
    "chunked_linear_scan",
    "init_mamba", "mamba_specs", "mamba_seq", "mamba_decode",
    "init_xlstm", "xlstm_specs", "mlstm_seq", "mlstm_decode",
    "slstm_seq", "slstm_decode",
]

_NEG = -1e30


def chunked_linear_scan(a, b, h0, chunk: int):
    """h_t = a_t · h_{t−1} + b_t along axis 1.

    a, b: (B, S, ...) elementwise coefficients; h0: (B, ...) initial state.
    Returns (h_all (B,S,...), h_final (B,...)).
    """
    bsz, s = a.shape[:2]
    c = min(chunk, s)
    nc = s // c
    assert nc * c == s, (s, c)
    rest = a.shape[2:]
    a_c = jnp.moveaxis(a.reshape(bsz, nc, c, *rest), 1, 0)
    b_c = jnp.moveaxis(b.reshape(bsz, nc, c, *rest), 1, 0)

    def combine(x, y):
        return (y[0] * x[0], y[0] * x[1] + y[1])

    def outer(h, ab):
        ac, bc = ab
        acc_a, acc_b = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = acc_a * h[:, None] + acc_b
        return h_all[:, -1], h_all

    h_final, chunks = jax.lax.scan(outer, h0, (a_c, b_c))
    out = jnp.moveaxis(chunks, 0, 1).reshape(bsz, s, *rest)
    return out, h_final


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (hymba's SSM heads)
# ---------------------------------------------------------------------------


def init_mamba(key, cfg) -> dict:
    d = cfg.d_model
    sc = cfg.ssm
    n = sc.d_state
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    d_in = d  # hymba: SSM heads run at model width in parallel with attention
    return {
        "w_in": lecun_init(ks[0], (d, 2 * d_in), dt),
        "conv_w": (jax.random.normal(ks[1], (sc.conv_kernel, d_in), jnp.float32) * 0.2).astype(dt),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, 1))),
        "w_dt": lecun_init(ks[2], (d_in,), jnp.float32, fan_in=d_in),
        "b_dt": jnp.full((d_in,), -4.6, jnp.float32),  # softplus ≈ 0.01
        "w_b": lecun_init(ks[3], (d_in, n), dt),
        "w_c": lecun_init(ks[4], (d_in, n), dt),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "w_out": lecun_init(ks[5], (d_in, d), dt),
    }


def mamba_specs(cfg) -> dict:
    return {
        "w_in": ("embed", "ffn"),
        "conv_w": (None, "ffn"),
        "a_log": ("ffn", "state"),
        "w_dt": ("ffn",),
        "b_dt": ("ffn",),
        "w_b": ("ffn", "state"),
        "w_c": ("ffn", "state"),
        "d_skip": ("ffn",),
        "w_out": ("ffn", "embed"),
    }


def _causal_conv(x, w):
    """Depthwise causal conv via shifted adds (kernel k ≤ ~4: cheaper than
    conv_general_dilated and trivially shardable).  x (B,S,D), w (k,D)."""
    k = w.shape[0]
    out = x * w[-1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return out


def _ssm_coeffs(p, x_in, dtv=None):
    """Shared discretization: returns (decay a, drive b, C, D·x) all fp32."""
    xf = x_in.astype(jnp.float32)
    dt = jax.nn.softplus(xf * p["w_dt"] + p["b_dt"])              # (B,S,D)
    a_cont = -jnp.exp(p["a_log"])                                  # (D,N)
    a = jnp.exp(dt[..., None] * a_cont)                            # (B,S,D,N)
    bmat = xf @ p["w_b"].astype(jnp.float32)                       # (B,S,N)
    b = dt[..., None] * bmat[..., None, :] * xf[..., None]         # (B,S,D,N)
    cmat = xf @ p["w_c"].astype(jnp.float32)                       # (B,S,N)
    return a, b, cmat, xf * p["d_skip"]


def mamba_seq(p, cfg, x, state=None, conv_tail=None):
    """Full-sequence selective SSM.  x (B,S,d) → (out (B,S,d), (h, conv_tail)).

    ``state``/``conv_tail`` carry recurrent state across calls (prefill →
    decode hand-off).

    §Perf (hillclimb 2): the C-contraction is fused into the chunk loop —
    the (B,S,D,N) state tensor never round-trips HBM in full; only the
    per-chunk (B,c,D,N) slice is live, and what crosses the loop boundary
    is the contracted (B,c,D) output.  (The Pallas twin in
    ``repro.kernels.mamba_scan`` removes the N-dim traffic entirely by
    keeping h in VMEM.)
    """
    bsz, s, d = x.shape
    sc = cfg.ssm
    xz = x @ p["w_in"]
    raw, z = jnp.split(xz, 2, axis=-1)
    if conv_tail is None:
        conv_tail = jnp.zeros((bsz, sc.conv_kernel - 1, raw.shape[-1]), jnp.float32)
    ext = jnp.concatenate([conv_tail.astype(raw.dtype), raw], axis=1)
    x_in = _causal_conv(ext, p["conv_w"])[:, conv_tail.shape[1] :]
    x_in = jax.nn.silu(x_in)
    a, b, cmat, dx = _ssm_coeffs(p, x_in)
    d_in, n = p["a_log"].shape
    h0 = state if state is not None else jnp.zeros((bsz, d_in, n), jnp.float32)

    if not sc.fuse_contraction:
        # baseline layout: full (B,S,D,N) state tensor round-trips HBM
        h_all, h_fin = chunked_linear_scan(a, b, h0, sc.chunk)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, cmat) + dx
        out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
        new_conv_tail = ext[:, -(sc.conv_kernel - 1) :].astype(jnp.float32)
        return out, (h_fin, new_conv_tail)

    c = min(sc.chunk, s)
    nc = s // c
    assert nc * c == s, (s, c)
    a_c = jnp.moveaxis(a.reshape(bsz, nc, c, d_in, n), 1, 0)
    b_c = jnp.moveaxis(b.reshape(bsz, nc, c, d_in, n), 1, 0)
    cm_c = jnp.moveaxis(cmat.reshape(bsz, nc, c, n), 1, 0)

    def combine(u, v):
        return (v[0] * u[0], v[0] * u[1] + v[1])

    def outer(h, inp):
        ac, bc, cmc = inp
        acc_a, acc_b = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = acc_a * h[:, None] + acc_b                 # (B,c,D,N) chunk-local
        y_c = jnp.einsum("bsdn,bsn->bsd", h_all, cmc)      # contract before HBM
        return h_all[:, -1], y_c

    h_fin, y_chunks = jax.lax.scan(outer, h0, (a_c, b_c, cm_c))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(bsz, s, d_in) + dx
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    new_conv_tail = ext[:, -(sc.conv_kernel - 1) :].astype(jnp.float32)
    return out, (h_fin, new_conv_tail)


def mamba_decode(p, cfg, x, state, conv_tail):
    """One-token step.  x (B,1,d); state (B,D,N); conv_tail (B,k−1,D)."""
    sc = cfg.ssm
    xz = x @ p["w_in"]
    x_raw, z = jnp.split(xz, 2, axis=-1)
    ext = jnp.concatenate([conv_tail.astype(x_raw.dtype), x_raw], axis=1)
    x_in = _causal_conv(ext, p["conv_w"])[:, -1:]
    x_in = jax.nn.silu(x_in)
    a, b, cmat, dx = _ssm_coeffs(p, x_in)
    h = a[:, 0] * state + b[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0]) + dx[:, 0]
    out = (y[:, None].astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    new_tail = ext[:, -(sc.conv_kernel - 1) :].astype(jnp.float32)
    return out, (h, new_tail)


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) + sLSTM (scalar memory)
# ---------------------------------------------------------------------------


def init_xlstm(key, cfg) -> dict:
    """One xLSTM block's parameters (layout shared by mLSTM and sLSTM so
    the layer stack can alternate under a single scan)."""
    d = cfg.d_model
    h = cfg.ssm.n_heads
    hd = d // h
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        "w_up": lecun_init(ks[0], (d, 2 * d), dt),      # core input + output gate
        "wq": lecun_init(ks[1], (d, d), dt),
        "wk": lecun_init(ks[2], (d, d), dt),
        "wv": lecun_init(ks[3], (d, d), dt),
        "w_if": lecun_init(ks[4], (d, 2 * h), jnp.float32),  # input/forget gate logits
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.full((h,), 3.0)]),
        "w_down": lecun_init(ks[5], (d, d), dt),
        "core_norm": jnp.zeros((d,), jnp.float32),
    }


def xlstm_specs(cfg) -> dict:
    return {
        "w_up": ("embed", "ffn"),
        "wq": ("embed", "heads"),
        "wk": ("embed", "heads"),
        "wv": ("embed", "heads"),
        "w_if": ("embed", None),
        "b_if": (None,),
        "w_down": ("heads", "embed"),
        "core_norm": (None,),
    }


def _xlstm_proj(p, cfg, x):
    b, s, d = x.shape
    h = cfg.ssm.n_heads
    hd = d // h
    up = x @ p["w_up"]
    core_in, out_gate = jnp.split(up, 2, axis=-1)
    q = (core_in @ p["wq"]).reshape(b, s, h, hd)
    k = (core_in @ p["wk"]).reshape(b, s, h, hd) / jnp.sqrt(hd)
    v = (core_in @ p["wv"]).reshape(b, s, h, hd)
    gates = core_in.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_log = gates[..., :h]                                   # (B,S,H) exp-gate logit
    f_log = jax.nn.log_sigmoid(gates[..., h:])               # log f ∈ (−inf, 0)
    return q, k, v, i_log, f_log, out_gate


def mlstm_seq(p, cfg, x, state=None):
    """Chunkwise-parallel mLSTM with running-max stabilization.

    Quadratic within the sequence (like attention) but computed chunk ×
    chunk flash-style.  state = (C (B,H,hd,hd), n (B,H,hd), m (B,H)).
    Cross-call state hand-off supported for prefill→decode.
    """
    b, s, d = x.shape
    hh = cfg.ssm.n_heads
    hd = d // hh
    ck = min(cfg.ssm.chunk, s)
    nc = s // ck
    assert nc * ck == s
    q, k, v, i_log, f_log, out_gate = _xlstm_proj(p, cfg, x)
    # cumulative log-forget within the whole sequence, fp32
    F = jnp.cumsum(f_log, axis=1)                            # (B,S,H)

    qc = jnp.moveaxis(q.reshape(b, nc, ck, hh, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, nc, ck, hh, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, ck, hh, hd), 1, 0)
    Fc = jnp.moveaxis(F.reshape(b, nc, ck, hh), 1, 0)
    ic = jnp.moveaxis(i_log.reshape(b, nc, ck, hh), 1, 0)

    def outer(carry, blk):
        C, n, m, F_prev = carry                              # recurrent state @ chunk start
        qb, kb, vb, Fb, ib = blk
        # intra-chunk decay logits: D_ij = F_i − F_j + i_j   (j ≤ i, within chunk)
        Fi = Fb[:, :, None, :]                               # (B,cq,1,H)
        Fj = Fb[:, None, :, :]
        lg = Fi - Fj + ib[:, None, :, :]
        ii = jnp.arange(ck)
        causal = (ii[:, None] >= ii[None, :])[None, :, :, None]
        lg = jnp.where(causal, lg, _NEG)
        # inter-chunk (state) contribution logit: F_i − F_prev + m
        lg_state = Fb - F_prev[:, None, :] + m[:, None, :]   # (B,cq,H)
        m_new = jnp.maximum(jnp.max(lg, axis=2), lg_state)   # (B,cq,H)
        w_intra = jnp.exp(lg - m_new[:, :, None, :])         # (B,cq,ck,H)
        w_state = jnp.exp(lg_state - m_new)                  # (B,cq,H)
        qf = qb.astype(jnp.float32)
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        dots = jnp.einsum("bqhd,bkhd->bqkh", qf, kf)
        h_intra = jnp.einsum("bqkh,bqkh,bkhe->bqhe", dots, w_intra, vf)
        n_intra = jnp.einsum("bqkh,bqkh->bqh", dots, w_intra)
        h_state = jnp.einsum("bqhd,bhde->bqhe", qf, C) * w_state[..., None]
        n_state = jnp.einsum("bqhd,bhd->bqh", qf, n) * w_state
        num = h_intra + h_state
        den = jnp.abs(n_intra + n_state)
        hmax = jnp.maximum(den, jnp.exp(-m_new))
        y = num / hmax[..., None]                            # (B,cq,H,hd)
        # ---- update recurrent state to chunk end ----
        F_end = Fb[:, -1, :]                                 # (B,H)
        m_endcand_state = F_end - F_prev + m
        decay_j = F_end[:, None, :] - Fb + ib                # (B,ck,H): contribution of each j to end-state
        m_end = jnp.maximum(jnp.max(decay_j, axis=1), m_endcand_state)
        wj = jnp.exp(decay_j - m_end[:, None, :])
        C_new = jnp.exp(m_endcand_state - m_end)[:, :, None, None] * C + jnp.einsum(
            "bkh,bkhd,bkhe->bhde", wj, kf, vf
        )
        n_new = jnp.exp(m_endcand_state - m_end)[:, :, None] * n + jnp.einsum(
            "bkh,bkhd->bhd", wj, kf
        )
        return (C_new, n_new, m_end, F_end), y

    if state is None:
        C0 = jnp.zeros((b, hh, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, hh, hd), jnp.float32)
        m0 = jnp.full((b, hh), _NEG, jnp.float32)
    else:
        C0, n0, m0 = state
    F0 = jnp.zeros((b, hh), jnp.float32)
    (C, n, m, _), ys = jax.lax.scan(outer, (C0, n0, m0, F0), (qc, kc, vc, Fc, ic))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d)
    y = rms_norm(y.astype(x.dtype), p["core_norm"], cfg.norm_eps)
    out = (y * jax.nn.silu(out_gate)) @ p["w_down"]
    return out, (C, n, m)


def mlstm_decode(p, cfg, x, state):
    """O(1) recurrent mLSTM step.  x (B,1,d)."""
    b, _, d = x.shape
    hh = cfg.ssm.n_heads
    hd = d // hh
    q, k, v, i_log, f_log, out_gate = _xlstm_proj(p, cfg, x)
    C, n, m = state
    i1 = i_log[:, 0]                                         # (B,H)
    f1 = f_log[:, 0]
    m_new = jnp.maximum(f1 + m, i1)
    fp = jnp.exp(f1 + m - m_new)
    ip = jnp.exp(i1 - m_new)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    qf = q[:, 0].astype(jnp.float32)
    C = fp[:, :, None, None] * C + ip[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = fp[:, :, None] * n + ip[:, :, None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    y = y.reshape(b, 1, d).astype(x.dtype)
    y = rms_norm(y, p["core_norm"], cfg.norm_eps)
    out = (y * jax.nn.silu(out_gate)) @ p["w_down"]
    return out, (C, n, m_new)


def slstm_seq(p, cfg, x, state=None):
    """sLSTM (linearized, no h-feedback — DESIGN.md §9): per-head scalar
    memory with exponential gating, computed as a chunked linear scan.

    state = (c (B,H,hd), n (B,H,hd), m (B,H)).
    """
    b, s, d = x.shape
    hh = cfg.ssm.n_heads
    hd = d // hh
    q, k, v, i_log, f_log, out_gate = _xlstm_proj(p, cfg, x)
    del q, k  # sLSTM uses the value path only (z = tanh proj)
    z = jnp.tanh(v.astype(jnp.float32))                      # (B,S,H,hd)
    # stabilized gates via running max: m_t = max(f_t + m_{t-1}, i_t)
    # m recursion is itself a (max,+) scan — associative.
    def mcomb(a_, b_):
        return (a_[0] + b_[0], jnp.maximum(a_[1] + b_[0], b_[1]))

    fsum, m_run = jax.lax.associative_scan(mcomb, (f_log, i_log), axis=1)
    if state is not None:
        m_prev0 = state[2]
        m_run = jnp.maximum(m_run, fsum + m_prev0[:, None])
    fp = jnp.exp(
        f_log + jnp.concatenate([jnp.full_like(m_run[:, :1], _NEG) if state is None
                                 else state[2][:, None], m_run[:, :-1]], axis=1) - m_run
    )
    ip = jnp.exp(i_log - m_run)
    a = fp[..., None] * jnp.ones((1, 1, hh, hd))
    bdrive = ip[..., None] * z
    c0 = state[0] if state is not None else jnp.zeros((b, hh, hd), jnp.float32)
    n0 = state[1] if state is not None else jnp.zeros((b, hh, hd), jnp.float32)
    c_all, c_fin = chunked_linear_scan(a, bdrive, c0, cfg.ssm.chunk)
    n_all, n_fin = chunked_linear_scan(a, ip[..., None] * jnp.ones_like(z), n0, cfg.ssm.chunk)
    h = c_all / jnp.maximum(jnp.abs(n_all), 1e-6)
    y = h.reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, p["core_norm"], cfg.norm_eps)
    out = (y * jax.nn.silu(out_gate)) @ p["w_down"]
    m_fin = m_run[:, -1]
    return out, (c_fin, n_fin, m_fin)


def slstm_decode(p, cfg, x, state):
    b, _, d = x.shape
    hh = cfg.ssm.n_heads
    hd = d // hh
    _, _, v, i_log, f_log, out_gate = _xlstm_proj(p, cfg, x)
    z = jnp.tanh(v[:, 0].astype(jnp.float32))
    c, n, m = state
    i1, f1 = i_log[:, 0], f_log[:, 0]
    m_new = jnp.maximum(f1 + m, i1)
    fp = jnp.exp(f1 + m - m_new)[..., None]
    ip = jnp.exp(i1 - m_new)[..., None]
    c = fp * c + ip * z
    n = fp * n + ip
    h = c / jnp.maximum(jnp.abs(n), 1e-6)
    y = h.reshape(b, 1, d).astype(x.dtype)
    y = rms_norm(y, p["core_norm"], cfg.norm_eps)
    out = (y * jax.nn.silu(out_gate)) @ p["w_down"]
    return out, (c, n, m_new)
