"""Composable decoder stack covering all 10 assigned architectures.

One scan over stacked per-layer params; layer heterogeneity (gemma3's
local/global pattern, xlstm's mLSTM/sLSTM alternation) enters as
per-layer flag arrays fed as scan xs (DESIGN.md §8.1).

Block types (``cfg.block_type``):
- ``attn``   — pre-norm attention (GQA or MLA) + pre-norm MLP (dense or MoE)
- ``hymba``  — parallel attention ∥ mamba heads, outputs fused as the mean
               of per-branch RMS-normed outputs (Hymba §2), then MLP
- ``xlstm``  — mLSTM or sLSTM core per layer flag, no separate MLP

Public API:
- ``init_transformer`` / ``transformer_specs`` — params + logical axes
- ``forward``        — full-sequence hidden states (+ MoE aux loss)
- ``loss_fn``        — seq-chunked softmax CE (never materializes (B,S,V))
- ``init_cache`` / ``prefill`` / ``decode_step`` — serving path
- ``layer_flags``    — per-layer pattern flags

``mesh`` is threaded through (None on CPU): when present and
``cfg.moe.impl == "capacity"``, the MoE runs expert-parallel inside
``shard_map`` over the ``model`` axis.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.jax_compat import shard_map
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import activation, lecun_init, rms_norm, layer_norm, rope_table

__all__ = [
    "init_transformer", "transformer_specs", "layer_flags",
    "forward", "loss_fn", "output_head",
    "init_cache", "prefill", "decode_step",
]


# ---------------------------------------------------------------------------
# Flags / patterns
# ---------------------------------------------------------------------------


def layer_flags(cfg) -> dict[str, np.ndarray]:
    pat = (cfg.layer_pattern * cfg.n_layers)[: cfg.n_layers]
    if len(cfg.layer_pattern) == cfg.n_layers:
        pat = cfg.layer_pattern
    is_global = np.array([1.0 if c in "G" else 0.0 for c in pat], np.float32)
    is_mlstm = np.array([1.0 if c == "M" else 0.0 for c in pat], np.float32)
    return {"is_global": is_global, "is_mlstm": is_mlstm}


def _norm(p, cfg, x, name):
    if cfg.norm == "layernorm":
        return layer_norm(x, p[name + "_scale"], p[name + "_bias"], cfg.norm_eps)
    return rms_norm(x, p[name], cfg.norm_eps)


def _init_norm(cfg, name) -> dict:
    if cfg.norm == "layernorm":
        return {
            name + "_scale": jnp.ones((cfg.d_model,), jnp.float32),
            name + "_bias": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    return {name: jnp.zeros((cfg.d_model,), jnp.float32)}


def _norm_specs(cfg, name) -> dict:
    if cfg.norm == "layernorm":
        return {name + "_scale": (None,), name + "_bias": (None,)}
    return {name: (None,)}


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def _init_mlp(key, cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {
        "w_up": lecun_init(ks[0], (d, f), dt),
        "w_down": lecun_init(ks[1], (f, d), dt, fan_in=f),
    }
    if cfg.mlp_activation in ("swiglu", "geglu"):
        p["w_gate"] = lecun_init(ks[2], (d, f), dt)
    return p


def _mlp_specs(cfg) -> dict:
    s = {"w_up": ("embed", "ffn"), "w_down": ("ffn", "embed")}
    if cfg.mlp_activation in ("swiglu", "geglu"):
        s["w_gate"] = ("embed", "ffn")
    return s


def _mlp(p, cfg, x):
    gate = x @ p["w_gate"] if "w_gate" in p else None
    h = activation(cfg.mlp_activation, x @ p["w_up"], gate)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Layer init / specs
# ---------------------------------------------------------------------------


def _init_layer(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    if cfg.block_type == "xlstm":
        return {"xlstm": ssm_mod.init_xlstm(ks[0], cfg), **_init_norm(cfg, "norm1")}
    p = {**_init_norm(cfg, "norm1"), **_init_norm(cfg, "norm2")}
    p["attn"] = attn.init_mla(ks[0], cfg) if cfg.use_mla else attn.init_gqa(ks[0], cfg)
    if cfg.block_type == "hymba":
        p["ssm"] = ssm_mod.init_mamba(ks[1], cfg)
        p["attn_out_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ssm_out_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    p["mlp"] = moe_mod.init_moe(ks[2], cfg) if cfg.moe else _init_mlp(ks[2], cfg)
    return p


def _layer_specs(cfg) -> dict:
    if cfg.block_type == "xlstm":
        return {"xlstm": ssm_mod.xlstm_specs(cfg), **_norm_specs(cfg, "norm1")}
    s = {**_norm_specs(cfg, "norm1"), **_norm_specs(cfg, "norm2")}
    s["attn"] = attn.mla_specs(cfg) if cfg.use_mla else attn.gqa_specs(cfg)
    if cfg.block_type == "hymba":
        s["ssm"] = ssm_mod.mamba_specs(cfg)
        s["attn_out_norm"] = (None,)
        s["ssm_out_norm"] = (None,)
    s["mlp"] = moe_mod.moe_specs(cfg) if cfg.moe else _mlp_specs(cfg)
    return s


# ---------------------------------------------------------------------------
# Model init / specs
# ---------------------------------------------------------------------------


def init_transformer(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    p = {"layers": layers, **_init_norm(cfg, "final_norm")}
    if cfg.input_mode in ("tokens", "vlm"):
        p["embed"] = (
            jax.random.normal(ks[1], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt)
    else:  # frames arrive at d_model from the stub frontend
        p["frame_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["embed"] = (
            jax.random.normal(ks[1], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt)  # output vocab table (EnCodec codes)
    if not cfg.tie_embeddings:
        p["head"] = lecun_init(ks[2], (cfg.d_model, cfg.vocab), dt)
    if cfg.mtp:
        p["mtp_proj"] = lecun_init(ks[3], (cfg.d_model, cfg.d_model), dt)
        p["mtp_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def transformer_specs(cfg) -> dict:
    layers = jax.tree.map(
        lambda axes: ("layers",) + tuple(axes),
        _layer_specs(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )
    s = {"layers": layers, **_norm_specs(cfg, "final_norm")}
    s["embed"] = ("vocab", "embed")
    if cfg.input_mode not in ("tokens", "vlm"):
        s["frame_norm"] = (None,)
    if not cfg.tie_embeddings:
        s["head"] = ("embed", "vocab")
    if cfg.mtp:
        s["mtp_proj"] = ("embed", "embed2")
        s["mtp_norm"] = (None,)
    return s


# ---------------------------------------------------------------------------
# Embedding of modal inputs
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg, batch) -> tuple[jax.Array, jax.Array | None]:
    """batch → (x (B,S,d), loss_mask (B,S) or None).

    tokens: {"tokens": (B,S) int32}
    frames: {"frames": (B,S,d) bf16}             (audio stub frontend)
    vlm:    {"patches": (B,P,d) bf16, "tokens": (B,S−P) int32}
    """
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.tie_embeddings:
            x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
        return x, None
    if cfg.input_mode == "frames":
        x = rms_norm(batch["frames"].astype(jnp.dtype(cfg.dtype)), params["frame_norm"], cfg.norm_eps)
        return x, None
    if cfg.input_mode == "vlm":
        tok = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = jnp.concatenate([batch["patches"].astype(tok.dtype), tok], axis=1)
        bsz, s = x.shape[0], x.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((bsz, cfg.n_patches)), jnp.ones((bsz, s - cfg.n_patches))], axis=1
        )
        return x, mask
    raise ValueError(cfg.input_mode)


def _rope_tables(cfg, seq_len, positions=None):
    """Two (S, rot/2) tables (local theta, global theta).  ``positions``
    (decode) selects single rows."""
    if cfg.use_mla:
        dim = cfg.qk_rope_head_dim
    else:
        hd = cfg.resolved_head_dim
        dim = int(hd * cfg.rope_fraction)
        dim -= dim % 2
    if dim == 0:
        dim = 2
    sin_l, cos_l = rope_table(seq_len, dim, cfg.rope_theta)
    if cfg.rope_theta_global:
        sin_g, cos_g = rope_table(seq_len, dim, cfg.rope_theta_global)
    else:
        sin_g, cos_g = sin_l, cos_l
    if positions is not None:
        def sel(t):
            return jax.lax.dynamic_slice_in_dim(t, positions, 1, axis=0)

        sin_l, cos_l, sin_g, cos_g = sel(sin_l), sel(cos_l), sel(sin_g), sel(cos_g)
    return (sin_l, cos_l), (sin_g, cos_g)


def _select_rope(tabs_l, tabs_g, is_global):
    sin = jnp.where(is_global > 0, tabs_g[0], tabs_l[0])
    cos = jnp.where(is_global > 0, tabs_g[1], tabs_l[1])
    return sin, cos


# ---------------------------------------------------------------------------
# MoE dispatch (impl × mesh)
# ---------------------------------------------------------------------------


def _run_moe(p_mlp, cfg, x, mesh):
    if cfg.moe.impl == "dense" or mesh is None:
        return moe_mod.moe_dense(p_mlp, cfg, x)
    all_axes = tuple(mesh.axis_names)
    n_dev = 1
    for a in all_axes:
        n_dev *= mesh.shape[a]
    tokens = x.shape[0] * x.shape[1]
    if tokens <= 8192 and cfg.moe.n_experts % n_dev == 0:
        # §Perf (decode iteration): full expert parallelism.  At decode the
        # baseline layout FSDP-gathers GBs of expert weights per layer for
        # a handful of tokens; instead keep ONE expert fully resident per
        # device, replicate the (tiny) token batch, psum the combine —
        # collective bytes drop from O(expert weights) to O(tokens·d).
        e_loc = cfg.moe.n_experts // n_dev
        pspec = jax.tree.map(lambda _: P(), p_mlp)
        pspec["w_gate"] = P(all_axes, None, None)
        pspec["w_up"] = P(all_axes, None, None)
        pspec["w_down"] = P(all_axes, None, None)
        xspec = P(*([None] * x.ndim))

        def ep_block(pl, xl):
            b, s, d = xl.shape
            idx = jnp.zeros((), jnp.int32)
            for a in all_axes:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            x2d = xl.reshape(-1, d)
            out2d, aux = moe_mod.moe_capacity(
                pl, cfg, x2d, expert_offset=idx * e_loc, n_local_experts=e_loc,
                include_shared=False,
            )
            out2d = jax.lax.psum(out2d, all_axes)
            if cfg.moe.n_shared:
                out2d = out2d + moe_mod._shared_expert(pl, cfg, x2d)
            return out2d.reshape(b, s, d), aux

        return shard_map(
            ep_block, mesh=mesh, in_specs=(pspec, xspec), out_specs=(xspec, P()),
            check_vma=False,
        )(p_mlp, x)
    if (
        tokens <= 8192
        and cfg.moe.n_experts % mesh.shape["model"] == 0
        and cfg.moe.d_expert % (n_dev // mesh.shape["model"]) == 0
    ):
        # §Perf (decode iteration, few-expert MoE e.g. dbrx): experts over
        # `model`, expert-FFN columns over the data axes.  The gated
        # activation is elementwise over ff columns, so column-parallel
        # expert compute is exact; the combine psum over all axes sums
        # disjoint expert contributions (model) and ff partials (data) —
        # again no per-layer weight gather at decode.
        dp_axes_all = tuple(a for a in all_axes if a != "model")
        e_loc = cfg.moe.n_experts // mesh.shape["model"]
        pspec = jax.tree.map(lambda _: P(), p_mlp)
        pspec["w_gate"] = P("model", None, dp_axes_all)
        pspec["w_up"] = P("model", None, dp_axes_all)
        pspec["w_down"] = P("model", dp_axes_all, None)
        xspec = P(*([None] * x.ndim))

        def tp_block(pl, xl):
            b, s, d = xl.shape
            idx = jax.lax.axis_index("model")
            x2d = xl.reshape(-1, d)
            out2d, aux = moe_mod.moe_capacity(
                pl, cfg, x2d, expert_offset=idx * e_loc, n_local_experts=e_loc,
                include_shared=False,
            )
            out2d = jax.lax.psum(out2d, all_axes)
            if cfg.moe.n_shared:
                out2d = out2d + moe_mod._shared_expert(pl, cfg, x2d)
            return out2d.reshape(b, s, d), aux

        return shard_map(
            tp_block, mesh=mesh, in_specs=(pspec, xspec), out_specs=(xspec, P()),
            check_vma=False,
        )(p_mlp, x)
    if cfg.moe.n_experts % mesh.shape["model"] != 0:
        # cannot expert-shard evenly — replicated capacity path
        out, aux = moe_mod.moe_capacity(p_mlp, cfg, x.reshape(-1, x.shape[-1]))
        return out.reshape(x.shape), aux
    dp_axes = tuple(n for n in mesh.axis_names if n != "model")
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]
    if x.shape[0] % dp_total != 0:
        dp_axes = ()  # batch too small (decode long_500k): replicate tokens
    xspec = P(dp_axes if dp_axes else None, None, None)
    pspec = jax.tree.map(lambda _: P(), p_mlp)
    pspec["w_gate"] = P("model", None, None)
    pspec["w_up"] = P("model", None, None)
    pspec["w_down"] = P("model", None, None)

    def block(pl, xl):
        out, aux = moe_mod.moe_capacity_sharded(pl, cfg, xl, mesh_axis="model")
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return out, aux

    # NOTE (§Perf deepseek iteration 2, refuted hypothesis): the shared
    # expert is computed INSIDE the shard_map, replicated over `model`.
    # Tensor-parallelizing it under GSPMD-auto cut the compute term −36%
    # but the per-token down-proj all-reduce raised the collective term
    # +37% — a net wall-time regression (≈87 ms redundant compute vs
    # ≈118 ms TP+all-reduce per layer on v5e napkin numbers).  Redundant
    # compute beats communication for this thin (d_ff=2048) layer.
    return shard_map(
        block, mesh=mesh, in_specs=(pspec, xspec), out_specs=(xspec, P()),
        check_vma=False,
    )(p_mlp, x)


# ---------------------------------------------------------------------------
# Layer apply (full sequence)
# ---------------------------------------------------------------------------


def _act_constraint(cfg, x, mesh):
    """Optional explicit activation sharding (§Perf iteration 2): pins the
    residual stream to batch-sharded layout so GSPMD does not introduce
    per-op resharding churn (observed as 'involuntary full
    rematerialization' all-gathers under the fsdp policy)."""
    if mesh is None or not cfg.act_shard:
        return x
    from jax.sharding import NamedSharding

    if cfg.act_shard == "dp_all":
        axes = tuple(mesh.axis_names)
    else:  # dp_data
        axes = tuple(a for a in mesh.axis_names if a != "model")
    if x.shape[0] % int(np.prod([mesh.shape[a] for a in axes])) != 0:
        return x
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _apply_layer_seq(pl, cfg, x, flags, tabs_l, tabs_g, mesh):
    """One layer, full sequence.  Returns (x_out, aux, cache_entry)."""
    x = _act_constraint(cfg, x, mesh)
    is_global = flags["is_global"]
    sin, cos = _select_rope(tabs_l, tabs_g, is_global)
    aux = jnp.zeros((), jnp.float32)

    if cfg.block_type == "xlstm":
        h = _norm(pl, cfg, x, "norm1")
        out_m, st_m = ssm_mod.mlstm_seq(pl["xlstm"], cfg, h)
        out_s, st_s = ssm_mod.slstm_seq(pl["xlstm"], cfg, h)
        is_m = flags["is_mlstm"]
        out = jnp.where(is_m > 0, out_m, out_s)
        x = x + out
        cache = {"mlstm": st_m, "slstm": st_s}
        return x, aux, cache

    h = _norm(pl, cfg, x, "norm1")
    if cfg.use_mla:
        a_out, kv = attn.mla_attention(pl["attn"], cfg, h, sin, cos, is_global)
        cache = {"latent": kv[0], "k_rope": kv[1]}
    else:
        a_out, kv = attn.gqa_attention(pl["attn"], cfg, h, sin, cos, is_global)
        cache = {"k": kv[0], "v": kv[1]}

    if cfg.block_type == "hymba":
        s_out, (h_fin, conv_tail) = ssm_mod.mamba_seq(pl["ssm"], cfg, h)
        a_out = 0.5 * (
            rms_norm(a_out, pl["attn_out_norm"], cfg.norm_eps)
            + rms_norm(s_out, pl["ssm_out_norm"], cfg.norm_eps)
        )
        cache.update({"ssm_h": h_fin, "conv": conv_tail})
    x = x + a_out

    h2 = _norm(pl, cfg, x, "norm2")
    if cfg.moe:
        m_out, aux = _run_moe(pl["mlp"], cfg, h2, mesh)
    else:
        m_out = _mlp(pl["mlp"], cfg, h2)
    x = x + m_out
    return x, aux, cache


def forward(params, cfg, batch, mesh=None, collect_cache: bool = False):
    """Full-sequence forward.  Returns (hidden (B,S,d), loss_mask, aux,
    caches-or-None)."""
    x, loss_mask = embed_inputs(params, cfg, batch)
    s = x.shape[1]
    tabs_l, tabs_g = _rope_tables(cfg, s)
    flags = layer_flags(cfg)
    flags_j = {k: jnp.asarray(v) for k, v in flags.items()}

    def body(carry, xs):
        x, aux_acc = carry
        pl, fl = xs
        x, aux, cache = _apply_layer_seq(pl, cfg, x, fl, tabs_l, tabs_g, mesh)
        return (x, aux_acc + aux), (cache if collect_cache else 0)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), caches = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), (params["layers"], flags_j),
        unroll=cfg.scan_unroll,
    )
    x = _norm(params, cfg, x, "final_norm")
    return x, loss_mask, aux / cfg.n_layers, (caches if collect_cache else None)


# ---------------------------------------------------------------------------
# Loss (seq-chunked CE) and logits
# ---------------------------------------------------------------------------


def output_head(params, cfg):
    """The (d_model, vocab) output projection — tied embedding transpose
    or the separate head.  Public so downstream losses (e.g. the
    federated LM task) share one untying rule with ``loss_fn``."""
    return params["embed"].T if cfg.tie_embeddings else params["head"]


def _logits(params, cfg, h):
    return h @ output_head(params, cfg)


def loss_fn(params, cfg, batch, mesh=None):
    """Mean next-token CE, computed over sequence chunks so the full
    (B,S,V) logits tensor never exists.  Returns (loss, metrics)."""
    h, loss_mask, aux, _ = forward(params, cfg, batch, mesh)
    labels = batch["labels"]
    b, s, _ = h.shape
    c = min(cfg.loss_chunk, s)
    nc = s // c
    assert nc * c == s

    mask = loss_mask if loss_mask is not None else jnp.ones((b, s), jnp.float32)

    def chunk_ce(hc, yc, mc):
        logits = _logits(params, cfg, hc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mc), jnp.sum(mc)

    def body(carry, i):
        tot, cnt = carry
        hc = jax.lax.dynamic_slice_in_dim(h, i * c, c, axis=1)
        yc = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        mc = jax.lax.dynamic_slice_in_dim(mask, i * c, c, axis=1)
        t, n = chunk_ce(hc, yc, mc)
        return (tot + t, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), jnp.arange(nc))
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce
    metrics = {"ce": ce, "aux": aux}
    if cfg.moe:
        loss = loss + cfg.moe.router_aux_weight * aux
    if cfg.mtp:
        # Predict t+2 from a light projection of the trunk (DESIGN.md §5).
        h_mtp = rms_norm(h @ params["mtp_proj"], params["mtp_norm"], cfg.norm_eps)
        y2 = jnp.roll(labels, -1, axis=1)
        m2 = mask * (jnp.arange(s) < s - 1)[None, :]

        def body2(carry, i):
            tot, cnt = carry
            hc = jax.lax.dynamic_slice_in_dim(h_mtp, i * c, c, axis=1)
            yc = jax.lax.dynamic_slice_in_dim(y2, i * c, c, axis=1)
            mc = jax.lax.dynamic_slice_in_dim(m2, i * c, c, axis=1)
            t, n = chunk_ce(hc, yc, mc)
            return (tot + t, cnt + n), None

        (tot2, cnt2), _ = jax.lax.scan(body2, (jnp.zeros(()), jnp.zeros(())), jnp.arange(nc))
        mtp_ce = tot2 / jnp.maximum(cnt2, 1.0)
        loss = loss + cfg.mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    """Stacked (L-leading) decode cache for the arch's block type."""
    L = cfg.n_layers
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    if cfg.block_type == "xlstm":
        hh = cfg.ssm.n_heads
        hd = d // hh
        return {
            "mlstm": (
                jnp.zeros((L, batch_size, hh, hd, hd), jnp.float32),
                jnp.zeros((L, batch_size, hh, hd), jnp.float32),
                jnp.full((L, batch_size, hh), -1e30, jnp.float32),
            ),
            "slstm": (
                jnp.zeros((L, batch_size, hh, hd), jnp.float32),
                jnp.zeros((L, batch_size, hh, hd), jnp.float32),
                jnp.full((L, batch_size, hh), -1e30, jnp.float32),
            ),
        }
    cache: dict = {}
    if cfg.use_mla:
        cache["latent"] = jnp.zeros((L, batch_size, max_len, cfg.kv_lora_rank), dt)
        cache["k_rope"] = jnp.zeros((L, batch_size, max_len, cfg.qk_rope_head_dim), dt)
    else:
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cache["k"] = jnp.zeros((L, batch_size, max_len, kv, hd), dt)
        cache["v"] = jnp.zeros((L, batch_size, max_len, kv, hd), dt)
    if cfg.block_type == "hymba":
        n = cfg.ssm.d_state
        cache["ssm_h"] = jnp.zeros((L, batch_size, d, n), jnp.float32)
        cache["conv"] = jnp.zeros((L, batch_size, cfg.ssm.conv_kernel - 1, d), jnp.float32)
    return cache


def cache_specs(cfg) -> dict:
    """Logical axes for the cache pytree (mirrors init_cache)."""
    if cfg.block_type == "xlstm":
        # lists (not tuples) so tree flattening stops at the axis tuples
        return {
            "mlstm": [
                ("layers", "batch", None, None, None),
                ("layers", "batch", None, None),
                ("layers", "batch", None),
            ],
            "slstm": [
                ("layers", "batch", None, None),
                ("layers", "batch", None, None),
                ("layers", "batch", None),
            ],
        }
    s: dict = {}
    if cfg.use_mla:
        s["latent"] = ("layers", "batch", "seq", None)
        s["k_rope"] = ("layers", "batch", "seq", None)
    else:
        s["k"] = ("layers", "batch", "seq", "kv_heads", None)
        s["v"] = ("layers", "batch", "seq", "kv_heads", None)
    if cfg.block_type == "hymba":
        s["ssm_h"] = ("layers", "batch", None, None)
        s["conv"] = ("layers", "batch", None, None)
    return s


def _cache_constraint(cache, mesh):
    """Pin decode-cache leaves to their storage layout (batch over data
    axes when divisible, else seq over data axes, rest replicated) so the
    while-loop carry is not resharded by GSPMD — without this, dbrx-style
    decode gathers the full per-layer KV cache every step (§Perf decode
    iteration)."""
    if mesh is None:
        return cache
    from jax.sharding import NamedSharding

    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]

    def one(leaf):
        if leaf.ndim < 2:
            return leaf
        if leaf.shape[0] % dp_total == 0 and leaf.shape[0] > 1:
            spec = P(dp, *([None] * (leaf.ndim - 1)))
        elif leaf.ndim >= 2 and leaf.shape[1] % dp_total == 0 and leaf.shape[1] > 1:
            spec = P(None, dp, *([None] * (leaf.ndim - 2)))
        else:
            return leaf
        return jax.lax.with_sharding_constraint(leaf, NamedSharding(mesh, spec))

    return jax.tree.map(one, cache)


def _apply_layer_decode(pl, cfg, x, flags, tabs_l, tabs_g, cache_l, pos, mesh):
    cache_l = _cache_constraint(cache_l, mesh)
    is_global = flags["is_global"]
    sin, cos = _select_rope(tabs_l, tabs_g, is_global)

    if cfg.block_type == "xlstm":
        h = _norm(pl, cfg, x, "norm1")
        out_m, st_m = ssm_mod.mlstm_decode(pl["xlstm"], cfg, h, cache_l["mlstm"])
        out_s, st_s = ssm_mod.slstm_decode(pl["xlstm"], cfg, h, cache_l["slstm"])
        is_m = flags["is_mlstm"]
        out = jnp.where(is_m > 0, out_m, out_s)
        # only the active branch's state advances
        st_m = jax.tree.map(lambda new, old: jnp.where(is_m > 0, new, old), st_m, cache_l["mlstm"])
        st_s = jax.tree.map(lambda new, old: jnp.where(is_m > 0, old, new), st_s, cache_l["slstm"])
        return x + out, {"mlstm": st_m, "slstm": st_s}

    h = _norm(pl, cfg, x, "norm1")
    if cfg.use_mla:
        a_out, (lat, kr) = attn.mla_decode(
            pl["attn"], cfg, h, sin, cos, (cache_l["latent"], cache_l["k_rope"]), pos, is_global
        )
        new_cache = {"latent": lat, "k_rope": kr}
    else:
        a_out, (kc, vc) = attn.gqa_decode(
            pl["attn"], cfg, h, sin, cos, (cache_l["k"], cache_l["v"]), pos, is_global
        )
        new_cache = {"k": kc, "v": vc}

    if cfg.block_type == "hymba":
        s_out, (h_new, conv_new) = ssm_mod.mamba_decode(
            pl["ssm"], cfg, h, cache_l["ssm_h"], cache_l["conv"]
        )
        a_out = 0.5 * (
            rms_norm(a_out, pl["attn_out_norm"], cfg.norm_eps)
            + rms_norm(s_out, pl["ssm_out_norm"], cfg.norm_eps)
        )
        new_cache.update({"ssm_h": h_new, "conv": conv_new})
    x = x + a_out

    h2 = _norm(pl, cfg, x, "norm2")
    if cfg.moe:
        m_out, _ = _run_moe(pl["mlp"], cfg, h2, mesh)
    else:
        m_out = _mlp(pl["mlp"], cfg, h2)
    return x + m_out, new_cache


def decode_step(params, cfg, batch, cache, pos, mesh=None):
    """One-token decode.  batch: {"token": (B,1)} or {"frame": (B,1,d)};
    ``pos``: scalar int32 current position.  Returns (logits (B,V), cache)."""
    if cfg.input_mode == "tokens" or (cfg.input_mode == "vlm" and "token" in batch):
        x = jnp.take(params["embed"], batch["token"], axis=0)
        if cfg.tie_embeddings:
            x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
    else:
        x = rms_norm(
            batch["frame"].astype(jnp.dtype(cfg.dtype)), params["frame_norm"], cfg.norm_eps
        )
    # max_len known from cache; rope rows selected at pos
    if cfg.block_type == "xlstm":
        max_len = 1
    elif cfg.use_mla:
        max_len = cache["latent"].shape[2]
    else:
        max_len = cache["k"].shape[2]
    tabs_l, tabs_g = _rope_tables(cfg, max(max_len, 1), positions=pos)
    flags = {k: jnp.asarray(v) for k, v in layer_flags(cfg).items()}

    def body(x, xs):
        pl, fl, cl = xs
        x, new_cache = _apply_layer_decode(pl, cfg, x, fl, tabs_l, tabs_g, cl, pos, mesh)
        return x, _cache_constraint(new_cache, mesh)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, new_cache = jax.lax.scan(
        body_fn, x, (params["layers"], flags, cache), unroll=cfg.scan_unroll
    )
    x = _norm(params, cfg, x, "final_norm")
    logits = _logits(params, cfg, x[:, 0])
    return logits, new_cache


def prefill(params, cfg, batch, max_len: int, mesh=None):
    """Prefill: run the prompt, return (last-position logits, cache padded
    to ``max_len``)."""
    h, _, _, caches = forward(params, cfg, batch, mesh, collect_cache=True)
    b, s, _ = h.shape
    logits = _logits(params, cfg, h[:, -1])
    out = init_cache(cfg, b, max_len)
    if cfg.block_type == "xlstm":
        # caches collected per layer: {"mlstm": (C,n,m), "slstm": ...} stacked on L
        flags = layer_flags(cfg)
        is_m = jnp.asarray(flags["is_mlstm"])

        def sel(new, zero, flag_nd):
            shape = (cfg.n_layers,) + (1,) * (new.ndim - 1)
            return jnp.where(is_m.reshape(shape) > 0 if flag_nd else is_m.reshape(shape) <= 0, new, zero)

        ml = jax.tree.map(lambda n_, z: sel(n_, z, True), caches["mlstm"], out["mlstm"])
        sl = jax.tree.map(lambda n_, z: sel(n_, z, False), caches["slstm"], out["slstm"])
        return logits, {"mlstm": ml, "slstm": sl}
    # sequence caches: place the s prefill entries at [0, s)
    for k in ("latent", "k_rope", "k", "v"):
        if k in out:
            out[k] = jax.lax.dynamic_update_slice_in_dim(
                out[k], caches[k].astype(out[k].dtype), 0, axis=2
            )
    if cfg.block_type == "hymba":
        out["ssm_h"] = caches["ssm_h"]
        out["conv"] = caches["conv"]
    return logits, out
