"""Model zoo: the paper's MLP + a composable transformer stack covering
all 10 assigned architectures (dense / MoE / SSM / hybrid / audio / VLM).

Models are plain pytrees + pure functions (init/apply), so they compose
freely with vmap (federated simulation), pjit (scale-out), and grad.
"""

from repro.models.mlp import init_mlp, mlp_apply, cross_entropy_loss

__all__ = ["init_mlp", "mlp_apply", "cross_entropy_loss"]
