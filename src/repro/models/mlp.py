"""The paper's model: MLP with two hidden layers of 200 neurons (§V-A)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_mlp", "mlp_apply", "cross_entropy_loss", "accuracy"]


def init_mlp(key: jax.Array, sizes: tuple[int, ...] = (784, 200, 200, 10)):
    """He-initialized MLP params: [{'w': (in, out), 'b': (out,)}...]."""
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, (fan_in, fan_out) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (fan_in, fan_out), jnp.float32) * jnp.sqrt(
            2.0 / fan_in
        )
        params.append({"w": w, "b": jnp.zeros((fan_out,), jnp.float32)})
    return params


def mlp_apply(params, x: jax.Array) -> jax.Array:
    """Forward pass; ReLU hidden activations, raw logits out."""
    h = x
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    last = params[-1]
    return h @ last["w"] + last["b"]


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, weights: jax.Array | None = None
) -> jax.Array:
    """Mean CE over (optionally sample-weighted) batch."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    if weights is None:
        return jnp.mean(nll)
    w = weights.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1e-9)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
