"""Shared model building blocks: norms, activations, RoPE, initializers.

Parameter convention: plain nested-dict pytrees of jnp arrays.  Every
module provides ``init(key, ...) -> params`` and a parallel
``specs(...) -> same-structure tree of logical-axis tuples`` consumed by
``repro.sharding`` (structure equality is asserted by tests for all
configs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "layer_norm",
    "activation",
    "rope_table",
    "apply_rope",
    "he_init",
    "lecun_init",
]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with fp32 accumulation (bf16-safe)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(
        jnp.float32
    )
    return out.astype(x.dtype)


def activation(name: str, x: jax.Array, gate: jax.Array | None = None) -> jax.Array:
    """Gated / plain activations.  ``gate`` present → gated variants."""
    if name == "swiglu":
        assert gate is not None
        return jax.nn.silu(gate) * x
    if name == "geglu":
        assert gate is not None
        return jax.nn.gelu(gate) * x
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {name!r}")


def rope_table(seq_len: int, dim: int, theta: float, dtype=jnp.float32):
    """(seq_len, dim/2) sin/cos tables."""
    assert dim % 2 == 0
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    ang = jnp.outer(t, freqs)
    return jnp.sin(ang).astype(dtype), jnp.cos(ang).astype(dtype)


def apply_rope(
    x: jax.Array,             # (..., S, H, D)
    sin: jax.Array,           # (S, rot/2)
    cos: jax.Array,
    rope_fraction: float = 1.0,
) -> jax.Array:
    """Rotary embedding on the leading ``rope_fraction`` of head dims.

    Interleaved-pair convention: (x0, x1) -> (x0 c - x1 s, x0 s + x1 c).
    ``sin``/``cos`` tables may be precomputed for absolute positions (the
    decode path passes 1-row tables for the current position).
    """
    d = x.shape[-1]
    rot = int(d * rope_fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    xf = xr.astype(jnp.float32).reshape(*xr.shape[:-1], rot // 2, 2)
    x0, x1 = xf[..., 0], xf[..., 1]
    # broadcast tables over batch and heads: (S, rot/2) -> (..., S, 1, rot/2)
    s = sin[: x.shape[-3], None, :].astype(jnp.float32)
    c = cos[: x.shape[-3], None, :].astype(jnp.float32)
    y0 = x0 * c - x1 * s
    y1 = x0 * s + x1 * c
    y = jnp.stack([y0, y1], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([y, xp], axis=-1)


def he_init(key, shape, dtype=jnp.bfloat16):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return (jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)).astype(dtype)


def lecun_init(key, shape, dtype=jnp.bfloat16, fan_in: int | None = None):
    fan_in = fan_in or (shape[-2] if len(shape) >= 2 else shape[-1])
    return (jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(1.0 / fan_in)).astype(dtype)
