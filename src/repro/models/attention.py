"""Attention: GQA (+qk-norm, partial RoPE, sliding window) and MLA.

Three compute paths:

- ``naive_attention``  — materializes S×S scores; oracle for tests.
- ``flash_attention``  — double-scan online-softmax (query chunks ×
    kv chunks), O(S·chunk) memory: this is what lets prefill_32k lower
    without S² temporaries.  Pure JAX (the Pallas twin lives in
    ``repro.kernels.flash_attention`` and is TPU-only).
- ``decode_attention`` — one query position against a (possibly
    window-masked) KV cache.

Sliding-window blending: layer heterogeneity (gemma3's 5:1 local:global
pattern) enters through the *scalar* ``is_global`` flag in the mask
arithmetic — one scan over stacked layers, no S×S masks materialized
(DESIGN.md §8.1).

MLA (deepseek-v3): low-rank Q/KV projections with a decoupled shared
RoPE key.  Prefill materializes per-head K/V; decode uses the absorbed
formulation so the cache holds only (kv_lora_rank + rope_dim) per token
— the 9.6× KV-cache compression that makes long_500k cheap for a 671B
model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, lecun_init, rms_norm

__all__ = [
    "init_gqa", "gqa_specs", "gqa_attention", "gqa_decode",
    "init_mla", "mla_specs", "mla_attention", "mla_decode",
    "naive_attention", "flash_attention", "decode_attention",
]

_NEG = -1e30


def _mask_val(qpos, kpos, window, is_global):
    """Additive mask: causal ∧ (global ∨ within window).  ``is_global`` is
    a traced scalar (0/1) so heterogeneous layer patterns blend into one
    formula."""
    causal = kpos <= qpos
    if window and window > 0:
        in_window = (qpos - kpos) < window
        ok = causal & (in_window | (is_global > 0))
    else:
        ok = causal
    return jnp.where(ok, 0.0, _NEG)


# ---------------------------------------------------------------------------
# Core attention maths (GQA layout: q (B,S,KV,G,D), k/v (B,S,KV,D))
# ---------------------------------------------------------------------------


def naive_attention(q, k, v, window: int = 0, is_global=1.0) -> jax.Array:
    """Oracle: full S×S scores.  q (B,Sq,H,Dk), k (B,Sk,KV,Dk),
    v (B,Sk,KV,Dv) — Dv may differ from Dk (MLA)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    dv = v.shape[-1]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    scores = scores + _mask_val(qpos, kpos, window, is_global)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def flash_attention(
    q, k, v, window: int = 0, is_global=1.0, chunk_q: int = 512, chunk_k: int = 512
) -> jax.Array:
    """Online-softmax attention, O(Sq·chunk_k) memory.  Causal.

    q (B,Sq,H,Dk) with H = KV·G; k (B,Sk,KV,Dk); v (B,Sk,KV,Dv) — Dv may
    differ from Dk (MLA uses 128-dim values under 192-dim keys).  Sq/Sk
    must divide by the chunk sizes (configs guarantee this).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kv
    cq, ck = min(chunk_q, sq), min(chunk_k, sk)
    nq, nk = sq // cq, sk // ck
    assert nq * cq == sq and nk * ck == sk, (sq, sk, cq, ck)

    qg = q.reshape(b, nq, cq, kv, g, d)
    kg = k.reshape(b, nk, ck, kv, d)
    vg = v.reshape(b, nk, ck, kv, dv)
    scale = 1.0 / jnp.sqrt(d)

    def q_block(qi, q_blk):
        # online softmax state over kv chunks
        m0 = jnp.full((b, kv, g, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, kv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, cq, dv), jnp.float32)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk, v_blk = kg[:, ki], vg[:, ki]
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs", q_blk.astype(jnp.float32), k_blk.astype(jnp.float32)
            ) * scale
            qpos = qi * cq + jnp.arange(cq)[:, None]
            kpos = ki * ck + jnp.arange(ck)[None, :]
            s = s + _mask_val(qpos, kpos, window, is_global)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (b,kv,g,cq,d) -> (b,cq,kv,g,d)
        return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(q.dtype)

    blocks = jax.lax.map(lambda qi: q_block(qi, qg[:, qi]), jnp.arange(nq))
    # (nq, b, cq, kv, g, dv) -> (b, sq, h, dv)
    out = jnp.transpose(blocks, (1, 0, 2, 3, 4, 5)).reshape(b, sq, h, dv)
    return out


def decode_attention(q, k_cache, v_cache, pos, window: int = 0, is_global=1.0):
    """One-token attention: q (B,1,H,D) vs cache (B,S,KV,D); ``pos`` is the
    current position (cache entries > pos are invalid)."""
    b, _, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, d)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) / jnp.sqrt(d)
    kpos = jnp.arange(s)[None, None, None, :]
    scores = scores + _mask_val(pos, kpos, window, is_global)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module (params + apply)
# ---------------------------------------------------------------------------


def init_gqa(key, cfg) -> dict:
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": lecun_init(ks[0], (d, h * hd), dt),
        "wk": lecun_init(ks[1], (d, kv * hd), dt),
        "wv": lecun_init(ks[2], (d, kv * hd), dt),
        "wo": lecun_init(ks[3], (h * hd, d), dt, fan_in=h * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def gqa_specs(cfg) -> dict:
    s = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "heads"),
        "wv": ("embed", "heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qk_norm:
        s["q_norm"] = (None,)
        s["k_norm"] = (None,)
    return s


def _project_qkv(p, cfg, x, sin, cos):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, sin, cos, cfg.rope_fraction)
    k = apply_rope(k, sin, cos, cfg.rope_fraction)
    return q, k, v


def gqa_attention(p, cfg, x, sin, cos, is_global=1.0):
    """Full-sequence (train/prefill).  Returns (out, (k, v)) — the k/v pair
    becomes the layer's decode cache."""
    q, k, v = _project_qkv(p, cfg, x, sin, cos)
    w = cfg.sliding_window
    if cfg.attn_impl == "naive":
        o = naive_attention(q, k, v, w, is_global)
    else:
        o = flash_attention(q, k, v, w, is_global, cfg.attn_chunk, cfg.attn_chunk)
    b, s = x.shape[:2]
    out = o.reshape(b, s, -1) @ p["wo"]
    return out, (k, v)


def gqa_decode(p, cfg, x, sin_pos, cos_pos, cache, pos, is_global=1.0):
    """One-token decode.  ``cache`` = (k_cache, v_cache) (B,Smax,KV,hd);
    ``sin_pos/cos_pos`` are 1-row RoPE tables for the current position."""
    k_cache, v_cache = cache
    q, k_new, v_new = _project_qkv(p, cfg, x, sin_pos, cos_pos)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    o = decode_attention(q, k_cache, v_cache, pos, cfg.sliding_window, is_global)
    out = o.reshape(x.shape[0], 1, -1) @ p["wo"]
    return out, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA (deepseek-v3)
# ---------------------------------------------------------------------------


def init_mla(key, cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    return {
        "wq_a": lecun_init(ks[0], (d, rq), dt),
        "q_norm": jnp.zeros((rq,), jnp.float32),
        "wq_b": lecun_init(ks[1], (rq, h * (nope + rope)), dt),
        "wkv_a": lecun_init(ks[2], (d, rkv + rope), dt),
        "kv_norm": jnp.zeros((rkv,), jnp.float32),
        "wkv_b": lecun_init(ks[3], (rkv, h * (nope + vd)), dt),
        "wo": lecun_init(ks[4], (h * vd, d), dt, fan_in=h * vd),
    }


def mla_specs(cfg) -> dict:
    return {
        "wq_a": ("embed", "q_lora"),
        "q_norm": (None,),
        "wq_b": ("q_lora", "heads"),
        "wkv_a": ("embed", None),
        "kv_norm": (None,),
        "wkv_b": ("kv_lora", "heads"),
        "wo": ("heads", "embed"),
    }


def _mla_qkv_latent(p, cfg, x, sin, cos):
    """Shared front: q heads (nope+rope) + normalized latent + rotated shared k_rope."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, sin, cos)
    kv_a = x @ p["wkv_a"]
    latent = rms_norm(kv_a[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., cfg.kv_lora_rank :][:, :, None, :], sin, cos)[:, :, 0]
    return q_nope, q_rope, latent, k_rope


def mla_attention(p, cfg, x, sin, cos, is_global=1.0):
    """Prefill/train: materialize per-head K/V from the latent; returns
    (out, (latent, k_rope)) — the compressed decode cache."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, latent, k_rope = _mla_qkv_latent(p, cfg, x, sin, cos)
    kvb = (latent @ p["wkv_b"]).reshape(b, s, h, nope + vd)
    k_nope, v = kvb[..., :nope], kvb[..., nope:]
    # Assemble MHA-layout q/k (KV = H) with the shared rope-key broadcast.
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rope))], axis=-1
    )
    # §Perf (deepseek iteration): values stay at their native head dim —
    # the old path zero-padded v from 128 to 192 dims, inflating the PV
    # matmul and accumulator by 1.5×.
    if cfg.attn_impl == "naive":
        o = naive_attention(q_full, k_full, v, cfg.sliding_window, is_global)
    else:
        o = flash_attention(
            q_full, k_full, v, cfg.sliding_window, is_global,
            cfg.attn_chunk, cfg.attn_chunk,
        )
    out = o.reshape(b, s, h * vd) @ p["wo"]
    return out, (latent, k_rope)


def mla_decode(p, cfg, x, sin_pos, cos_pos, cache, pos, is_global=1.0):
    """Absorbed-matmul decode: scores against the latent cache directly.

    cache = (latent (B,Smax,rkv), k_rope (B,Smax,rope)).
    """
    b = x.shape[0]
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    latent_c, krope_c = cache
    q_nope, q_rope, latent_new, krope_new = _mla_qkv_latent(p, cfg, x, sin_pos, cos_pos)
    latent_c = jax.lax.dynamic_update_slice_in_dim(
        latent_c, latent_new.astype(latent_c.dtype), pos, axis=1
    )
    krope_c = jax.lax.dynamic_update_slice_in_dim(
        krope_c, krope_new.astype(krope_c.dtype), pos, axis=1
    )
    # Absorb W^{KV_b,K} into q: q_lat (B,H,rkv)
    wkv_b = p["wkv_b"].reshape(rkv, h, nope + vd)
    wk = wkv_b[..., :nope]          # (rkv, H, nope)
    wv = wkv_b[..., nope:]          # (rkv, H, vd)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32), wk.astype(jnp.float32))
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat, latent_c.astype(jnp.float32))
    s_rope = jnp.einsum(
        "bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32), krope_c.astype(jnp.float32)
    )
    scores = (s_lat + s_rope) / jnp.sqrt(nope + rope)
    kpos = jnp.arange(latent_c.shape[1])[None, None, :]
    scores = scores + _mask_val(pos, kpos, cfg.sliding_window, is_global)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", probs, latent_c.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", ctx_lat, wv.astype(jnp.float32))  # (B,H,vd)
    out = o.reshape(b, 1, h * vd).astype(x.dtype) @ p["wo"]
    return out, (latent_c, krope_c)
