"""``PopulationConfig`` — the validated, JSON-safe slot behind
``FLConfig.population`` (DESIGN.md §15).

Like the systems / async / fault axes, everything here must survive
``FLConfig.to_dict()`` / ``from_dict`` round-tripping, so the fields are
plain scalars; the heavyweight runtime objects (the client store, the
shard hierarchy) are built at engine construction.

The axis makes per-round cost *cohort*-proportional: the population is
partitioned into ``n_shards`` contiguous shards, each round materializes
only ``shards_per_round`` of them (the *resident* set, picked by the
shard-level Algorithm 1 in ``repro.population.hierarchy``), and the
strategy's usual selection runs inside the resident set.  ``n_shards=1``
with ``shards_per_round=1`` keeps every client resident every round and
is bit-identical to the flat engine (the conformance cells pin this).
"""

from __future__ import annotations

from dataclasses import dataclass, fields

__all__ = ["PopulationConfig"]


@dataclass
class PopulationConfig:
    """The population-scale axis of one federated experiment.

    - ``n_shards`` — contiguous, near-equal shards the K clients are
      split into (``np.array_split`` layout, owned by the store).
    - ``shards_per_round`` — shards resident per round; per-round
      polling, gathering, and training touch only their members.
    - ``j_shards`` — Algorithm 1's J at the *shard* level: shards are
      clustered by summary histogram, shard clusters ranked by mean
      estimated loss, and the resident set drawn from the top
      ``j_shards`` clusters (backfilling like the client-level rule).
    - ``min_samples`` — OPTICS ``min_samples`` for the shard-summary
      clustering (clamped to the shard count).
    """

    n_shards: int = 1
    shards_per_round: int = 1
    j_shards: int = 3
    min_samples: int = 3

    def __post_init__(self) -> None:
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if not 1 <= self.shards_per_round <= self.n_shards:
            raise ValueError(
                f"shards_per_round must be in [1, n_shards="
                f"{self.n_shards}], got {self.shards_per_round}"
            )
        if self.j_shards < 1:
            raise ValueError(f"j_shards must be >= 1, got {self.j_shards}")
        if self.min_samples < 1:
            raise ValueError(
                f"min_samples must be >= 1, got {self.min_samples}"
            )

    @classmethod
    def from_dict(cls, d: dict) -> "PopulationConfig":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown PopulationConfig keys: {sorted(unknown)}"
            )
        return cls(**d)
