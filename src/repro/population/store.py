"""Sharded client-dataset stores (DESIGN.md §15).

Every engine backend today keeps the packed (K, N_max, ...) client
stacks device-resident and gathers cohorts out of them with ``jnp.take``
— per-round *compute* is cohort-proportional (PR 4) but per-round
*memory* is population-proportional.  A ``ClientStore`` inverts that:
the population lives host-side (or is synthesized on demand, shard by
shard), and only the rows a round actually touches — the resident
shards' poll subset and the dispatched cohort — are ever device-put.

Two implementations:

- ``InMemoryStore``  — wraps today's packed numpy arrays.  Same data,
  same gather semantics; the full stack simply stays in host RAM
  instead of device memory.
- ``ShardedStore``   — materializes shards lazily through a
  ``ShardLoader`` (deterministic per ``(seed, shard)``: reloading an
  evicted shard is bit-identical), with an optional LRU bound on the
  cached shard count.  ``summary()`` provides per-client sizes and
  label histograms *without* materializing features, which is what the
  hierarchy clusters on — so a 10⁶-client run only ever synthesizes the
  shards the shard-level Algorithm 1 actually selects (the
  ``materialized_shards`` assertion in tests pins this).

Shard layout is contiguous ``np.array_split`` blocks — deterministic,
order-preserving, and sizes differing by at most one — shared by both
stores so a ``ShardedStore`` and the ``InMemoryStore`` over its
materialized union gather bit-identical cohorts.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ClientStore",
    "InMemoryStore",
    "ShardedStore",
    "ShardData",
    "ShardLoader",
    "SyntheticShardLoader",
    "shard_layout",
    "materialize_store",
    "POPULATION_DATA_STREAM",
]

# Child-stream tag for per-shard data synthesis: shard s of a run seeded
# ``seed`` draws from default_rng([seed, POPULATION_DATA_STREAM, s, ...])
# — independent of every engine stream and of the shard-selection stream
# (repro.population.hierarchy.POPULATION_SELECT_STREAM).
POPULATION_DATA_STREAM = 0x5E3D_0005


def shard_layout(n_clients: int, n_shards: int) -> list[np.ndarray]:
    """Contiguous near-equal shard membership (sizes differ by <= 1)."""
    if not 1 <= n_shards <= n_clients:
        raise ValueError(
            f"n_shards must be in [1, n_clients={n_clients}], got {n_shards}"
        )
    return [
        np.asarray(a, np.int64)
        for a in np.array_split(np.arange(n_clients, dtype=np.int64), n_shards)
    ]


class ShardData(NamedTuple):
    """One materialized shard: packed member rows (pack_clients layout —
    padding repeats the first sample, the mask zeroes it out)."""

    xs: np.ndarray     # (n, N_max, ...) features
    ys: np.ndarray     # (n, N_max, ...) labels
    mask: np.ndarray   # (n, N_max) float32 validity
    sizes: np.ndarray  # (n,) int64 true sample counts
    hists: np.ndarray  # (n, C) normalized label histograms


class ClientStore:
    """Population-side data access: shard membership, per-client
    summaries, and cohort gathers.  The engine (and the hierarchy) only
    ever talk to this interface, so the flat in-memory population and
    the lazily synthesized one are interchangeable."""

    n_clients: int
    n_shards: int

    def shard_members(self, shard: int) -> np.ndarray:
        """(n,) global client indices of ``shard``."""
        raise NotImplementedError

    def client_sizes(self) -> np.ndarray:
        """(K,) per-client sample counts (summary — never materializes
        features)."""
        raise NotImplementedError

    def client_hists(self) -> np.ndarray:
        """(K, C) normalized label histograms (summary)."""
        raise NotImplementedError

    def shard_hists(self) -> np.ndarray:
        """(S, C) shard summary histograms: the size-weighted mix of the
        member histograms, renormalized — what the hierarchy clusters."""
        sizes = np.asarray(self.client_sizes(), np.float64)
        hists = np.asarray(self.client_hists(), np.float64)
        out = np.stack(
            [
                (hists[m] * sizes[m, None]).sum(axis=0)
                for m in (self.shard_members(s) for s in range(self.n_shards))
            ]
        )
        return out / np.maximum(out.sum(axis=1, keepdims=True), 1e-12)

    def gather(
        self, indices
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Device-put packed rows for the given global client indices,
        in the given order: ``(xs, ys, mask)`` each with leading axis
        ``len(indices)``.  This is the only path by which client data
        reaches the device."""
        raise NotImplementedError

    def materialized_shards(self) -> tuple[int, ...]:
        """Shards whose *feature data* was ever materialized (sorted).
        The population-proportionality proof obligation: under
        hierarchical selection this stays the union of the resident
        sets, not the full shard range."""
        raise NotImplementedError


class InMemoryStore(ClientStore):
    """Today's packed arrays behind the store interface, kept host-side."""

    def __init__(self, xs, ys, mask, sizes, hists, n_shards: int = 1):
        self._xs = np.asarray(xs)
        self._ys = np.asarray(ys)
        self._mask = np.asarray(mask)
        self._sizes = np.asarray(sizes, np.int64)
        self._hists = np.asarray(hists)
        self.n_clients = int(self._xs.shape[0])
        for name, arr in (("ys", self._ys), ("mask", self._mask),
                          ("sizes", self._sizes), ("hists", self._hists)):
            if arr.shape[0] != self.n_clients:
                raise ValueError(
                    f"InMemoryStore {name} leading axis {arr.shape[0]} != "
                    f"n_clients {self.n_clients}"
                )
        self._shards = shard_layout(self.n_clients, n_shards)
        self.n_shards = len(self._shards)

    def shard_members(self, shard: int) -> np.ndarray:
        return self._shards[shard]

    def client_sizes(self) -> np.ndarray:
        return self._sizes

    def client_hists(self) -> np.ndarray:
        return self._hists

    def gather(self, indices):
        idx = np.asarray(indices, np.int64)
        return (
            jnp.asarray(self._xs[idx]),
            jnp.asarray(self._ys[idx]),
            jnp.asarray(self._mask[idx]),
        )

    def materialized_shards(self) -> tuple[int, ...]:
        # the whole population is resident by construction
        return tuple(range(self.n_shards))


class ShardLoader:
    """Materializes one shard's client data, deterministically per
    ``(seed, shard)``.  ``summary`` returns the cheap per-client
    ``(sizes, hists)`` pair without touching features — the default
    derives it from a full ``load``, but loaders that *can* separate the
    label stream from the feature stream (``SyntheticShardLoader``)
    override it, which is what keeps unselected shards unmaterialized."""

    def load(self, shard: int, members: np.ndarray) -> ShardData:
        raise NotImplementedError

    def summary(
        self, shard: int, members: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        d = self.load(shard, members)
        return d.sizes, d.hists


class SyntheticShardLoader(ShardLoader):
    """Label-skewed synthetic clients, synthesized shard by shard.

    Each client gets a dominant class (drawn per client) and a sample
    count in ``samples``; a sample is its class prototype plus Gaussian
    noise (the ``make_classification`` recipe without the image blur —
    prototypes are fixed by ``proto_seed``, shared across shards, so all
    shards pose one task).  Labels and features draw from *separate*
    child streams of ``(seed, shard)``:

    - labels:   ``default_rng([seed, POPULATION_DATA_STREAM, shard, 0])``
    - features: ``default_rng([seed, POPULATION_DATA_STREAM, shard, 1])``

    so ``summary`` replays only the label stream — bit-identical to the
    labels inside ``load`` — while features are synthesized exactly for
    the shards a round materializes.
    """

    def __init__(self, *, n_features: int = 64, n_classes: int = 10,
                 samples: tuple[int, int] = (8, 16), skew: float = 0.8,
                 noise: float = 0.3, seed: int = 0, proto_seed: int = 1234):
        if not 1 <= samples[0] <= samples[1]:
            raise ValueError(
                f"samples must be (lo, hi) with 1 <= lo <= hi, got {samples}"
            )
        if not 0.0 <= skew <= 1.0:
            raise ValueError(f"skew must be in [0, 1], got {skew}")
        self.n_features = int(n_features)
        self.n_classes = int(n_classes)
        self.samples = (int(samples[0]), int(samples[1]))
        self.skew = float(skew)
        self.noise = float(noise)
        self.seed = int(seed) & 0xFFFF_FFFF
        proto_rng = np.random.default_rng(proto_seed)
        self.protos = proto_rng.normal(
            0.0, 1.0, size=(self.n_classes, self.n_features)
        ).astype(np.float32)

    def _label_rng(self, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            [self.seed, POPULATION_DATA_STREAM, int(shard), 0]
        )

    def _feature_rng(self, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            [self.seed, POPULATION_DATA_STREAM, int(shard), 1]
        )

    def _labels(
        self, shard: int, n: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(sizes, ys, mask) for the shard's n clients — the label-only
        prefix shared bit-for-bit by ``summary`` and ``load``."""
        rng = self._label_rng(shard)
        lo, hi = self.samples
        sizes = rng.integers(lo, hi + 1, size=n).astype(np.int64)
        dom = rng.integers(0, self.n_classes, size=n)
        ys = np.where(
            rng.random((n, hi)) < self.skew,
            dom[:, None],
            rng.integers(0, self.n_classes, size=(n, hi)),
        ).astype(np.int32)
        mask = (np.arange(hi)[None, :] < sizes[:, None]).astype(np.float32)
        # pack_clients convention: padding repeats the first sample
        ys = np.where(mask > 0, ys, ys[:, :1])
        return sizes, ys, mask

    def summary(self, shard: int, members: np.ndarray):
        n = len(members)
        sizes, ys, mask = self._labels(shard, n)
        hists = np.zeros((n, self.n_classes), np.float64)
        rows = np.repeat(np.arange(n), ys.shape[1])
        np.add.at(hists, (rows, ys.ravel()), mask.ravel())
        hists = hists / np.maximum(hists.sum(axis=1, keepdims=True), 1e-12)
        return sizes, hists

    def load(self, shard: int, members: np.ndarray) -> ShardData:
        n = len(members)
        sizes, ys, mask = self._labels(shard, n)
        hists = self.summary(shard, members)[1]
        frng = self._feature_rng(shard)
        hi = self.samples[1]
        xs = self.protos[ys] + frng.normal(
            0.0, self.noise, size=(n, hi, self.n_features)
        ).astype(np.float32)
        return ShardData(
            xs=xs.astype(np.float32), ys=ys, mask=mask, sizes=sizes,
            hists=hists,
        )


class ShardedStore(ClientStore):
    """Lazy shard materialization with an optional LRU cache bound.

    Summaries (sizes, histograms) come from ``ShardLoader.summary`` for
    all shards up front — they are the O(K·C) metadata clients ship the
    server once (the comm ledger already counts them) — but *feature
    data* materializes only when ``gather`` touches a shard.  Reloading
    an evicted shard is bit-identical (loader determinism per
    ``(seed, shard)``), so the cache bound trades host RAM for reload
    compute without changing any result.
    """

    def __init__(self, loader: ShardLoader, n_clients: int, n_shards: int,
                 max_cached_shards: int | None = None):
        if max_cached_shards is not None and max_cached_shards < 1:
            raise ValueError(
                f"max_cached_shards must be >= 1 or None, got "
                f"{max_cached_shards}"
            )
        self.loader = loader
        self.n_clients = int(n_clients)
        self._shards = shard_layout(self.n_clients, n_shards)
        self.n_shards = len(self._shards)
        self.max_cached_shards = max_cached_shards
        self._cache: OrderedDict[int, ShardData] = OrderedDict()
        self._ever_loaded: set[int] = set()
        self.load_count = 0
        # global index → (shard, local row)
        self._shard_of = np.empty(self.n_clients, np.int64)
        self._local_of = np.empty(self.n_clients, np.int64)
        for s, m in enumerate(self._shards):
            self._shard_of[m] = s
            self._local_of[m] = np.arange(len(m))
        sizes, hists = [], []
        for s, m in enumerate(self._shards):
            sz, h = loader.summary(s, m)
            sizes.append(np.asarray(sz, np.int64))
            hists.append(np.asarray(h))
        self._sizes = np.concatenate(sizes)
        self._hists = np.concatenate(hists, axis=0)

    def shard_members(self, shard: int) -> np.ndarray:
        return self._shards[shard]

    def client_sizes(self) -> np.ndarray:
        return self._sizes

    def client_hists(self) -> np.ndarray:
        return self._hists

    def _materialize(self, shard: int) -> ShardData:
        if shard in self._cache:
            self._cache.move_to_end(shard)
            return self._cache[shard]
        data = self.loader.load(shard, self._shards[shard])
        if data.xs.shape[0] != len(self._shards[shard]):
            raise ValueError(
                f"loader returned {data.xs.shape[0]} rows for shard "
                f"{shard} with {len(self._shards[shard])} members"
            )
        self._cache[shard] = data
        self._ever_loaded.add(shard)
        self.load_count += 1
        if (self.max_cached_shards is not None
                and len(self._cache) > self.max_cached_shards):
            self._cache.popitem(last=False)
        return data

    def gather(self, indices):
        idx = np.asarray(indices, np.int64)
        shards = self._shard_of[idx]
        locals_ = self._local_of[idx]
        xs_rows: dict[int, np.ndarray] = {}
        ys_rows: dict[int, np.ndarray] = {}
        mk_rows: dict[int, np.ndarray] = {}
        for s in np.unique(shards):
            data = self._materialize(int(s))
            for pos in np.flatnonzero(shards == s):
                li = locals_[pos]
                xs_rows[int(pos)] = data.xs[li]
                ys_rows[int(pos)] = data.ys[li]
                mk_rows[int(pos)] = data.mask[li]
        order = range(len(idx))
        return (
            jnp.asarray(np.stack([xs_rows[i] for i in order])),
            jnp.asarray(np.stack([ys_rows[i] for i in order])),
            jnp.asarray(np.stack([mk_rows[i] for i in order])),
        )

    def cached_shards(self) -> tuple[int, ...]:
        """Shards currently held in the LRU cache (sorted)."""
        return tuple(sorted(self._cache))

    def materialized_shards(self) -> tuple[int, ...]:
        return tuple(sorted(self._ever_loaded))


def materialize_store(store: ShardedStore, n_shards: int | None = None
                      ) -> InMemoryStore:
    """Load *every* shard of a ``ShardedStore`` into one
    ``InMemoryStore`` (test/reference path for the ≡ cohort bit-identity
    property; obviously defeats laziness)."""
    parts = [store._materialize(s) for s in range(store.n_shards)]
    return InMemoryStore(
        xs=np.concatenate([p.xs for p in parts]),
        ys=np.concatenate([p.ys for p in parts]),
        mask=np.concatenate([p.mask for p in parts]),
        sizes=np.concatenate([p.sizes for p in parts]),
        hists=np.concatenate([p.hists for p in parts]),
        n_shards=n_shards if n_shards is not None else store.n_shards,
    )
