"""Two-level hierarchical selection (DESIGN.md §15).

FedLECC's Algorithm 1 — cluster, rank clusters by mean loss, pick within
the top clusters — decomposes over shards: apply the *same rule one
level up*, with shards in place of clients.  ``HierarchicalSelector``
owns that level:

1. **Shard clustering** (once, at construction): shards are clustered by
   their summary histograms — OPTICS over the blocked HD matrix when the
   shard count is small enough to afford S², the on-demand k-medoids
   (``kmedoids_hists``) beyond that, so construction never materializes
   S² either.
2. **Shard ranking** (per round): shards carry a running mean-loss
   estimate, updated from each round's polled resident losses.
   Unexplored shards hold ``+inf`` — Algorithm 1 ranks descending, so
   every shard gets polled before any is revisited (explore-first).
   Loss-blind strategies instead draw per-round shard scores from a
   dedicated child stream, never touching the engine's selection rng.
3. **Resident set**: ``fedlecc_select`` over (shard labels, shard
   scores) picks ``shards_per_round`` shards; their members are the only
   clients polled, gathered, or trained this round.  The engine marks
   everyone else ``-inf`` through the same admission gate the systems
   and fault axes use, so every strategy composes unchanged.

With one shard there is nothing to rank — no stream is drawn, every
client is resident, and the round is bit-identical to the flat engine
(conformance cells pin this per strategy, on host and compiled).
"""

from __future__ import annotations

import numpy as np

from repro.core.selection import fedlecc_select
from repro.population.config import PopulationConfig
from repro.population.store import ClientStore

__all__ = ["HierarchicalSelector", "POPULATION_SELECT_STREAM"]

# Child-stream tag for loss-blind per-round shard scores:
# default_rng([seed, POPULATION_SELECT_STREAM, round]).  Distinct from
# the data-synthesis tag so shard contents and shard choices are
# independent streams.
POPULATION_SELECT_STREAM = 0x5E3D_0006

# OPTICS consumes the dense S x S matrix; past this shard count the
# hierarchy switches to the O(S·k)-memory k-medoids over on-demand
# distances.
_OPTICS_MAX_SHARDS = 2048


class HierarchicalSelector:
    """The shard level of the two-level Algorithm 1 (DESIGN.md §15)."""

    def __init__(self, cfg: PopulationConfig, store: ClientStore, *,
                 seed: int = 0, needs_losses: bool = True):
        if store.n_shards != cfg.n_shards:
            raise ValueError(
                f"store has {store.n_shards} shards but PopulationConfig "
                f"says {cfg.n_shards}"
            )
        self.cfg = cfg
        self.store = store
        self.seed = int(seed) & 0xFFFF_FFFF
        self.needs_losses = bool(needs_losses)
        s = cfg.n_shards
        if s == 1:
            self.shard_labels = np.zeros(1, np.int64)
        elif s <= _OPTICS_MAX_SHARDS:
            from repro.core.clustering import cluster_label_histograms

            self.shard_labels, _ = cluster_label_histograms(
                store.shard_hists(),
                min_samples=min(cfg.min_samples, s),
            )
        else:
            from repro.core.clustering import kmedoids_hists

            self.shard_labels = kmedoids_hists(
                store.shard_hists(), k=max(8, s // 64), seed=seed
            )
        self.n_shard_clusters = int(self.shard_labels.max()) + 1
        # running mean-loss estimate per shard; +inf = never polled,
        # which ranks first under Algorithm 1's descending order
        self.estimates = np.full(s, np.inf, np.float64)
        self._resident_shards: np.ndarray | None = None
        self._resident_members: np.ndarray | None = None

    # ------------------------------------------------------------------
    def choose_shards(self, rnd: int) -> np.ndarray:
        """Sorted shard ids resident at round ``rnd``."""
        s, r = self.cfg.n_shards, self.cfg.shards_per_round
        if r >= s:
            return np.arange(s, dtype=np.int64)
        if self.needs_losses:
            scores = self.estimates
        else:
            rng = np.random.default_rng(
                [self.seed, POPULATION_SELECT_STREAM, int(rnd)]
            )
            scores = rng.random(s)
        return fedlecc_select(
            self.shard_labels, scores, m=r,
            J=min(self.cfg.j_shards, self.n_shard_clusters),
        )

    def begin_round(self, rnd: int) -> tuple[np.ndarray, np.ndarray]:
        """Pick the round's resident shards; returns ``(shards,
        members)`` with ``members`` the sorted global client indices
        (sorted because shards are contiguous index blocks)."""
        shards = self.choose_shards(rnd)
        members = np.concatenate(
            [self.store.shard_members(int(s)) for s in shards]
        )
        self._resident_shards = shards
        self._resident_members = members
        return shards, members

    def resident_mask(self) -> np.ndarray:
        """(K,) bool — this round's resident clients (the extra
        admission gate the engine ANDs into ``_gated_losses``)."""
        if self._resident_members is None:
            raise RuntimeError("resident_mask before begin_round")
        mask = np.zeros(self.store.n_clients, bool)
        mask[self._resident_members] = True
        return mask

    def observe(self, losses: np.ndarray) -> None:
        """Fold the round's polled (K,) losses into the resident shards'
        running estimates.  Non-resident / gated entries are ``-inf`` or
        ``nan``-free by construction; only finite member losses count —
        a fully offline shard keeps its previous estimate."""
        if not self.needs_losses or self._resident_shards is None:
            return
        for s in self._resident_shards:
            ls = np.asarray(losses)[self.store.shard_members(int(s))]
            finite = np.isfinite(ls)
            if finite.any():
                self.estimates[int(s)] = float(ls[finite].mean())

    def select_cohort(self, losses_members: np.ndarray, m: int
                      ) -> np.ndarray:
        """Resident-local top-m by loss — the O(resident) fast path a
        production server runs (and the population bench times): never
        touches a K-length vector.  The engine's strategy-generic path
        instead gates the full loss vector, trading an O(K) pass for
        compatibility with every registered strategy; both pick the same
        cohort for the loss-ranked rule (tests pin it)."""
        if self._resident_members is None:
            raise RuntimeError("select_cohort before begin_round")
        members = self._resident_members
        m = min(int(m), len(members))
        part = np.argpartition(-np.asarray(losses_members), m - 1)[:m]
        return np.sort(members[part])

    # -- checkpoint contract (DESIGN.md §12) ----------------------------
    def state_dict(self) -> dict:
        """JSON-safe round carry: the shard loss estimates (``None`` for
        never-polled shards).  Shard clusters are a pure function of the
        store summaries, and the loss-blind score stream is a pure
        function of ``(seed, round)`` — neither needs carrying."""
        return {
            "estimates": [
                None if not np.isfinite(e) else float(e)
                for e in self.estimates
            ]
        }

    def load_state_dict(self, state: dict) -> None:
        est = state.get("estimates")
        if est is None or len(est) != self.cfg.n_shards:
            raise ValueError(
                f"population checkpoint carries "
                f"{None if est is None else len(est)} shard estimates, "
                f"expected {self.cfg.n_shards}"
            )
        self.estimates = np.array(
            [np.inf if e is None else float(e) for e in est], np.float64
        )
