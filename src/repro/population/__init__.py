"""``repro.population`` — population-scale federated learning
(DESIGN.md §15).

Makes per-round cost *cohort*-proportional instead of
population-proportional, so the cross-device setting FedLECC is pitched
at (K up to 10⁶) is actually runnable:

- ``store``     — ``ClientStore`` protocol + ``InMemoryStore`` /
  ``ShardedStore``: client data lives host-side or is synthesized shard
  by shard; only polled / dispatched rows are ever device-put.
- ``hierarchy`` — ``HierarchicalSelector``: the paper's Algorithm 1
  applied one level up (shards clustered by summary histogram, ranked
  by mean polled loss) to pick the round's *resident* shards; the
  registered strategy then selects inside them unchanged.
- ``config``    — ``PopulationConfig``, the validated JSON-safe slot
  behind ``FLConfig.population``.

The blocked Hellinger build backing the clustering at scale lives in
``repro.core.hellinger`` (``hellinger_blocked`` / ``hellinger_rows``).
"""

from repro.population.config import PopulationConfig
from repro.population.hierarchy import (
    POPULATION_SELECT_STREAM,
    HierarchicalSelector,
)
from repro.population.store import (
    POPULATION_DATA_STREAM,
    ClientStore,
    InMemoryStore,
    ShardData,
    ShardedStore,
    ShardLoader,
    SyntheticShardLoader,
    materialize_store,
    shard_layout,
)

__all__ = [
    "PopulationConfig",
    "HierarchicalSelector",
    "ClientStore",
    "InMemoryStore",
    "ShardedStore",
    "ShardData",
    "ShardLoader",
    "SyntheticShardLoader",
    "materialize_store",
    "shard_layout",
    "POPULATION_DATA_STREAM",
    "POPULATION_SELECT_STREAM",
]
