"""Serving substrate: batched request scheduling over the decode path."""

from repro.serving.scheduler import Request, BatchScheduler

__all__ = ["Request", "BatchScheduler"]
