"""Bucketed batch scheduler for the serving path.

Production pattern (TGI-style length bucketing, adapted to the
fixed-shape jit world): requests are queued by exact prompt length, so
each prefill/decode group compiles once per (bucket length, batch size)
and runs with zero padding-mask complexity — every sequence in a group
shares positions, which is exactly what ``decode_step``'s scalar ``pos``
wants.  Underfull groups are padded with dummy rows (masked out of the
returned results).

Usage:
    sched = BatchScheduler(cfg, params, max_batch=8, max_new=32)
    ids = [sched.submit(prompt) for prompt in prompts]
    sched.run()                       # drains the queue
    out = sched.result(ids[0])        # np.ndarray of generated tokens

Greedy decoding with optional EOS early-exit per group.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import decode_step, prefill

__all__ = ["Request", "BatchScheduler"]


@dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (prompt_len,) int32
    max_new: int
    done: bool = False
    output: np.ndarray | None = None


class BatchScheduler:
    def __init__(self, cfg, params, max_batch: int = 8, max_new: int = 32,
                 eos_id: int | None = None, mesh=None):
        if cfg.input_mode != "tokens":
            raise ValueError("BatchScheduler serves token-input archs")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_new = max_new
        self.eos_id = eos_id
        self.mesh = mesh
        self._queue: dict[int, list[Request]] = defaultdict(list)  # by prompt len
        self._results: dict[int, Request] = {}
        self._next_id = 0
        self._prefill = jax.jit(
            lambda p, b, ml: prefill(p, cfg, b, max_len=ml, mesh=mesh),
            static_argnums=(2,),
        )
        self._decode = jax.jit(
            lambda p, b, c, pos: decode_step(p, cfg, b, c, pos, mesh=mesh),
            donate_argnums=(),
        )

    # ------------------------------------------------------------------
    def submit(self, tokens: np.ndarray, max_new: int | None = None) -> int:
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, np.asarray(tokens, np.int32), max_new or self.max_new)
        self._queue[len(req.tokens)].append(req)
        self._results[rid] = req
        return rid

    def pending(self) -> int:
        return sum(len(v) for v in self._queue.values())

    def result(self, rid: int) -> np.ndarray:
        req = self._results[rid]
        if not req.done:
            raise RuntimeError(f"request {rid} not finished; call run()")
        return req.output

    # ------------------------------------------------------------------
    def _next_group(self) -> list[Request] | None:
        if not self._queue:
            return None
        # largest bucket first: best slot utilization
        plen = max(self._queue, key=lambda k: len(self._queue[k]))
        bucket = self._queue[plen]
        group = bucket[: self.max_batch]
        self._queue[plen] = bucket[self.max_batch:]
        if not self._queue[plen]:
            del self._queue[plen]
        return group

    def run(self) -> int:
        """Drain the queue; returns the number of completed requests."""
        completed = 0
        while (group := self._next_group()) is not None:
            completed += self._run_group(group)
        return completed

    def _run_group(self, group: list[Request]) -> int:
        plen = len(group[0].tokens)
        gmax = max(r.max_new for r in group)
        b = self.max_batch
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(group):
            toks[i] = r.tokens
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch, plen + gmax)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        outs = [np.asarray(tok)]
        alive = np.ones(b, bool)
        for i in range(gmax - 1):
            if self.eos_id is not None:
                alive &= outs[-1][:, 0] != self.eos_id
                if not alive[: len(group)].any():
                    break
            logits, cache = self._decode(
                self.params, {"token": tok}, cache, jnp.int32(plen + i)
            )
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
        gen = np.concatenate(outs, axis=1)            # (b, ≤gmax)
        for i, r in enumerate(group):
            seq = gen[i, : r.max_new]
            if self.eos_id is not None:
                stop = np.flatnonzero(seq == self.eos_id)
                if stop.size:
                    seq = seq[: stop[0] + 1]
            r.output = seq
            r.done = True
        return len(group)
