"""Logical-axis → mesh-axis sharding policy.

Models annotate every parameter with logical axis names
(``transformer_specs``); this module turns those into
``NamedSharding``s for a concrete mesh, with divisibility guards (an
axis whose dimension does not divide the mesh axis size is replicated —
e.g. hymba's vocab 32001 on a 16-way model axis).

Baseline policy (recorded as such in EXPERIMENTS.md §Perf; the hillclimb
mutates it):

  experts    → model     (expert parallelism)
  heads      → model     (Megatron tensor parallelism)
  ffn        → model
  vocab      → model     (sharded logits / embedding)
  expert_ff  → data      (FSDP: expert weights are the memory giants —
                          gathered per layer inside the scan, grads
                          reduce-scattered back)
  batch      → all data-parallel axes ("pod","data")
  seq        → data axes only when batch cannot fill them (long_500k)

Everything else replicated.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingPolicy", "make_policy", "named_sharding_tree"]


class ShardingPolicy:
    def __init__(self, mesh: Mesh, rules: dict[str, Any], dp_axes: tuple[str, ...]):
        self.mesh = mesh
        self.rules = rules
        self.dp_axes = dp_axes

    def _axis_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        return int(np.prod([self.mesh.shape[a] for a in mesh_axes]))

    def spec_for(self, logical_axes: tuple, shape: tuple[int, ...]) -> P:
        """PartitionSpec with divisibility guards against ``shape``."""
        entries = []
        used: set[str] = set()
        for dim, name in zip(shape, logical_axes):
            mesh_axes = self.rules.get(name) if name is not None else None
            if mesh_axes is None:
                entries.append(None)
                continue
            tup = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
            # guard: divisibility + no mesh axis reused within one spec
            if any(a in used for a in tup) or dim % self._axis_size(tup) != 0:
                entries.append(None)
                continue
            used.update(tup)
            entries.append(mesh_axes if isinstance(mesh_axes, str) else tup)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def shardings(self, specs_tree, shapes_tree):
        """specs_tree: logical-axes tuples; shapes_tree: matching
        ShapeDtypeStructs / arrays.  Returns a NamedSharding tree."""
        def is_axes(x):
            return isinstance(x, tuple) and all(
                isinstance(e, (str, tuple, type(None))) for e in x
            )

        flat_specs = jax.tree.leaves(specs_tree, is_leaf=is_axes)
        flat_shapes = jax.tree.leaves(shapes_tree)
        assert len(flat_specs) == len(flat_shapes), (
            f"specs/shapes leaf mismatch: {len(flat_specs)} vs {len(flat_shapes)}"
        )
        out = [
            NamedSharding(self.mesh, self.spec_for(sp, sh.shape))
            for sp, sh in zip(flat_specs, flat_shapes)
        ]
        treedef = jax.tree.structure(shapes_tree)
        return jax.tree.unflatten(treedef, out)


def make_policy(
    mesh: Mesh,
    batch_size: int,
    shard_seq: bool = False,
    overrides: dict[str, Any] | None = None,
    variant: str = "baseline",
) -> ShardingPolicy:
    """Sharding policy for ``mesh``.  ``shard_seq=True`` moves the data
    axes from batch to sequence (long-context decode with batch 1).

    Variants (§Perf hillclimb — EXPERIMENTS.md):
      baseline — Megatron tensor parallel on ``model`` + data parallel:
                 activations shard by batch over data axes, weights by
                 heads/ffn/vocab over model.  Per-layer activation
                 all-reduces scale with tokens — collective-heavy when
                 tokens/device ≫ params/layer.
      fsdp     — fully data-parallel compute: batch shards over ALL mesh
                 axes; weights are stored sharded over the same axes
                 (ZeRO-3 style) and gathered per layer inside the scan.
                 Collective bytes scale with params, not tokens.
    """
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    all_axes = tuple(mesh.axis_names)
    all_total = int(np.prod([mesh.shape[a] for a in all_axes]))
    if variant == "fsdp":
        batch_axes = all_axes if (not shard_seq and batch_size % all_total == 0) else None
        rules: dict[str, Any] = {
            "experts": all_axes,
            "heads": all_axes,
            "ffn": all_axes,
            "vocab": all_axes,
            "expert_ff": None,
            "kv_heads": None,
            "q_lora": None,
            "kv_lora": None,
            "embed": None,
            "embed2": None,
            "layers": None,
            "state": None,
            "batch": batch_axes,
            "seq": dp if shard_seq else None,
        }
    else:
        batch_axes = dp if (not shard_seq and batch_size % dp_total == 0) else None
        rules = {
            "experts": "model",
            "heads": "model",
            "ffn": "model",
            "vocab": "model",
            "expert_ff": "data",
            "kv_heads": "model",
            "q_lora": None,
            "kv_lora": None,
            "embed": None,
            "embed2": None,
            "layers": None,
            "state": None,
            "batch": batch_axes,
            "seq": dp if shard_seq else None,
        }
    if overrides:
        rules.update(overrides)
    return ShardingPolicy(mesh, rules, dp)


def named_sharding_tree(policy: ShardingPolicy, specs_tree, shapes_tree):
    return policy.shardings(specs_tree, shapes_tree)
