"""Thin compatibility layer over moving JAX APIs.

The scale-out code targets the modern spelling (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.set_mesh``); older JAX (< 0.6, e.g.
the 0.4.x in this container) only has ``jax.experimental.shard_map``
(``auto``/``check_rep``) and uses the ``Mesh`` object itself as the
context manager.  These wrappers prefer the modern API when present and
translate otherwise, so every call site is version-agnostic:

- ``axis_names`` (manual axes) ↔ ``auto`` (its complement over the mesh)
- ``check_vma``               ↔ ``check_rep``
- ``jax.set_mesh(mesh)``      ↔ ``with mesh:``
"""

from __future__ import annotations

import jax

__all__ = ["set_mesh", "shard_map", "cost_analysis"]


def cost_analysis(compiled) -> dict:
    """Dict-valued ``compiled.cost_analysis()`` on any JAX version
    (older JAX returns a one-element list of dicts per module)."""
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return c or {}


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax<0.6: Mesh is itself a context manager


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` with the modern signature on any supported JAX.

    ``axis_names`` is the set of *manual* mesh axes (None = all manual);
    on older JAX this becomes ``auto = mesh axes − axis_names``.
    """
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, **kw)

    from jax.experimental.shard_map import shard_map as _shard_map

    # Old JAX/XLA crashes on partial-auto shard_map (IsManualSubgroup
    # check), so run fully manual instead: axes absent from the specs are
    # replicated rather than GSPMD-parallelized.  The body sees identical
    # shapes and computes identical values — only intra-shard auto
    # parallelism over the would-be-auto axes is lost (a documented
    # perf-only degradation on jax<0.6).
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
