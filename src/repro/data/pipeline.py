"""Minimal batching pipeline (host-side numpy → device arrays).

The simulation regime samples client-local minibatches *inside* jit (see
``repro.federated.simulation``); this iterator serves the centralized /
example paths and the scale-out input feed.
"""

from __future__ import annotations

from typing import Iterator

import numpy as np

__all__ = ["batch_iterator"]


def batch_iterator(
    x: np.ndarray,
    y: np.ndarray,
    batch_size: int,
    seed: int = 0,
    drop_remainder: bool = True,
    epochs: int | None = None,
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Shuffled minibatch iterator; loops ``epochs`` times (None = forever)."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    epoch = 0
    while epochs is None or epoch < epochs:
        perm = rng.permutation(n)
        end = n - (n % batch_size) if drop_remainder else n
        for s in range(0, end, batch_size):
            ix = perm[s : s + batch_size]
            yield x[ix], y[ix]
        epoch += 1
