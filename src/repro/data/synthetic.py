"""Synthetic dataset generators (MNIST-scale images, LM token streams).

``make_classification`` builds a class-conditional Gaussian mixture in
pixel space: each class owns a small number of prototype "digits"
(smooth random blobs), samples are prototype + pixel noise, clipped to
[0, 1].  An MLP reaches high accuracy given enough rounds, yet the task
is hard enough that label-skewed federation shows the paper's effects
(client drift, selection gains).

``make_token_stream`` builds an order-2 Markov token stream so LM
training losses actually decrease (used by LM-family smoke examples).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["Dataset", "make_classification", "make_token_stream"]


class Dataset(NamedTuple):
    x: np.ndarray  # (N, F) float32 features  /  (N, S) int32 tokens
    y: np.ndarray  # (N,)  int64 labels       /  (N, S) int32 next-tokens


def _smooth_prototype(rng: np.random.Generator, side: int) -> np.ndarray:
    """Random smooth blob image: low-frequency noise, normalized to [0,1]."""
    coarse = rng.normal(size=(side // 4, side // 4))
    img = np.kron(coarse, np.ones((4, 4)))  # upsample
    # cheap blur
    for _ in range(2):
        img = (
            img
            + np.roll(img, 1, 0)
            + np.roll(img, -1, 0)
            + np.roll(img, 1, 1)
            + np.roll(img, -1, 1)
        ) / 5.0
    img = img - img.min()
    return (img / max(img.max(), 1e-9)).astype(np.float32)


def make_classification(
    n: int,
    n_features: int = 784,
    n_classes: int = 10,
    prototypes_per_class: int = 2,
    noise: float = 0.25,
    seed: int = 0,
    proto_seed: int = 1234,
) -> Dataset:
    """Class-conditional Gaussian-mixture images, MNIST-like scale.

    ``proto_seed`` fixes the class prototypes (the task); ``seed`` draws
    the samples.  Train/test splits share ``proto_seed`` and differ in
    ``seed`` — otherwise they would be two unrelated tasks.
    """
    proto_rng = np.random.default_rng(proto_seed)
    rng = np.random.default_rng(seed)
    side = int(round(n_features**0.5))
    assert side * side == n_features, "n_features must be a square"
    protos = np.stack(
        [
            np.stack(
                [_smooth_prototype(proto_rng, side).ravel() for _ in range(prototypes_per_class)]
            )
            for _ in range(n_classes)
        ]
    )  # (C, P, F)
    y = rng.integers(0, n_classes, size=n).astype(np.int64)
    which = rng.integers(0, prototypes_per_class, size=n)
    x = protos[y, which] + rng.normal(0.0, noise, size=(n, n_features)).astype(np.float32)
    x = np.clip(x, 0.0, 1.0).astype(np.float32)
    return Dataset(x=x, y=y)


def make_token_stream(
    n_seqs: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    order: int = 2,
) -> Dataset:
    """Order-``order`` Markov chain token sequences (learnable structure)."""
    rng = np.random.default_rng(seed)
    # Sparse transition table: each context maps to a few likely tokens.
    # Favored tokens are drawn with a power-law skew so the stream has a
    # non-uniform unigram distribution too — models show loss progress
    # within hundreds of steps instead of needing to crack the full
    # order-2 structure first.
    n_ctx = min(vocab**order, 65536)
    fav = np.floor(vocab * rng.random((n_ctx, 4)) ** 3).astype(np.int64)
    toks = np.empty((n_seqs, seq_len + 1), dtype=np.int32)
    toks[:, :order] = rng.integers(0, vocab, size=(n_seqs, order))
    ctx = (toks[:, 0] * 31 + toks[:, 1] * 7) % n_ctx if order == 2 else toks[:, 0] % n_ctx
    for t in range(order, seq_len + 1):
        pick = rng.integers(0, 4, size=n_seqs)
        explore = rng.random(n_seqs) < 0.1
        nxt = np.where(explore, rng.integers(0, vocab, size=n_seqs), fav[ctx, pick])
        toks[:, t] = nxt
        ctx = (ctx * 31 + nxt * 7) % n_ctx
    return Dataset(x=toks[:, :-1].astype(np.int32), y=toks[:, 1:].astype(np.int32))
