"""Data substrate: synthetic datasets + non-IID partitioning + batching.

MNIST/FMNIST are not available offline (DESIGN.md §6); ``synthetic``
provides class-conditional Gaussian-mixture images at MNIST scale and
token streams for the LM architectures.  ``partition`` implements the
FedArtML-style Dirichlet label-skew split the paper uses, with
Hellinger-distance calibration to hit the paper's HD≈0.9 regime.
"""

from repro.data.synthetic import make_classification, make_token_stream
from repro.data.partition import dirichlet_partition, calibrate_alpha, pack_clients
from repro.data.pipeline import batch_iterator

__all__ = [
    "make_classification",
    "make_token_stream",
    "dirichlet_partition",
    "calibrate_alpha",
    "pack_clients",
    "batch_iterator",
]
