"""Dirichlet label-skew partitioning (FedArtML-style) + HD calibration.

The paper partitions MNIST/FMNIST across K clients with a
Dirichlet(alpha) label split and reports the regime by its average
Hellinger distance (HD ≈ 0.9 = severe skew, HD ≈ 0.86 for the larger-K
settings).  ``dirichlet_partition`` reproduces the split;
``calibrate_alpha`` binary-searches alpha to hit a target HD, because
the alpha↔HD mapping depends on K and the class count.

``pack_clients`` turns ragged per-client index lists into the fixed-size
(K, N_max) arrays + validity masks the vmapped simulation consumes.
"""

from __future__ import annotations

import numpy as np

from repro.core.hellinger import average_hd

__all__ = [
    "dirichlet_partition", "shard_partition", "calibrate_alpha",
    "calibrate_shards", "pack_clients", "label_histograms",
]


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    alpha: float,
    seed: int = 0,
    min_samples_per_client: int = 8,
) -> list[np.ndarray]:
    """Split sample indices across clients with per-class Dirichlet proportions.

    For each class c: draw proportions ~ Dir(alpha * 1_K) and multinomially
    assign that class's samples.  Small alpha → each class concentrates on
    few clients (severe label skew).  Clients below
    ``min_samples_per_client`` are topped up from the largest client so
    every client can form at least one batch.
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for c in np.unique(labels):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        counts = np.floor(props * len(idx)).astype(int)
        # distribute the remainder to the largest shares
        rem = len(idx) - counts.sum()
        if rem > 0:
            counts[np.argsort(-props)[:rem]] += 1
        splits = np.split(idx, np.cumsum(counts)[:-1])
        for k in range(n_clients):
            client_idx[k].extend(splits[k].tolist())

    out = [np.array(sorted(ix), dtype=np.int64) for ix in client_idx]
    # Top up starved clients (the paper's tooling guarantees non-empty
    # clients).  Donors rotate and each starved client draws a *random*
    # slice of a different donor, so top-up clients do not end up with
    # mutually identical single-class histograms (which would artificially
    # deflate the average HD at extreme skew).
    starved = [k for k in range(n_clients) if len(out[k]) < min_samples_per_client]
    for j, k in enumerate(starved):
        while len(out[k]) < min_samples_per_client:
            donors = np.argsort([-len(o) for o in out])
            donor = int(donors[j % max(1, min(len(donors), n_clients // 4))])
            if len(out[donor]) <= min_samples_per_client:
                donor = int(donors[0])
            pick = rng.integers(0, len(out[donor]))
            take = out[donor][pick]
            out[donor] = np.delete(out[donor], pick)
            out[k] = np.append(out[k], take)
    return out


def shard_partition(
    labels: np.ndarray,
    n_clients: int,
    shards_per_client: int = 1,
    seed: int = 0,
) -> list[np.ndarray]:
    """McMahan-style shard split: sort by label, cut into
    K·shards_per_client equal shards, deal ``shards_per_client`` to each
    client.  Produces BALANCED client sizes with ≤ shards_per_client
    distinct classes each — the severe-label-skew regime the paper's
    HD≈0.9 row corresponds to (K=100, 10 classes, 1 shard/client gives
    avg HD ≈ 0.909 analytically).

    The plain Dirichlet split at comparable HD concentrates whole classes
    on 1–2 clients and leaves the rest as tiny top-up stubs, which is a
    *different* (and pathological) regime — see EXPERIMENTS.md §Claims.
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    order = np.argsort(labels, kind="stable")
    # shuffle within each class so shards are random samples of the class
    out_order = []
    for c in np.unique(labels):
        block = order[labels[order] == c]
        rng.shuffle(block)
        out_order.append(block)
    order = np.concatenate(out_order)
    n_shards = n_clients * shards_per_client
    shards = np.array_split(order, n_shards)
    perm = rng.permutation(n_shards)
    return [
        np.concatenate([shards[perm[i * shards_per_client + j]]
                        for j in range(shards_per_client)])
        for i in range(n_clients)
    ]


def calibrate_shards(
    labels: np.ndarray,
    n_clients: int,
    target_hd: float,
    n_classes: int,
    seed: int = 0,
) -> int:
    """Pick shards_per_client whose partition HD is closest to target."""
    best, best_err = 1, float("inf")
    for s in (1, 2, 3, 4, 6, 8):
        parts = shard_partition(labels, n_clients, s, seed=seed)
        hd = float(average_hd(label_histograms(labels, parts, n_classes)))
        if abs(hd - target_hd) < best_err:
            best, best_err = s, abs(hd - target_hd)
    return best


def label_histograms(
    labels: np.ndarray, client_idx: list[np.ndarray], n_classes: int
) -> np.ndarray:
    """(K, C) normalized label histograms — what clients ship the server."""
    h = np.stack(
        [np.bincount(labels[ix], minlength=n_classes).astype(np.float64) for ix in client_idx]
    )
    return h / np.maximum(h.sum(1, keepdims=True), 1e-12)


def calibrate_alpha(
    labels: np.ndarray,
    n_clients: int,
    target_hd: float,
    n_classes: int,
    seed: int = 0,
    tol: float = 0.02,
    iters: int = 6,
) -> float:
    """Find Dirichlet alpha so the partition's average HD hits the target.

    HD decreases with alpha in the practical range but is mildly
    non-monotone at extreme skew (top-up artifacts), so: coarse log-grid
    scan first, then local bisection between the best neighbours.
    """

    def hd_at(alpha: float) -> float:
        part = dirichlet_partition(labels, n_clients, alpha, seed=seed)
        return float(average_hd(label_histograms(labels, part, n_classes)))

    grid = np.geomspace(0.002, 50.0, 12)
    hds = np.array([hd_at(a) for a in grid])
    # HD saturates at extreme skew: several alphas can hit the target.
    # Prefer the SMALLEST qualifying alpha — the paper's severe-label-skew
    # regime is the *structured* one (clients dominated by few classes),
    # which is what label-distribution clustering (FedLECC/HACCS) sees;
    # large-alpha mixtures can reach the same average HD with no cluster
    # structure at all.
    ok = np.flatnonzero(np.abs(hds - target_hd) < tol)
    if ok.size:
        return float(grid[ok[0]])
    best = int(np.argmin(np.abs(hds - target_hd)))
    # local bisection between best and the neighbour bracketing the target
    lo_i = max(best - 1, 0)
    hi_i = min(best + 1, len(grid) - 1)
    lo, hi = grid[lo_i], grid[hi_i]
    best_a, best_err = float(grid[best]), abs(hds[best] - target_hd)
    for _ in range(iters):
        mid = (lo * hi) ** 0.5
        hd = hd_at(mid)
        err = abs(hd - target_hd)
        if err < best_err:
            best_a, best_err = float(mid), err
        if err < tol:
            return float(mid)
        if hd > target_hd:
            lo = mid
        else:
            hi = mid
    return best_a


def pack_clients(
    x: np.ndarray, y: np.ndarray, client_idx: list[np.ndarray]
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Ragged per-client indices → stacked (K, N_max, ...) arrays + mask.

    Padding rows repeat each client's first sample and are masked out, so
    vmapped code never sees garbage values.
    """
    n_max = max(len(ix) for ix in client_idx)
    k = len(client_idx)
    xs = np.zeros((k, n_max) + x.shape[1:], dtype=x.dtype)
    ys = np.zeros((k, n_max) + y.shape[1:], dtype=y.dtype)
    mask = np.zeros((k, n_max), dtype=np.float32)
    for i, ix in enumerate(client_idx):
        n = len(ix)
        xs[i, :n] = x[ix]
        ys[i, :n] = y[ix]
        mask[i, :n] = 1.0
        if n < n_max and n > 0:
            xs[i, n:] = x[ix[0]]
            ys[i, n:] = y[ix[0]]
    return xs, ys, mask
