"""Communication accounting (paper §V-C, Table III).

The paper measures "total communication exchanged between the server and
clients over training, including model parameters, cluster information,
and loss values".  This module is the exact bytes ledger used both by the
simulation (``repro.federated.simulation``) and by the Table III
benchmark:

  per round:  m * P * bytes_per_param          (model download to selected)
            + m * P * upload_bytes_per_param   (update upload from selected)
            + K * 4                     (loss scalars, if the strategy polls)
  one-time:   K * C * 4                 (label histograms, if used)
            + K * 4                     (cluster assignments pushed back)

``upload_bytes_per_param`` defaults to ``bytes_per_param`` (fp32 both
ways); quantized-delta uploads (``FLConfig.compress_bits``,
``repro.federated.compression``) set it to ``bits / 8`` — per-leaf
quantization scales are a handful of floats per client and are omitted
as negligible next to the parameter payload.

FedLECC's saving in the paper comes from a small, well-chosen ``m`` —
the protocol overhead (histograms once + K loss floats/round) is
negligible next to model traffic, which is what Table III shows.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

__all__ = ["CommModel", "count_params"]

_MB = 1024.0 * 1024.0


def count_params(params) -> int:
    """Total parameter count of a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))


@dataclass
class CommModel:
    n_params: int
    K: int
    n_classes: int
    bytes_per_param: int = 4
    upload_bytes_per_param: float | None = None  # None → bytes_per_param

    def __post_init__(self) -> None:
        if self.upload_bytes_per_param is None:
            self.upload_bytes_per_param = float(self.bytes_per_param)

    def model_mb(self) -> float:
        return self.n_params * self.bytes_per_param / _MB

    def one_time_mb(self, needs_histograms: bool) -> float:
        if not needs_histograms:
            return 0.0
        hist = self.K * self.n_classes * 4
        assignments = self.K * 4
        return (hist + assignments) / _MB

    def round_mb(self, m_selected: int, needs_losses: bool,
                 m_uploaded: int | None = None,
                 n_polled: int | None = None) -> float:
        """Bytes of one round.  ``m_uploaded`` (default: ``m_selected``)
        counts the updates that actually arrived — under a systems
        deadline (``repro.systems``, DESIGN.md §10) dropped stragglers
        paid the download but never completed the upload.  ``n_polled``
        (default: ``K``) counts the clients the loss poll reached —
        population mode (DESIGN.md §15) polls only the resident shards,
        so the poll traffic scales with the cohort, not the
        population."""
        if m_uploaded is None:
            m_uploaded = m_selected
        if n_polled is None:
            n_polled = self.K
        model_traffic = self.n_params * (
            m_selected * self.bytes_per_param
            + m_uploaded * self.upload_bytes_per_param
        )
        loss_poll = n_polled * 4 if needs_losses else 0
        return (model_traffic + loss_poll) / _MB

    def total_mb(
        self, rounds: int, m_selected: int, needs_losses: bool, needs_histograms: bool
    ) -> float:
        return self.one_time_mb(needs_histograms) + rounds * self.round_mb(
            m_selected, needs_losses
        )

    def average_round_mb(
        self, rounds: int, m_selected: int, needs_losses: bool, needs_histograms: bool
    ) -> float:
        """Table III's "average communication overhead" (MB per round,
        one-time costs amortized)."""
        return self.total_mb(rounds, m_selected, needs_losses, needs_histograms) / rounds
