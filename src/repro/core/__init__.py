"""FedLECC core: the paper's primary contribution.

- ``hellinger``   — pairwise Hellinger-distance matrix over label histograms
- ``clustering``  — OPTICS density ordering + reachability-threshold
                    cluster extraction (pure JAX/numpy, no sklearn)
- ``selection``   — Algorithm 1: cluster- and loss-guided client selection
- ``strategies``  — selection strategies behind one interface: fedlecc,
                    random (FedAvg), POC, HACCS, FedCLS, FedCor
- ``comm_model``  — per-round communication accounting (Table III)
"""

from repro.core.hellinger import hellinger_matrix, hellinger_distance
from repro.core.clustering import optics, extract_clusters, cluster_label_histograms
from repro.core.selection import fedlecc_select, selection_weights
from repro.core.strategies import get_strategy, STRATEGIES
from repro.core.comm_model import CommModel

__all__ = [
    "hellinger_matrix",
    "hellinger_distance",
    "optics",
    "extract_clusters",
    "cluster_label_histograms",
    "fedlecc_select",
    "selection_weights",
    "get_strategy",
    "STRATEGIES",
    "CommModel",
]
