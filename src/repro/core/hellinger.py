"""Hellinger distance over label histograms (FedLECC §IV-A).

The Hellinger distance between two discrete distributions p, q over C
classes is

    HD(p, q) = sqrt(1 - sum_c sqrt(p_c * q_c))            (bounded in [0, 1])

FedLECC uses the pairwise K x K HD matrix over the clients' normalized
label histograms as the similarity structure for clustering.  The matrix
is symmetric with zero diagonal.

The Bhattacharyya coefficient sum_c sqrt(p_c q_c) is a plain inner
product of sqrt-histograms, so the whole matrix is one K x C @ C x K
matmul — which is what the Pallas kernel in ``repro.kernels.hellinger``
tiles for the MXU.  This module is the framework-facing API; it routes to
the pure-jnp implementation (always correct, used on CPU) and exists as
the oracle the kernel is tested against.

At population scale (``repro.population``, DESIGN.md §15) the dense
K x K build is the memory wall: ``hellinger_blocked`` assembles the same
matrix from (block, K) row strips — each strip is one device matmul (the
Pallas strip kernel on TPU, a jitted lax matmul elsewhere) immediately
copied to a host buffer, so peak *device* memory is O(K·block) instead
of O(K²).  ``hellinger_rows`` is the strip primitive itself, exposed for
consumers (blocked k-medoids) that never need the full matrix at all.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "hellinger_distance",
    "hellinger_matrix",
    "hellinger_rows",
    "hellinger_blocked",
    "average_hd",
    "dense_budget_bytes",
    "set_dense_budget_bytes",
]


def _normalize(h: jax.Array, axis: int = -1) -> jax.Array:
    s = jnp.sum(h, axis=axis, keepdims=True)
    return h / jnp.maximum(s, 1e-12)


def hellinger_distance(p: jax.Array, q: jax.Array) -> jax.Array:
    """HD between two histograms (unnormalized inputs are normalized)."""
    p = _normalize(jnp.asarray(p, jnp.float32))
    q = _normalize(jnp.asarray(q, jnp.float32))
    bc = jnp.sum(jnp.sqrt(p * q), axis=-1)
    return jnp.sqrt(jnp.clip(1.0 - bc, 0.0, 1.0))


def hellinger_matrix(hists: jax.Array) -> jax.Array:
    """Pairwise K x K Hellinger distance matrix.

    Args:
      hists: (K, C) label histograms (counts or probabilities; rows are
        normalized internally).

    Returns:
      (K, K) float32 symmetric matrix, zero diagonal.
    """
    h = _normalize(jnp.asarray(hists, jnp.float32))
    r = jnp.sqrt(h)                       # (K, C)
    bc = r @ r.T                          # Bhattacharyya coefficients
    d = jnp.sqrt(jnp.clip(1.0 - bc, 0.0, 1.0))
    # Exact zeros on the diagonal (numerical noise otherwise).
    return d * (1.0 - jnp.eye(h.shape[0], dtype=d.dtype))


# ---------------------------------------------------------------- blocked
# Memory guard: consumers that materialize the dense K x K float32 matrix
# (host-side) warn past this budget so a population-scale K does not
# silently eat the server's RAM.  Configurable because benchmarks probe
# above it deliberately.
_DENSE_BUDGET_BYTES = 1 << 30  # 1 GiB ≈ K = 16384


def dense_budget_bytes() -> int:
    """The current dense-matrix warning budget in bytes."""
    return _DENSE_BUDGET_BYTES


def set_dense_budget_bytes(n_bytes: int) -> int:
    """Set the dense-matrix warning budget; returns the previous value."""
    global _DENSE_BUDGET_BYTES
    if int(n_bytes) < 1:
        raise ValueError(f"dense budget must be >= 1 byte, got {n_bytes}")
    old = _DENSE_BUDGET_BYTES
    _DENSE_BUDGET_BYTES = int(n_bytes)
    return old


def _warn_if_over_budget(k: int, budget_bytes: int | None) -> None:
    budget = _DENSE_BUDGET_BYTES if budget_bytes is None else int(budget_bytes)
    need = k * k * 4
    if need > budget:
        warnings.warn(
            f"dense {k}x{k} Hellinger matrix needs {need / 2**20:.0f} MiB "
            f"(budget {budget / 2**20:.0f} MiB) — at this population scale "
            f"prefer shard-level clustering (repro.population, DESIGN.md "
            f"§15) or raise the budget via "
            f"repro.core.hellinger.set_dense_budget_bytes",
            ResourceWarning,
            stacklevel=3,
        )


def _strip(rb: jax.Array, r: jax.Array) -> jax.Array:
    """(B, C) x (K, C) *sqrt-histogram* panels → (B, K) HD strip."""
    bc = rb @ r.T
    return jnp.sqrt(jnp.clip(1.0 - bc, 0.0, 1.0))


_strip_jit = jax.jit(_strip, donate_argnums=())


def _sqrt_rows(hists: np.ndarray) -> np.ndarray:
    h = np.asarray(hists, np.float32)
    h = h / np.maximum(h.sum(axis=-1, keepdims=True), 1e-12)
    return np.sqrt(h)


def hellinger_rows(rows, hists) -> np.ndarray:
    """HD between each of B query histograms and all K histograms.

    Args:
      rows:  (B, C) histograms (normalized internally).
      hists: (K, C) histograms.

    Returns:
      (B, K) float32 distance strip (no diagonal treatment — callers
      assembling a square matrix zero it themselves).  This is the
      O(K·B)-memory primitive behind ``hellinger_blocked`` and the
      blocked k-medoids in ``repro.core.clustering``.
    """
    rb = jnp.asarray(_sqrt_rows(np.atleast_2d(rows)))
    r = jnp.asarray(_sqrt_rows(hists))
    return np.asarray(_strip_jit(rb, r))


def hellinger_blocked(
    hists,
    block: int = 4096,
    *,
    use_kernel: bool | str = "auto",
    budget_bytes: int | None = None,
) -> np.ndarray:
    """Pairwise K x K Hellinger matrix assembled from (block, K) strips.

    Numerically the same matrix as ``hellinger_matrix`` (each entry is
    the identical sqrt-clip of a row inner product; the regression test
    pins ``allclose``), but peak *device* memory is O(K·block): each
    strip is one matmul on device, copied straight into the host output
    buffer.  ``use_kernel`` picks the strip backend — ``"auto"`` uses the
    Pallas MXU kernel on TPU and the jitted lax matmul elsewhere;
    ``True`` forces the Pallas path (interpret mode off-TPU, for tests);
    ``False`` forces the lax fallback.

    The K x K float32 *host* result still gets allocated; past the
    configurable dense budget (``set_dense_budget_bytes``) a
    ``ResourceWarning`` points at shard-level clustering instead
    (``repro.population``, DESIGN.md §15).
    """
    h = np.atleast_2d(np.asarray(hists, np.float32))
    k = h.shape[0]
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    _warn_if_over_budget(k, budget_bytes)
    r_host = _sqrt_rows(h)
    r = jnp.asarray(r_host)

    if use_kernel == "auto":
        on_tpu = jax.default_backend() == "tpu"
        kernel, interpret = on_tpu, False
    elif use_kernel:
        kernel, interpret = True, jax.default_backend() != "tpu"
    else:
        kernel, interpret = False, False
    if kernel:
        from repro.kernels.hellinger.ops import hellinger_strip_pallas

    out = np.empty((k, k), np.float32)
    for i0 in range(0, k, block):
        i1 = min(i0 + block, k)
        rb = r[i0:i1]
        if kernel:
            strip = hellinger_strip_pallas(rb, r, interpret=interpret)
        else:
            strip = _strip_jit(rb, r)
        out[i0:i1] = np.asarray(strip)
    np.fill_diagonal(out, 0.0)
    return out


def average_hd(hists: jax.Array) -> jax.Array:
    """Mean off-diagonal HD — the paper's scalar "how non-IID" measure.

    The paper targets HD ~= 0.9 ("high non-IID regime"); the partitioner
    in ``repro.data.partition`` calibrates Dirichlet alpha against this.
    """
    d = hellinger_matrix(hists)
    k = d.shape[0]
    off = jnp.sum(d) / jnp.maximum(k * (k - 1), 1)
    return off
