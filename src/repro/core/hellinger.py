"""Hellinger distance over label histograms (FedLECC §IV-A).

The Hellinger distance between two discrete distributions p, q over C
classes is

    HD(p, q) = sqrt(1 - sum_c sqrt(p_c * q_c))            (bounded in [0, 1])

FedLECC uses the pairwise K x K HD matrix over the clients' normalized
label histograms as the similarity structure for clustering.  The matrix
is symmetric with zero diagonal.

The Bhattacharyya coefficient sum_c sqrt(p_c q_c) is a plain inner
product of sqrt-histograms, so the whole matrix is one K x C @ C x K
matmul — which is what the Pallas kernel in ``repro.kernels.hellinger``
tiles for the MXU.  This module is the framework-facing API; it routes to
the pure-jnp implementation (always correct, used on CPU) and exists as
the oracle the kernel is tested against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["hellinger_distance", "hellinger_matrix", "average_hd"]


def _normalize(h: jax.Array, axis: int = -1) -> jax.Array:
    s = jnp.sum(h, axis=axis, keepdims=True)
    return h / jnp.maximum(s, 1e-12)


def hellinger_distance(p: jax.Array, q: jax.Array) -> jax.Array:
    """HD between two histograms (unnormalized inputs are normalized)."""
    p = _normalize(jnp.asarray(p, jnp.float32))
    q = _normalize(jnp.asarray(q, jnp.float32))
    bc = jnp.sum(jnp.sqrt(p * q), axis=-1)
    return jnp.sqrt(jnp.clip(1.0 - bc, 0.0, 1.0))


def hellinger_matrix(hists: jax.Array) -> jax.Array:
    """Pairwise K x K Hellinger distance matrix.

    Args:
      hists: (K, C) label histograms (counts or probabilities; rows are
        normalized internally).

    Returns:
      (K, K) float32 symmetric matrix, zero diagonal.
    """
    h = _normalize(jnp.asarray(hists, jnp.float32))
    r = jnp.sqrt(h)                       # (K, C)
    bc = r @ r.T                          # Bhattacharyya coefficients
    d = jnp.sqrt(jnp.clip(1.0 - bc, 0.0, 1.0))
    # Exact zeros on the diagonal (numerical noise otherwise).
    return d * (1.0 - jnp.eye(h.shape[0], dtype=d.dtype))


def average_hd(hists: jax.Array) -> jax.Array:
    """Mean off-diagonal HD — the paper's scalar "how non-IID" measure.

    The paper targets HD ~= 0.9 ("high non-IID regime"); the partitioner
    in ``repro.data.partition`` calibrates Dirichlet alpha against this.
    """
    d = hellinger_matrix(hists)
    k = d.shape[0]
    off = jnp.sum(d) / jnp.maximum(k * (k - 1), 1)
    return off
