"""OPTICS clustering over the Hellinger-distance matrix (FedLECC §IV-B).

The paper clusters clients by label-distribution similarity and found
OPTICS the best trade-off (no preset number of clusters, robust to
varying client densities).  sklearn is not available offline, so this is
a from-scratch implementation:

- ``optics``          — density ordering + reachability profile.  With a
    precomputed distance matrix and ``max_eps=inf`` the OPTICS expansion
    reduces to a Prim-style loop: repeatedly visit the unprocessed point
    with the smallest reachability and relax every unprocessed point with
    ``max(core_dist(i), D[i, j])``.  Implemented as a ``lax.fori_loop``
    with O(K) vectorized relaxation per step (O(K^2) total, K = clients).
- ``extract_clusters`` — DBSCAN-equivalent extraction at a cut ``eps``
    (the same rule as sklearn's ``cluster_optics_dbscan``).  ``eps="auto"``
    picks the cut from the reachability profile.  Noise points become
    singleton clusters — FedLECC requires every client to live in some
    cluster so it stays selectable.

Deviation vs. sklearn (recorded in DESIGN.md §9): cluster extraction uses
the reachability-threshold rule rather than the xi-steepness refinement;
on Dirichlet label-skew histograms the two agree (see tests).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "OpticsResult",
    "optics",
    "extract_clusters",
    "cluster_label_histograms",
    "kmedoids",
    "kmedoids_hists",
    "best_clustering",
    "silhouette_score",
]

_INF = jnp.inf


class OpticsResult(NamedTuple):
    ordering: jax.Array       # (K,) int32 — visit order (permutation)
    reachability: jax.Array   # (K,) float32 — reachability per *point index*
    core_distances: jax.Array  # (K,) float32


@partial(jax.jit, static_argnames=("min_samples",))
def optics(dist: jax.Array, min_samples: int = 3) -> OpticsResult:
    """OPTICS ordering from a precomputed (K, K) distance matrix.

    ``max_eps`` is infinite (every point is every point's neighbour): for
    K up to a few thousand clients the O(K^2) relaxation is trivial
    server-side work, and it makes the expansion exactly Prim-like.
    """
    dist = jnp.asarray(dist, jnp.float32)
    k = dist.shape[0]
    ms = min(int(min_samples), k)
    # Core distance: distance to the ms-th nearest point, self included
    # (row i of dist has a zero at i, matching sklearn's kneighbors).
    core = jnp.sort(dist, axis=1)[:, ms - 1]

    def body(t, state):
        reach, processed, ordering = state
        key = jnp.where(processed, _INF, reach)
        # Unvisited starts have reach=inf; argmin's first-occurrence
        # tie-break reproduces "next unprocessed in index order".
        i = jnp.argmin(key)
        ordering = ordering.at[t].set(i.astype(jnp.int32))
        processed = processed.at[i].set(True)
        new = jnp.maximum(core[i], dist[i])
        reach = jnp.where(processed, reach, jnp.minimum(reach, new))
        return reach, processed, ordering

    reach0 = jnp.full((k,), _INF, jnp.float32)
    processed0 = jnp.zeros((k,), jnp.bool_)
    ordering0 = jnp.zeros((k,), jnp.int32)
    reach, _, ordering = jax.lax.fori_loop(0, k, body, (reach0, processed0, ordering0))
    return OpticsResult(ordering=ordering, reachability=reach, core_distances=core)


def _auto_eps(res: OpticsResult) -> float:
    """Pick the reachability cut from the profile (largest-gap heuristic).

    Cluster-internal reachabilities form dense plateaus; the separators
    between clusters are isolated jumps.  Sorting the finite
    reachabilities ascending, the cut goes through the *largest gap* in
    the upper half of the sorted values — below every separator jump,
    above every plateau.  Validated on Dirichlet label-skew HD matrices
    in tests (recovers planted modes).
    """
    r = np.asarray(res.reachability)
    finite = np.sort(r[np.isfinite(r)])
    if finite.size < 2:
        return float("inf")
    gaps = np.diff(finite)
    lo = finite.size // 2  # never cut inside the dense low region
    upper = gaps[lo:]
    if upper.size == 0 or upper.max() <= 1e-9:
        return float(finite[-1]) + 1e-6  # no structure: single cluster
    g = lo + int(np.argmax(upper))
    return float(0.5 * (finite[g] + finite[g + 1]))


def extract_clusters(res: OpticsResult, eps: float | str = "auto") -> np.ndarray:
    """DBSCAN-equivalent label extraction at reachability cut ``eps``.

    Returns (K,) int labels in [0, n_clusters); noise points are assigned
    fresh singleton cluster ids (FedLECC keeps every client selectable).
    """
    if eps == "auto":
        eps = _auto_eps(res)
    ordering = np.asarray(res.ordering)
    reach = np.asarray(res.reachability)
    core = np.asarray(res.core_distances)

    k = ordering.shape[0]
    labels = np.zeros(k, dtype=np.int64)
    far_reach = reach > eps
    near_core = core <= eps
    # sklearn cluster_optics_dbscan: a far-reach near-core point *starts*
    # a new cluster; a far-reach far-core point is noise.
    starts = far_reach[ordering] & near_core[ordering]
    labels[ordering] = np.cumsum(starts) - 1
    labels[far_reach & ~near_core] = -1
    # First visited point always has reach=inf; cumsum-1 can leave -1 for
    # a leading run if it is not near_core — normalize below.
    next_id = labels.max() + 1 if labels.max() >= 0 else 0
    for i in np.where(labels < 0)[0]:
        labels[i] = next_id
        next_id += 1
    # Compact ids to 0..n-1 preserving first-appearance order.
    _, labels = np.unique(labels, return_inverse=True)
    return labels.astype(np.int64)


def cluster_label_histograms(
    hists,
    min_samples: int = 3,
    eps: float | str = "auto",
) -> tuple[np.ndarray, OpticsResult]:
    """End-to-end: label histograms -> HD matrix -> OPTICS -> cluster labels.

    The matrix is assembled strip-wise (``hellinger_blocked``): device
    memory stays O(K·block) during the build, and the dense host matrix
    warns past the configurable budget.  OPTICS itself still consumes
    the full matrix — population-scale callers cluster shard *summaries*
    instead (``repro.population``, DESIGN.md §15) or use
    ``kmedoids_hists``, which never forms K² at all."""
    from repro.core.hellinger import hellinger_blocked

    d = jnp.asarray(hellinger_blocked(hists))
    res = optics(d, min_samples=min_samples)
    labels = extract_clusters(res, eps=eps)
    return labels, res


def kmedoids(dist: np.ndarray, k: int, seed: int = 0, iters: int = 25) -> np.ndarray:
    """PAM-lite k-medoids over a precomputed distance matrix.

    The paper evaluated k-medoids alongside OPTICS (§IV-B); it serves
    here as the fallback when the label-distribution geometry has no
    density structure (multi-class mixtures at large K — see
    EXPERIMENTS.md §Claims K=250).  k-means++-style seeding.
    """
    rng = np.random.default_rng(seed)
    n = dist.shape[0]
    k = min(k, n)
    medoids = [int(rng.integers(n))]
    for _ in range(k - 1):
        d_min = dist[:, medoids].min(axis=1)
        p = d_min**2
        p = p / p.sum() if p.sum() > 0 else np.full(n, 1.0 / n)
        medoids.append(int(rng.choice(n, p=p)))
    medoids = np.array(medoids)
    for _ in range(iters):
        labels = np.argmin(dist[:, medoids], axis=1)
        new = medoids.copy()
        for c in range(k):
            members = np.where(labels == c)[0]
            if members.size == 0:
                continue
            within = dist[np.ix_(members, members)].sum(axis=1)
            new[c] = members[int(np.argmin(within))]
        if np.array_equal(new, medoids):
            break
        medoids = new
    return np.argmin(dist[:, medoids], axis=1).astype(np.int64)


def kmedoids_hists(
    hists: np.ndarray, k: int, seed: int = 0, iters: int = 25
) -> np.ndarray:
    """k-medoids over Hellinger distances computed *on demand* from the
    histograms — O(K·k) memory, never forming the K x K matrix.

    Same seeding as ``kmedoids`` (k-means++-style on squared distance to
    the nearest chosen medoid), but every distance column comes from a
    ``hellinger_rows`` strip against the current medoid panel.  One
    documented deviation from PAM: the medoid update picks the member
    nearest the cluster's *mean histogram* (O(|cluster|·C)) instead of
    minimizing the within-cluster distance sum (O(|cluster|²)) — on
    label-skew geometries the two agree (see tests), and it is what
    keeps the whole procedure population-scalable.  This is the
    clustering the population hierarchy falls back to when the shard
    count itself is too large for OPTICS (DESIGN.md §15)."""
    from repro.core.hellinger import hellinger_rows

    h = np.asarray(hists, np.float32)
    rng = np.random.default_rng(seed)
    n = h.shape[0]
    k = max(1, min(int(k), n))
    medoids = [int(rng.integers(n))]
    d_near = hellinger_rows(h[medoids[-1:]], h)[0].astype(np.float64)
    for _ in range(k - 1):
        p = d_near**2
        p = p / p.sum() if p.sum() > 0 else np.full(n, 1.0 / n)
        nxt = int(rng.choice(n, p=p))
        medoids.append(nxt)
        d_near = np.minimum(d_near, hellinger_rows(h[nxt : nxt + 1], h)[0])
    med = np.array(medoids)
    for _ in range(iters):
        labels = np.argmin(hellinger_rows(h[med], h), axis=0)
        new = med.copy()
        for c in range(k):
            members = np.where(labels == c)[0]
            if members.size == 0:
                continue
            mean_h = h[members].mean(axis=0, keepdims=True)
            new[c] = members[
                int(np.argmin(hellinger_rows(mean_h, h[members])[0]))
            ]
        if np.array_equal(new, med):
            break
        med = new
    return np.argmin(hellinger_rows(h[med], h), axis=0).astype(np.int64)


def best_clustering(
    dist: np.ndarray,
    min_samples: int = 3,
    silhouette_floor: float = 0.2,
    k_range=range(3, 16),
    seed: int = 0,
) -> tuple[np.ndarray, str]:
    """OPTICS first; if its silhouette is poor (no density structure),
    sweep k-medoids over k and keep the best-silhouette clustering.
    Returns (labels, method_used).  Beyond-paper robustness layer used by
    ``fedlecc_adaptive`` (EXPERIMENTS.md §Claims K=250)."""
    res = optics(jnp.asarray(dist), min_samples=min_samples)
    labels = extract_clusters(res)
    s_opt = silhouette_score(dist, labels)
    if s_opt >= silhouette_floor:
        return labels, "optics"
    best_labels, best_s = labels, s_opt
    for k in k_range:
        if k >= dist.shape[0]:
            break
        lab = kmedoids(dist, k, seed=seed)
        s = silhouette_score(dist, lab)
        if s > best_s:
            best_labels, best_s = lab, s
    return best_labels, "kmedoids" if best_s > s_opt else "optics"


def silhouette_score(dist: np.ndarray, labels: np.ndarray) -> float:
    """Silhouette over a precomputed distance matrix (paper Table II row).

    Pure numpy; singleton clusters contribute 0 (sklearn convention).
    """
    dist = np.asarray(dist, np.float64)
    labels = np.asarray(labels)
    k = dist.shape[0]
    uniq = np.unique(labels)
    if uniq.size < 2:
        return 0.0
    s = np.zeros(k)
    for i in range(k):
        mine = labels == labels[i]
        n_mine = mine.sum()
        if n_mine <= 1:
            s[i] = 0.0
            continue
        a = dist[i, mine].sum() / (n_mine - 1)
        b = np.inf
        for c in uniq:
            if c == labels[i]:
                continue
            other = labels == c
            b = min(b, dist[i, other].mean())
        denom = max(a, b)
        s[i] = 0.0 if denom == 0 else (b - a) / denom
    return float(s.mean())
