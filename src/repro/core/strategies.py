"""Client-selection strategies behind one interface.

The paper compares FedLECC against selection-based baselines (HACCS,
FedCLS, FedCor, POC) and regularization-based ones (FedProx, FedNova,
FedDyn — those use *random* selection plus a modified local objective /
aggregation rule, implemented in ``repro.optim`` / ``repro.federated``).

Every strategy implements:

    setup(hists, client_sizes, seed, latency=None)
                                      — one-time server-side state
                                        (clustering etc.); ``latency``
                                        is the optional profile-derived
                                        per-client round time from the
                                        systems layer (DESIGN.md §10),
                                        consumed by latency-aware
                                        strategies (HACCS)
    select(rnd, losses, rng) -> (m,) int indices of selected clients
    extra_upload_bytes_per_round()    — selection-protocol overhead used
                                        by ``CommModel`` (Table III)

Availability enters every selection path the same way: when a systems
availability model is active, the engine gates the polled loss vector
to ``-inf`` for offline clients *before* calling ``select`` /
``select_mask_jax`` / ``select_mask_traced``.  Loss-ranked strategies
then avoid offline clients for free; strategies that ignore losses
(random, clusterrandom, haccs) read the ``-inf`` entries as an
exclusion mask and push those clients to the back of their own
ordering.  An offline client is therefore only ever dispatched when
the available supply runs out — and the systems layer drops it (zero
aggregation weight) even then.

Strategies register themselves into the engine registry at definition
time (``@register_strategy``); ``repro.engine`` builds them by name, so
new strategies plug in without touching any round loop.  Strategies with
a jit-compatible selection additionally expose
``select_mask_jax(losses, rng=None) -> (K,) bool mask`` and set
``supports_compiled_selection`` — that is what the mask-gated backends
(``CompiledEngine`` / ``ScaleoutEngine``) call.  The contract: any
per-round randomness is drawn host-side from ``rng`` (the same numpy
stream the host backend would consume, so backends stay in lockstep for
one seed), and the ranking itself is expressed in jax ops (top-k /
segment reductions) so the mask can live inside a compiled round.
``select`` and ``select_mask_jax`` must agree exactly for the same
inputs and rng state — the property suite asserts this.

A third, stricter tier powers the fused execution mode
(``FLConfig.fuse_rounds``, DESIGN.md §8.6): strategies whose per-round
decision can run *fully traced* — no host-side numpy in the round path,
any randomness drawn from a JAX PRNG key — expose
``select_mask_traced(losses, key) -> (K,) bool mask`` and set
``supports_traced_selection``.  For strategies deterministic given
losses (``fedlecc``, ``lossonly``, ``haccs``) the traced mask equals the
``select_mask_jax`` mask exactly; the randomized ones move their draws
onto the JAX stream — ``clusterrandom`` key-derives its scores through
the same Algorithm 1 core, ``random`` key-derives its uniform scores,
and ``poc`` replaces the host candidate draw with Gumbel-top-k over the
size weights (the exponential-race equivalence of weighted sampling
without replacement) — so their fused selections are a different, but
equally distributed, sequence than the host numpy stream.

All are host-side numpy: K scalars/vectors per round (DESIGN.md §8.5).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.clustering import cluster_label_histograms
from repro.core.hellinger import hellinger_blocked
from repro.core.selection import fedlecc_select, fedlecc_select_jax
from repro.engine.registry import STRATEGY_REGISTRY, register_strategy

__all__ = ["SelectionStrategy", "get_strategy", "STRATEGIES"]

_FLOAT_BYTES = 4


@dataclass
class SelectionStrategy:
    """Extension base: shared setup state + uniform random ``select``.

    External strategies subclass this and override ``select`` (and opt
    *in* to the jit/traced tiers by implementing ``select_mask_jax`` /
    ``select_mask_traced`` and flipping the ``supports_*`` flags — they
    default to False here so a plain subclass is host-only, and the
    mask-gated backends reject it at config construction instead of
    silently running the wrong selection).  The registered ``random``
    strategy is the ``UniformRandom`` subclass below."""

    m: int
    name: str = "random"
    needs_losses: bool = False          # does the server poll all clients for loss?
    needs_histograms: bool = False      # one-time label-histogram upload?
    supports_compiled_selection = False  # has a jit-compatible select_mask_jax?
    supports_traced_selection = False    # has a fully-traced select_mask_traced?
    K: int = field(default=0, init=False)
    client_sizes: np.ndarray | None = field(default=None, init=False)
    profile_latency: np.ndarray | None = field(default=None, init=False)

    def setup(self, hists: np.ndarray, client_sizes: np.ndarray,
              seed: int = 0, latency: np.ndarray | None = None) -> None:
        self.K = len(client_sizes)
        self.client_sizes = np.asarray(client_sizes)
        self.profile_latency = (
            None if latency is None else np.asarray(latency, np.float64)
        )

    @staticmethod
    def _gate_scores(scores: np.ndarray, losses) -> np.ndarray:
        """Push offline clients (-inf loss entries, the engine's
        availability gate) to the back of a float32 score ranking."""
        scores = np.asarray(scores, np.float32)
        if losses is None:
            return scores
        offline = np.asarray(losses, np.float32) == -np.inf
        return np.where(offline, np.float32(-np.inf), scores)

    @staticmethod
    def _gate_scores_traced(scores, losses):
        """The traced twin of ``_gate_scores`` (jnp, inside a scanned
        round chunk): offline clients' scores become -inf."""
        import jax.numpy as jnp

        if losses is None:
            return scores
        return jnp.where(
            jnp.asarray(losses, jnp.float32) == -jnp.inf, -jnp.inf, scores
        )

    def select(self, rnd: int, losses: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        gated = self._gate_scores(rng.random(self.K), losses)
        # float32 + stable argsort to match UniformRandom's jax mask
        return np.sort(np.argsort(-gated, kind="stable")[: min(self.m, self.K)])

    def extra_upload_bytes_per_round(self) -> float:
        # Loss scalars polled from all clients each round, if used.
        return float(self.K * _FLOAT_BYTES) if self.needs_losses else 0.0

    # -- checkpoint contract (DESIGN.md §12) ---------------------------
    # Every built-in strategy's setup state (cluster labels, latency,
    # K-matrices, presence traces) is a deterministic function of
    # (hists, sizes, seed, latency) and is rebuilt at engine
    # construction, so nothing needs serializing; per-round randomness
    # lives in the engine's numpy rng whose bit-generator state the
    # engine checkpoints itself.  Strategies that *do* accumulate
    # per-round state override both hooks; the structure of
    # ``state_dict()`` doubles as the restore ``like`` pytree, so it
    # must be stable for a given configuration.
    def state_dict(self) -> dict:
        """Array-valued per-round strategy state to checkpoint ({} when
        the strategy is stateless between rounds — the default)."""
        return {}

    def load_state_dict(self, state: dict) -> None:
        if state:
            raise ValueError(
                f"strategy {self.name!r} is stateless but the checkpoint "
                f"carries strategy state keys {sorted(state)} — override "
                f"load_state_dict in the strategy that wrote them"
            )


@register_strategy("random")
@dataclass
class UniformRandom(SelectionStrategy):
    """Uniform random sampling (what FedAvg/FedProx/... use).

    Implemented as top-m over host-drawn uniform scores so the numpy
    ``select`` and the jax ``select_mask_jax`` consume the identical
    rng draws and agree exactly (the ``rng.choice`` draw of the
    pre-systems implementation had no jit analog, so the rng sequence
    for a given seed changed once at this migration — uniformity is
    unchanged).  ``select_mask_traced`` moves the score draw onto the
    JAX PRNG stream (key-derived uniforms + ``lax.top_k``) so random
    selection also runs inside fused round chunks — self-consistent,
    not host-lockstep, like clusterrandom.  Scores of ``-inf``-gated
    (offline) clients are themselves gated to ``-inf``."""

    name: str = "random"
    supports_compiled_selection = True
    supports_traced_selection = True

    def select_mask_jax(self, losses, rng=None):
        import jax
        import jax.numpy as jnp

        if rng is None:
            raise ValueError("random selection draws scores host-side; pass rng")
        gated = jnp.asarray(self._gate_scores(rng.random(self.K), losses))
        _, top = jax.lax.top_k(gated, min(self.m, self.K))  # ties -> lowest index
        return jnp.zeros((self.K,), jnp.bool_).at[top].set(True)

    def select_mask_traced(self, losses, key):
        """Fused-mode selection: uniform scores from the JAX PRNG stream
        (a different — but equally uniform — sequence than the host rng
        for the same seed; fused random runs are self-consistent, not
        host-lockstep)."""
        import jax
        import jax.numpy as jnp

        scores = self._gate_scores_traced(
            jax.random.uniform(key, (self.K,)), losses
        )
        _, top = jax.lax.top_k(scores, min(self.m, self.K))
        return jnp.zeros((self.K,), jnp.bool_).at[top].set(True)


@register_strategy("fedlecc")
@dataclass
class FedLECC(SelectionStrategy):
    """The paper's strategy: OPTICS clusters + Algorithm 1.

    ``cluster="auto"`` adds the beyond-paper robustness layer: when the
    OPTICS silhouette is poor (no density structure in the HD geometry),
    fall back to a k-medoids sweep (the paper evaluated k-medoids too)."""

    J: int = 3
    min_samples: int = 3
    eps: float | str = "auto"
    cluster: str = "optics"      # optics | auto
    name: str = "fedlecc"
    needs_losses: bool = True
    needs_histograms: bool = True
    supports_compiled_selection = True
    supports_traced_selection = True
    labels: np.ndarray | None = field(default=None, init=False)
    n_clusters: int = field(default=0, init=False)
    cluster_method: str = field(default="optics", init=False)

    def setup(self, hists, client_sizes, seed: int = 0, latency=None) -> None:
        super().setup(hists, client_sizes, seed, latency=latency)
        if self.cluster == "auto":
            from repro.core.clustering import best_clustering

            # blocked build: O(K·block) device memory, and the dense
            # host matrix warns past the configurable budget (§15)
            d = hellinger_blocked(np.asarray(hists))
            self.labels, self.cluster_method = best_clustering(
                d, min_samples=self.min_samples, seed=seed
            )
        else:
            self.labels, _ = cluster_label_histograms(
                hists, min_samples=self.min_samples, eps=self.eps
            )
        self.n_clusters = int(self.labels.max()) + 1  # J_max from OPTICS

    def _round_J(self, losses: np.ndarray) -> int:
        return min(self.J, self.n_clusters)

    def select(self, rnd, losses, rng) -> np.ndarray:
        return fedlecc_select(
            self.labels, losses, m=self.m, J=self._round_J(losses)
        )

    def select_mask_jax(self, losses, rng=None):
        """(K,) boolean participation mask, computable inside jit — the
        selection hook of the mask-gated backends (verified identical to
        ``select`` by property test).  ``rng`` is accepted for protocol
        uniformity; FedLECC selection is deterministic given losses."""
        import jax.numpy as jnp

        J = max(1, min(self._round_J(np.asarray(losses)), self.n_clusters))
        return fedlecc_select_jax(
            jnp.asarray(self.labels), jnp.asarray(losses, jnp.float32),
            m=min(self.m, self.K), J=J, n_clusters=self.n_clusters,
        )

    def select_mask_traced(self, losses, key):
        """(K,) mask with ``losses`` a *traced* array (inside a scanned
        round chunk, DESIGN.md §8.6).  FedLECC's J is loss-independent
        (``fedlecc_adaptive``, whose J is data-dependent and enters
        ``fedlecc_select_jax`` as a static argument, opts out), so the
        traced mask is exactly the ``select_mask_jax`` mask."""
        import jax.numpy as jnp

        del key  # deterministic given losses
        J = max(1, min(self.J, self.n_clusters))
        return fedlecc_select_jax(
            jnp.asarray(self.labels), jnp.asarray(losses, jnp.float32),
            m=min(self.m, self.K), J=J, n_clusters=self.n_clusters,
        )


@register_strategy("poc")
@dataclass
class PowerOfChoice(SelectionStrategy):
    """POC (Cho et al., 2022): sample d candidates ~ p_i, keep top-m by loss.

    The candidate draw is host-side rng (both backends consume the same
    stream); the top-m ranking over the gated loss vector is jax
    ``top_k`` in ``select_mask_jax``, so the mask jits cleanly.  Ties are
    broken by lowest client index in both implementations.

    ``select_mask_traced`` (the fused tier, ROADMAP (j)) replaces the
    host-side candidate draw with Gumbel-top-k: adding i.i.d. Gumbel
    noise to ``log p_i`` and keeping the top d is exactly weighted
    sampling without replacement ~ p_i (the exponential-race
    equivalence), and it is pure jax ops on the JAX PRNG stream — so
    the whole per-round decision lives inside a scanned round chunk.
    Fused poc runs are self-consistent, not host-lockstep (the
    candidate sequence differs from the numpy stream), like
    clusterrandom.
    """

    d: int = 0  # candidate-set size; 0 -> max(2m, K//5)
    name: str = "poc"
    needs_losses: bool = True
    supports_compiled_selection = True
    supports_traced_selection = True

    def _d(self) -> int:
        d = self.d or max(2 * self.m, self.K // 5)
        return min(max(d, self.m), self.K)

    def _candidate_mask(self, rng: np.random.Generator) -> np.ndarray:
        """(K,) bool — the d-candidate set drawn ~ p_i without replacement."""
        d = self._d()
        p = self.client_sizes / self.client_sizes.sum()
        cand = rng.choice(self.K, size=d, replace=False, p=p)
        mask = np.zeros(self.K, bool)
        mask[cand] = True
        return mask

    def select(self, rnd, losses, rng) -> np.ndarray:
        cand = self._candidate_mask(rng)
        # float32 to match select_mask_jax exactly (same ordering + ties)
        gated = np.where(cand, np.asarray(losses, np.float32), -np.inf)
        return np.sort(np.argsort(-gated, kind="stable")[: min(self.m, self.K)])

    def select_mask_jax(self, losses, rng=None):
        import jax
        import jax.numpy as jnp

        if rng is None:
            raise ValueError("poc selection draws candidates host-side; pass rng")
        cand = jnp.asarray(self._candidate_mask(rng))
        gated = jnp.where(cand, jnp.asarray(losses, jnp.float32), -jnp.inf)
        _, top = jax.lax.top_k(gated, min(self.m, self.K))  # ties -> lowest index
        return jnp.zeros((self.K,), jnp.bool_).at[top].set(True)

    def select_mask_traced(self, losses, key):
        """Gumbel-top-k candidate draw on the JAX PRNG stream (weighted
        sampling without replacement ~ p_i), then the usual top-m over
        the candidate-gated losses — fully traced (ROADMAP (j))."""
        import jax
        import jax.numpy as jnp

        p = jnp.asarray(
            self.client_sizes / self.client_sizes.sum(), jnp.float32
        )
        race = jnp.log(jnp.maximum(p, 1e-30)) + jax.random.gumbel(key, (self.K,))
        _, cand_idx = jax.lax.top_k(race, self._d())
        cand = jnp.zeros((self.K,), jnp.bool_).at[cand_idx].set(True)
        gated = jnp.where(cand, jnp.asarray(losses, jnp.float32), -jnp.inf)
        _, top = jax.lax.top_k(gated, min(self.m, self.K))
        return jnp.zeros((self.K,), jnp.bool_).at[top].set(True)


@register_strategy("haccs")
@dataclass
class HACCS(SelectionStrategy):
    """HACCS (Wolfrath et al., 2022): histogram clusters; latency-efficient
    pick per cluster.  Device latency is the profile-derived expected
    round time when the systems layer is active (``setup``'s ``latency``
    hint, DESIGN.md §10); without a systems config it falls back to the
    legacy simulated static lognormal attribute.

    Selection is cluster-quota: proportional slots per cluster (>=1 for
    the largest), fastest devices first within each cluster, then trim /
    fill to exactly m with the globally fastest unchosen.  Both
    implementations rank clients by one lexicographic key
    ``(phase, cluster-rank, within-cluster latency rank | global latency
    rank)`` — phase 0 = inside the cluster quota, phase 1 = fill — so
    the numpy ``select`` and the jax ``select_mask_jax`` agree exactly.
    Selection ignores losses, so the mask is constant within a setup and
    trivially jit-compatible.
    """

    min_samples: int = 3
    name: str = "haccs"
    needs_histograms: bool = True
    supports_compiled_selection = True
    supports_traced_selection = True
    labels: np.ndarray | None = field(default=None, init=False)
    latency: np.ndarray | None = field(default=None, init=False)
    n_clusters: int = field(default=0, init=False)

    def setup(self, hists, client_sizes, seed: int = 0, latency=None) -> None:
        super().setup(hists, client_sizes, seed, latency=latency)
        self.labels, _ = cluster_label_histograms(hists, min_samples=self.min_samples)
        self.n_clusters = int(self.labels.max()) + 1
        if self.profile_latency is not None:
            # Profile-derived expected round seconds (repro.systems).
            self.latency = self.profile_latency
        else:
            # Simulated heterogeneous device latency (lognormal, fixed
            # per client) — the placeholder used when no systems profile
            # is configured.
            self.latency = np.random.default_rng(seed).lognormal(
                0.0, 0.5, size=self.K
            )

    def _selection_keys(self) -> np.ndarray:
        """(K,) int sort key: ascending order visits clients exactly as the
        quota algorithm does.  Computed per call (not cached at setup) so
        tests may re-plant ``labels``/``n_clusters`` after setup."""
        counts = np.bincount(self.labels, minlength=self.n_clusters)
        slots = np.maximum(np.round(self.m * counts / counts.sum()).astype(int), 0)
        largest = int(np.argmax(counts))
        if slots[largest] == 0:  # rounding can starve even the largest cluster
            slots[largest] = 1
        # cluster rank: 0 = most-populated (stable on count ties)
        crank = np.empty(self.n_clusters, np.int64)
        crank[np.argsort(-counts, kind="stable")] = np.arange(self.n_clusters)
        # within-cluster latency rank q (0 = fastest in own cluster) and
        # global latency rank g (0 = fastest overall)
        q = np.empty(self.K, np.int64)
        for c in range(self.n_clusters):
            members = np.where(self.labels == c)[0]
            q[members[np.argsort(self.latency[members], kind="stable")]] = (
                np.arange(members.size)
            )
        g = np.empty(self.K, np.int64)
        g[np.argsort(self.latency, kind="stable")] = np.arange(self.K)
        in_quota = q < slots[self.labels]
        key0 = crank[self.labels] * self.K + q       # < K*K by construction
        return np.where(in_quota, key0, self.K * self.K + g)

    # Offline clients are pushed past every online key (quota keys < K²,
    # fill keys < K²+K; the offset clears both) while keeping their
    # relative order, so they are dispatched only when the available
    # supply runs out.
    def _offline_offset(self) -> int:
        return 2 * self.K * self.K

    def select(self, rnd, losses, rng) -> np.ndarray:
        keys = self._selection_keys()
        if losses is not None:
            offline = np.asarray(losses, np.float32) == -np.inf
            keys = np.where(offline, keys + self._offline_offset(), keys)
        return np.sort(np.argsort(keys, kind="stable")[: min(self.m, self.K)])

    def select_mask_jax(self, losses, rng=None):
        import jax.numpy as jnp

        del rng  # latency-driven: deterministic given setup + availability
        keys = jnp.asarray(self._selection_keys())
        if losses is not None:
            offline = jnp.asarray(losses, jnp.float32) == -jnp.inf
            keys = jnp.where(offline, keys + self._offline_offset(), keys)
        take = jnp.argsort(keys, stable=True)[: min(self.m, self.K)]
        return jnp.zeros((self.K,), jnp.bool_).at[take].set(True)

    def select_mask_traced(self, losses, key):
        """Latency-driven selection ignores randomness; the only traced
        input is the availability gate riding the loss vector."""
        del key
        return self.select_mask_jax(losses, None)


@register_strategy("fedcs")
@dataclass
class FedCS(SelectionStrategy):
    """FedCS-style predicted-``T_i`` ranking (Nishio & Yonetani, 2019;
    ROADMAP follow-up (n)): dispatch the ``m`` *fastest* clients by the
    profile-derived expected round time — the systems layer's
    ``latency_hint`` handed to ``setup`` (DESIGN.md §10).  Offline
    clients ride the standard ``-inf`` loss gate to the back of the
    ranking, so the pick is "fastest among the currently available" —
    and under the async runtime (DESIGN.md §13), where busy in-flight
    clients are gated the same way, "fastest among the idle".

    Without a systems config there is no latency signal; scores
    degenerate to a constant and selection becomes lowest-index-first
    (deterministic, so the host/jax agreement property still holds).
    Selection ignores losses and draws no randomness, so the mask is
    trivially jit- and trace-compatible.
    """

    name: str = "fedcs"
    supports_compiled_selection = True
    supports_traced_selection = True

    def _scores(self) -> np.ndarray:
        """(K,) float32 ranking scores — faster clients score higher."""
        if self.profile_latency is None:
            return np.zeros(self.K, np.float32)
        return (-self.profile_latency).astype(np.float32)

    def select(self, rnd, losses, rng) -> np.ndarray:
        del rng  # latency-driven: deterministic given setup + availability
        gated = self._gate_scores(self._scores(), losses)
        return np.sort(np.argsort(-gated, kind="stable")[: min(self.m, self.K)])

    def select_mask_jax(self, losses, rng=None):
        import jax
        import jax.numpy as jnp

        del rng
        gated = jnp.asarray(self._gate_scores(self._scores(), losses))
        _, top = jax.lax.top_k(gated, min(self.m, self.K))  # ties -> lowest index
        return jnp.zeros((self.K,), jnp.bool_).at[top].set(True)

    def select_mask_traced(self, losses, key):
        """The only traced input is the availability gate riding the
        loss vector; the latency ranking is setup-static."""
        import jax
        import jax.numpy as jnp

        del key  # deterministic given setup + availability
        gated = self._gate_scores_traced(
            jnp.asarray(self._scores()), losses
        )
        _, top = jax.lax.top_k(gated, min(self.m, self.K))
        return jnp.zeros((self.K,), jnp.bool_).at[top].set(True)


@register_strategy("fedcls")
@dataclass
class FedCLS(SelectionStrategy):
    """FedCLS (Li & Wu, 2022): Hamming distance over binarized label
    presence; greedy selection maximizing label coverage."""

    presence_threshold: float = 0.05
    name: str = "fedcls"
    needs_histograms: bool = True
    supports_compiled_selection = False  # greedy host loop, no jit mask
    supports_traced_selection = False
    presence: np.ndarray | None = field(default=None, init=False)

    def setup(self, hists, client_sizes, seed: int = 0, latency=None) -> None:
        super().setup(hists, client_sizes, seed, latency=latency)
        h = np.asarray(hists, np.float64)
        h = h / np.maximum(h.sum(1, keepdims=True), 1e-12)
        self.presence = (h >= self.presence_threshold).astype(np.int64)  # (K, C)

    def select(self, rnd, losses, rng) -> np.ndarray:
        # Greedy max-coverage with random tie-break (Hamming gain).
        # Offline clients (-inf loss gate) score below every online gain
        # (gains are >= 0), so they are picked only as a last resort.
        offline = (
            np.asarray(losses, np.float32) == -np.inf
            if losses is not None else np.zeros(self.K, bool)
        )
        covered = np.zeros(self.presence.shape[1], dtype=np.int64)
        remaining = list(range(self.K))
        selected: list[int] = []
        for _ in range(min(self.m, self.K)):
            gains = np.array(
                [-1 if offline[i] else np.sum(self.presence[i] & (1 - covered))
                 for i in remaining]
            )
            best = np.flatnonzero(gains == gains.max())
            pick = remaining[int(rng.choice(best))]
            selected.append(pick)
            covered = np.minimum(covered + self.presence[pick], 1)
            remaining.remove(pick)
            if covered.all():
                covered[:] = 0  # restart coverage passes
        return np.sort(np.array(selected, dtype=np.int64))


@register_strategy("fedcor")
@dataclass
class FedCor(SelectionStrategy):
    """FedCor (Tang et al., 2022), lightweight variant: GP posterior over
    client losses with an RBF kernel on label-histogram HD; greedy
    max-variance-reduction selection (documented deviation, DESIGN.md §9)."""

    length_scale: float = 0.3
    noise: float = 1e-2
    name: str = "fedcor"
    needs_losses: bool = True
    needs_histograms: bool = True
    supports_compiled_selection = False  # iterative GP conditioning, host-only
    supports_traced_selection = False
    Kmat: np.ndarray | None = field(default=None, init=False)

    def setup(self, hists, client_sizes, seed: int = 0, latency=None) -> None:
        super().setup(hists, client_sizes, seed, latency=latency)
        d = hellinger_blocked(np.asarray(hists))
        self.Kmat = np.exp(-(d**2) / (2 * self.length_scale**2))

    def select(self, rnd, losses, rng) -> np.ndarray:
        # Greedy D-optimal style: repeatedly pick the client with the
        # largest posterior variance, conditioning the GP on each pick.
        # Loss magnitudes weight the prior variance (informativeness).
        # Offline clients (-inf loss gate) enter the GP with loss 0 (no
        # informativeness) and are ranked behind every online client.
        losses = np.asarray(losses, np.float64)
        offline = losses == -np.inf
        losses = np.where(offline, 0.0, losses)
        prior = self.Kmat * np.outer(losses, losses) / max(losses.max() ** 2, 1e-12)
        var = np.diag(prior).copy()
        cov = prior.copy()
        selected: list[int] = []
        for _ in range(min(self.m, self.K)):
            ranked = np.where(offline, -np.inf, var)
            cand = np.argsort(-ranked, kind="stable")
            pick = next(int(i) for i in cand if int(i) not in selected)
            selected.append(pick)
            denom = cov[pick, pick] + self.noise
            cov = cov - np.outer(cov[:, pick], cov[pick, :]) / denom
            var = np.clip(np.diag(cov).copy(), 0.0, None)
        return np.sort(np.array(selected, dtype=np.int64))


@register_strategy("lossonly")
@dataclass
class LossOnly(SelectionStrategy):
    """Ablation (RQ2): FedLECC without clustering — global top-m by loss.
    Isolates the informativeness term; the paper predicts over-
    specialization on the hardest data mode."""

    name: str = "lossonly"
    needs_losses: bool = True
    supports_compiled_selection = True
    supports_traced_selection = True

    def select(self, rnd, losses, rng) -> np.ndarray:
        # float32 to match select_mask_jax exactly (same ordering + ties)
        losses = np.asarray(losses, np.float32)
        return np.sort(np.argsort(-losses, kind="stable")[: min(self.m, self.K)])

    def select_mask_jax(self, losses, rng=None):
        import jax
        import jax.numpy as jnp

        del rng  # deterministic given losses
        _, top = jax.lax.top_k(
            jnp.asarray(losses, jnp.float32), min(self.m, self.K)
        )  # ties -> lowest index, matching the stable numpy argsort
        return jnp.zeros((self.K,), jnp.bool_).at[top].set(True)

    def select_mask_traced(self, losses, key):
        del key  # deterministic given losses
        return self.select_mask_jax(losses, None)


@register_strategy("clusterrandom")
@dataclass
class ClusterRandom(FedLECC):
    """Ablation (RQ2): FedLECC without loss guidance — same OPTICS
    clusters, but clusters and clients drawn uniformly.  Isolates the
    diversity term.

    Implemented as Algorithm 1 over *random scores*: per round the host
    draws a uniform cluster permutation and a uniform client permutation
    and composes them into one integer score vector whose cluster term
    dominates; ``fedlecc_select`` / ``fedlecc_select_jax`` on that vector
    then realize "top-J random clusters, z random members each, random-
    cluster-order backfill".  This keeps the selection uniform over
    clusters and members while reusing the already-property-tested
    numpy↔jax selection core, so the mask jits cleanly and both backends
    agree exactly.  (The rng draw sequence differs from the pre-scaleout
    implementation, so selections for a given seed changed once at that
    migration.)
    """

    name: str = "clusterrandom"
    needs_losses: bool = False
    supports_compiled_selection = True

    def _random_scores(self, rng: np.random.Generator) -> np.ndarray:
        """(K,) scores: cluster draw ≫ member draw, all values distinct.
        Integer-valued and bounded by ~n_clusters·K, so exact in the
        float32 arithmetic of ``fedlecc_select_jax`` for any realistic K.
        """
        cluster_rank = rng.permutation(self.n_clusters)  # 0 = drawn first
        client_rank = rng.permutation(self.K)
        return (
            (self.n_clusters - cluster_rank[self.labels]) * (self.K + 1)
            + (self.K - client_rank)
        ).astype(np.float64)

    def select(self, rnd, losses, rng) -> np.ndarray:
        scores = self._gate_scores(self._random_scores(rng), losses)
        return fedlecc_select(
            self.labels, scores, m=self.m,
            J=min(self.J, self.n_clusters),
        )

    def select_mask_jax(self, losses, rng=None):
        import jax.numpy as jnp

        if rng is None:
            raise ValueError(
                "clusterrandom draws its random scores host-side; pass rng"
            )
        scores = self._gate_scores(self._random_scores(rng), losses)
        return fedlecc_select_jax(
            jnp.asarray(self.labels),
            jnp.asarray(scores, jnp.float32),
            m=min(self.m, self.K),
            J=max(1, min(self.J, self.n_clusters)),
            n_clusters=self.n_clusters,
        )

    def select_mask_traced(self, losses, key):
        """Fused-mode selection: the cluster/client permutations move
        from the host numpy stream onto the JAX PRNG stream (same
        integer-score composition, same Algorithm 1 core), so the whole
        draw lives inside the scanned round chunk.  Equally uniform over
        clusters and members, but a *different* random sequence than
        ``select``/``select_mask_jax`` for the same seed — fused
        clusterrandom runs are self-consistent, not host-lockstep."""
        import jax
        import jax.numpy as jnp

        k_cluster, k_client = jax.random.split(key)
        labels = jnp.asarray(self.labels)
        cluster_rank = jax.random.permutation(k_cluster, self.n_clusters)
        client_rank = jax.random.permutation(k_client, self.K)
        scores = self._gate_scores_traced(
            (
                (self.n_clusters - cluster_rank[labels]) * (self.K + 1)
                + (self.K - client_rank)
            ).astype(jnp.float32),
            losses,
        )
        return fedlecc_select_jax(
            labels, scores, m=min(self.m, self.K),
            J=max(1, min(self.J, self.n_clusters)),
            n_clusters=self.n_clusters,
        )


@register_strategy("fedlecc_adaptive")
@dataclass
class FedLECCAdaptive(FedLECC):
    """Beyond-paper: adaptive J (the paper's stated future work, §VII).

    Per round, J is chosen from the dispersion of cluster mean losses:
    when a few clusters clearly dominate the loss mass, concentrate
    (small J → deeper per-cluster sampling); when losses are flat,
    spread out (large J → maximal diversity).  Concretely J = number of
    clusters whose mean loss ≥ (min + 0.5·(max−min)), clipped to
    [2, min(m, J_max)] — no new hyperparameter beyond the threshold.
    """

    name: str = "fedlecc_adaptive"
    # J is data-dependent but enters fedlecc_select_jax as a *static*
    # argument, so the selection cannot run fully traced.
    supports_traced_selection = False

    def _round_J(self, losses: np.ndarray) -> int:
        clusters = np.unique(self.labels)
        # availability-gated (-inf) members are excluded from the
        # dispersion estimate; clusters with nobody online drop out
        means = []
        for c in clusters:
            ls = losses[self.labels == c]
            ls = ls[ls > -np.inf]
            if ls.size:
                means.append(ls.mean())
        means = np.asarray(means)
        if means.size <= 1:
            return 1
        thr = means.min() + 0.5 * (means.max() - means.min())
        J = int((means >= thr).sum())
        return max(2, min(J, self.m, self.n_clusters))


# Deprecated alias: the registry *is* the strategy table now.  Kept so
# legacy ``from repro.core.strategies import STRATEGIES`` consumers keep
# working — it behaves like the old name → class dict.
STRATEGIES = STRATEGY_REGISTRY


def get_strategy(name: str, m: int, **kwargs) -> SelectionStrategy:
    """Build a selection strategy by name via the engine registry."""
    return STRATEGY_REGISTRY.build(name, m=m, **kwargs)
