"""Client-selection strategies behind one interface.

The paper compares FedLECC against selection-based baselines (HACCS,
FedCLS, FedCor, POC) and regularization-based ones (FedProx, FedNova,
FedDyn — those use *random* selection plus a modified local objective /
aggregation rule, implemented in ``repro.optim`` / ``repro.federated``).

Every strategy implements:

    setup(hists, client_sizes, seed)  — one-time server-side state
                                        (clustering etc.)
    select(rnd, losses, rng) -> (m,) int indices of selected clients
    extra_upload_bytes_per_round()    — selection-protocol overhead used
                                        by ``CommModel`` (Table III)

Strategies register themselves into the engine registry at definition
time (``@register_strategy``); ``repro.engine`` builds them by name, so
new strategies plug in without touching any round loop.  Strategies with
a jit-compatible selection additionally expose
``select_mask_jax(losses, rng=None) -> (K,) bool mask`` and set
``supports_compiled_selection`` — that is what the mask-gated backends
(``CompiledEngine`` / ``ScaleoutEngine``) call.  The contract: any
per-round randomness is drawn host-side from ``rng`` (the same numpy
stream the host backend would consume, so backends stay in lockstep for
one seed), and the ranking itself is expressed in jax ops (top-k /
segment reductions) so the mask can live inside a compiled round.
``select`` and ``select_mask_jax`` must agree exactly for the same
inputs and rng state — the property suite asserts this.

A third, stricter tier powers the fused execution mode
(``FLConfig.fuse_rounds``, DESIGN.md §8.6): strategies whose per-round
decision can run *fully traced* — no host-side numpy in the round path,
any randomness drawn from a JAX PRNG key — expose
``select_mask_traced(losses, key) -> (K,) bool mask`` and set
``supports_traced_selection``.  For strategies deterministic given
losses (``fedlecc``, ``lossonly``, ``haccs``) the traced mask equals the
``select_mask_jax`` mask exactly; ``clusterrandom`` moves its random
draws onto the JAX stream (key-derived scores through the same
Algorithm 1 core), so its fused selections are a different — but equally
uniform — sequence than the host numpy stream.

All are host-side numpy: K scalars/vectors per round (DESIGN.md §8.5).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.clustering import cluster_label_histograms
from repro.core.hellinger import hellinger_matrix
from repro.core.selection import fedlecc_select, fedlecc_select_jax
from repro.engine.registry import STRATEGY_REGISTRY, register_strategy

__all__ = ["SelectionStrategy", "get_strategy", "STRATEGIES"]

_FLOAT_BYTES = 4


@register_strategy("random")
@dataclass
class SelectionStrategy:
    """Base: uniform random sampling (what FedAvg/FedProx/... use)."""

    m: int
    name: str = "random"
    needs_losses: bool = False          # does the server poll all clients for loss?
    needs_histograms: bool = False      # one-time label-histogram upload?
    supports_compiled_selection = False  # has a jit-compatible select_mask_jax?
    supports_traced_selection = False    # has a fully-traced select_mask_traced?
    K: int = field(default=0, init=False)
    client_sizes: np.ndarray | None = field(default=None, init=False)

    def setup(self, hists: np.ndarray, client_sizes: np.ndarray, seed: int = 0) -> None:
        self.K = len(client_sizes)
        self.client_sizes = np.asarray(client_sizes)

    def select(self, rnd: int, losses: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        return np.sort(rng.choice(self.K, size=min(self.m, self.K), replace=False))

    def extra_upload_bytes_per_round(self) -> float:
        # Loss scalars polled from all clients each round, if used.
        return float(self.K * _FLOAT_BYTES) if self.needs_losses else 0.0


@register_strategy("fedlecc")
@dataclass
class FedLECC(SelectionStrategy):
    """The paper's strategy: OPTICS clusters + Algorithm 1.

    ``cluster="auto"`` adds the beyond-paper robustness layer: when the
    OPTICS silhouette is poor (no density structure in the HD geometry),
    fall back to a k-medoids sweep (the paper evaluated k-medoids too)."""

    J: int = 3
    min_samples: int = 3
    eps: float | str = "auto"
    cluster: str = "optics"      # optics | auto
    name: str = "fedlecc"
    needs_losses: bool = True
    needs_histograms: bool = True
    supports_compiled_selection = True
    supports_traced_selection = True
    labels: np.ndarray | None = field(default=None, init=False)
    n_clusters: int = field(default=0, init=False)
    cluster_method: str = field(default="optics", init=False)

    def setup(self, hists, client_sizes, seed: int = 0) -> None:
        super().setup(hists, client_sizes, seed)
        if self.cluster == "auto":
            from repro.core.clustering import best_clustering

            d = np.asarray(hellinger_matrix(np.asarray(hists)))
            self.labels, self.cluster_method = best_clustering(
                d, min_samples=self.min_samples, seed=seed
            )
        else:
            self.labels, _ = cluster_label_histograms(
                hists, min_samples=self.min_samples, eps=self.eps
            )
        self.n_clusters = int(self.labels.max()) + 1  # J_max from OPTICS

    def _round_J(self, losses: np.ndarray) -> int:
        return min(self.J, self.n_clusters)

    def select(self, rnd, losses, rng) -> np.ndarray:
        return fedlecc_select(
            self.labels, losses, m=self.m, J=self._round_J(losses)
        )

    def select_mask_jax(self, losses, rng=None):
        """(K,) boolean participation mask, computable inside jit — the
        selection hook of the mask-gated backends (verified identical to
        ``select`` by property test).  ``rng`` is accepted for protocol
        uniformity; FedLECC selection is deterministic given losses."""
        import jax.numpy as jnp

        J = max(1, min(self._round_J(np.asarray(losses)), self.n_clusters))
        return fedlecc_select_jax(
            jnp.asarray(self.labels), jnp.asarray(losses, jnp.float32),
            m=min(self.m, self.K), J=J, n_clusters=self.n_clusters,
        )

    def select_mask_traced(self, losses, key):
        """(K,) mask with ``losses`` a *traced* array (inside a scanned
        round chunk, DESIGN.md §8.6).  FedLECC's J is loss-independent
        (``fedlecc_adaptive``, whose J is data-dependent and enters
        ``fedlecc_select_jax`` as a static argument, opts out), so the
        traced mask is exactly the ``select_mask_jax`` mask."""
        import jax.numpy as jnp

        del key  # deterministic given losses
        J = max(1, min(self.J, self.n_clusters))
        return fedlecc_select_jax(
            jnp.asarray(self.labels), jnp.asarray(losses, jnp.float32),
            m=min(self.m, self.K), J=J, n_clusters=self.n_clusters,
        )


@register_strategy("poc")
@dataclass
class PowerOfChoice(SelectionStrategy):
    """POC (Cho et al., 2022): sample d candidates ~ p_i, keep top-m by loss.

    The candidate draw is host-side rng (both backends consume the same
    stream); the top-m ranking over the gated loss vector is jax
    ``top_k`` in ``select_mask_jax``, so the mask jits cleanly.  Ties are
    broken by lowest client index in both implementations.
    """

    d: int = 0  # candidate-set size; 0 -> max(2m, K//5)
    name: str = "poc"
    needs_losses: bool = True
    supports_compiled_selection = True

    def _candidate_mask(self, rng: np.random.Generator) -> np.ndarray:
        """(K,) bool — the d-candidate set drawn ~ p_i without replacement."""
        d = self.d or max(2 * self.m, self.K // 5)
        d = min(max(d, self.m), self.K)
        p = self.client_sizes / self.client_sizes.sum()
        cand = rng.choice(self.K, size=d, replace=False, p=p)
        mask = np.zeros(self.K, bool)
        mask[cand] = True
        return mask

    def select(self, rnd, losses, rng) -> np.ndarray:
        cand = self._candidate_mask(rng)
        # float32 to match select_mask_jax exactly (same ordering + ties)
        gated = np.where(cand, np.asarray(losses, np.float32), -np.inf)
        return np.sort(np.argsort(-gated, kind="stable")[: min(self.m, self.K)])

    def select_mask_jax(self, losses, rng=None):
        import jax
        import jax.numpy as jnp

        if rng is None:
            raise ValueError("poc selection draws candidates host-side; pass rng")
        cand = jnp.asarray(self._candidate_mask(rng))
        gated = jnp.where(cand, jnp.asarray(losses, jnp.float32), -jnp.inf)
        _, top = jax.lax.top_k(gated, min(self.m, self.K))  # ties -> lowest index
        return jnp.zeros((self.K,), jnp.bool_).at[top].set(True)


@register_strategy("haccs")
@dataclass
class HACCS(SelectionStrategy):
    """HACCS (Wolfrath et al., 2022): histogram clusters; latency-efficient
    pick per cluster.  Device latency is a simulated static attribute.

    Selection is cluster-quota: proportional slots per cluster (>=1 for
    the largest), fastest devices first within each cluster, then trim /
    fill to exactly m with the globally fastest unchosen.  Both
    implementations rank clients by one lexicographic key
    ``(phase, cluster-rank, within-cluster latency rank | global latency
    rank)`` — phase 0 = inside the cluster quota, phase 1 = fill — so
    the numpy ``select`` and the jax ``select_mask_jax`` agree exactly.
    Selection ignores losses, so the mask is constant within a setup and
    trivially jit-compatible.
    """

    min_samples: int = 3
    name: str = "haccs"
    needs_histograms: bool = True
    supports_compiled_selection = True
    supports_traced_selection = True
    labels: np.ndarray | None = field(default=None, init=False)
    latency: np.ndarray | None = field(default=None, init=False)
    n_clusters: int = field(default=0, init=False)

    def setup(self, hists, client_sizes, seed: int = 0) -> None:
        super().setup(hists, client_sizes, seed)
        self.labels, _ = cluster_label_histograms(hists, min_samples=self.min_samples)
        self.n_clusters = int(self.labels.max()) + 1
        # Simulated heterogeneous device latency (lognormal, fixed per client).
        self.latency = np.random.default_rng(seed).lognormal(0.0, 0.5, size=self.K)

    def _selection_keys(self) -> np.ndarray:
        """(K,) int sort key: ascending order visits clients exactly as the
        quota algorithm does.  Computed per call (not cached at setup) so
        tests may re-plant ``labels``/``n_clusters`` after setup."""
        counts = np.bincount(self.labels, minlength=self.n_clusters)
        slots = np.maximum(np.round(self.m * counts / counts.sum()).astype(int), 0)
        largest = int(np.argmax(counts))
        if slots[largest] == 0:  # rounding can starve even the largest cluster
            slots[largest] = 1
        # cluster rank: 0 = most-populated (stable on count ties)
        crank = np.empty(self.n_clusters, np.int64)
        crank[np.argsort(-counts, kind="stable")] = np.arange(self.n_clusters)
        # within-cluster latency rank q (0 = fastest in own cluster) and
        # global latency rank g (0 = fastest overall)
        q = np.empty(self.K, np.int64)
        for c in range(self.n_clusters):
            members = np.where(self.labels == c)[0]
            q[members[np.argsort(self.latency[members], kind="stable")]] = (
                np.arange(members.size)
            )
        g = np.empty(self.K, np.int64)
        g[np.argsort(self.latency, kind="stable")] = np.arange(self.K)
        in_quota = q < slots[self.labels]
        key0 = crank[self.labels] * self.K + q       # < K*K by construction
        return np.where(in_quota, key0, self.K * self.K + g)

    def select(self, rnd, losses, rng) -> np.ndarray:
        keys = self._selection_keys()
        return np.sort(np.argsort(keys, kind="stable")[: min(self.m, self.K)])

    def select_mask_jax(self, losses, rng=None):
        import jax.numpy as jnp

        del losses, rng  # latency-driven: deterministic given setup
        take = jnp.argsort(jnp.asarray(self._selection_keys()), stable=True)[
            : min(self.m, self.K)
        ]
        return jnp.zeros((self.K,), jnp.bool_).at[take].set(True)

    def select_mask_traced(self, losses, key):
        """Latency-driven selection ignores both losses and randomness,
        so the traced mask is a constant folded at trace time."""
        del losses, key
        return self.select_mask_jax(None, None)


@register_strategy("fedcls")
@dataclass
class FedCLS(SelectionStrategy):
    """FedCLS (Li & Wu, 2022): Hamming distance over binarized label
    presence; greedy selection maximizing label coverage."""

    presence_threshold: float = 0.05
    name: str = "fedcls"
    needs_histograms: bool = True
    presence: np.ndarray | None = field(default=None, init=False)

    def setup(self, hists, client_sizes, seed: int = 0) -> None:
        super().setup(hists, client_sizes, seed)
        h = np.asarray(hists, np.float64)
        h = h / np.maximum(h.sum(1, keepdims=True), 1e-12)
        self.presence = (h >= self.presence_threshold).astype(np.int64)  # (K, C)

    def select(self, rnd, losses, rng) -> np.ndarray:
        # Greedy max-coverage with random tie-break (Hamming gain).
        covered = np.zeros(self.presence.shape[1], dtype=np.int64)
        remaining = list(range(self.K))
        selected: list[int] = []
        for _ in range(min(self.m, self.K)):
            gains = np.array(
                [np.sum(self.presence[i] & (1 - covered)) for i in remaining]
            )
            best = np.flatnonzero(gains == gains.max())
            pick = remaining[int(rng.choice(best))]
            selected.append(pick)
            covered = np.minimum(covered + self.presence[pick], 1)
            remaining.remove(pick)
            if covered.all():
                covered[:] = 0  # restart coverage passes
        return np.sort(np.array(selected, dtype=np.int64))


@register_strategy("fedcor")
@dataclass
class FedCor(SelectionStrategy):
    """FedCor (Tang et al., 2022), lightweight variant: GP posterior over
    client losses with an RBF kernel on label-histogram HD; greedy
    max-variance-reduction selection (documented deviation, DESIGN.md §9)."""

    length_scale: float = 0.3
    noise: float = 1e-2
    name: str = "fedcor"
    needs_losses: bool = True
    needs_histograms: bool = True
    Kmat: np.ndarray | None = field(default=None, init=False)

    def setup(self, hists, client_sizes, seed: int = 0) -> None:
        super().setup(hists, client_sizes, seed)
        d = np.asarray(hellinger_matrix(np.asarray(hists)))
        self.Kmat = np.exp(-(d**2) / (2 * self.length_scale**2))

    def select(self, rnd, losses, rng) -> np.ndarray:
        # Greedy D-optimal style: repeatedly pick the client with the
        # largest posterior variance, conditioning the GP on each pick.
        # Loss magnitudes weight the prior variance (informativeness).
        prior = self.Kmat * np.outer(losses, losses) / max(losses.max() ** 2, 1e-12)
        var = np.diag(prior).copy()
        cov = prior.copy()
        selected: list[int] = []
        for _ in range(min(self.m, self.K)):
            cand = np.argsort(-var, kind="stable")
            pick = next(int(i) for i in cand if int(i) not in selected)
            selected.append(pick)
            denom = cov[pick, pick] + self.noise
            cov = cov - np.outer(cov[:, pick], cov[pick, :]) / denom
            var = np.clip(np.diag(cov).copy(), 0.0, None)
        return np.sort(np.array(selected, dtype=np.int64))


@register_strategy("lossonly")
@dataclass
class LossOnly(SelectionStrategy):
    """Ablation (RQ2): FedLECC without clustering — global top-m by loss.
    Isolates the informativeness term; the paper predicts over-
    specialization on the hardest data mode."""

    name: str = "lossonly"
    needs_losses: bool = True
    supports_compiled_selection = True
    supports_traced_selection = True

    def select(self, rnd, losses, rng) -> np.ndarray:
        # float32 to match select_mask_jax exactly (same ordering + ties)
        losses = np.asarray(losses, np.float32)
        return np.sort(np.argsort(-losses, kind="stable")[: min(self.m, self.K)])

    def select_mask_jax(self, losses, rng=None):
        import jax
        import jax.numpy as jnp

        del rng  # deterministic given losses
        _, top = jax.lax.top_k(
            jnp.asarray(losses, jnp.float32), min(self.m, self.K)
        )  # ties -> lowest index, matching the stable numpy argsort
        return jnp.zeros((self.K,), jnp.bool_).at[top].set(True)

    def select_mask_traced(self, losses, key):
        del key  # deterministic given losses
        return self.select_mask_jax(losses, None)


@register_strategy("clusterrandom")
@dataclass
class ClusterRandom(FedLECC):
    """Ablation (RQ2): FedLECC without loss guidance — same OPTICS
    clusters, but clusters and clients drawn uniformly.  Isolates the
    diversity term.

    Implemented as Algorithm 1 over *random scores*: per round the host
    draws a uniform cluster permutation and a uniform client permutation
    and composes them into one integer score vector whose cluster term
    dominates; ``fedlecc_select`` / ``fedlecc_select_jax`` on that vector
    then realize "top-J random clusters, z random members each, random-
    cluster-order backfill".  This keeps the selection uniform over
    clusters and members while reusing the already-property-tested
    numpy↔jax selection core, so the mask jits cleanly and both backends
    agree exactly.  (The rng draw sequence differs from the pre-scaleout
    implementation, so selections for a given seed changed once at that
    migration.)
    """

    name: str = "clusterrandom"
    needs_losses: bool = False
    supports_compiled_selection = True

    def _random_scores(self, rng: np.random.Generator) -> np.ndarray:
        """(K,) scores: cluster draw ≫ member draw, all values distinct.
        Integer-valued and bounded by ~n_clusters·K, so exact in the
        float32 arithmetic of ``fedlecc_select_jax`` for any realistic K.
        """
        cluster_rank = rng.permutation(self.n_clusters)  # 0 = drawn first
        client_rank = rng.permutation(self.K)
        return (
            (self.n_clusters - cluster_rank[self.labels]) * (self.K + 1)
            + (self.K - client_rank)
        ).astype(np.float64)

    def select(self, rnd, losses, rng) -> np.ndarray:
        del losses
        return fedlecc_select(
            self.labels, self._random_scores(rng), m=self.m,
            J=min(self.J, self.n_clusters),
        )

    def select_mask_jax(self, losses, rng=None):
        import jax.numpy as jnp

        del losses
        if rng is None:
            raise ValueError(
                "clusterrandom draws its random scores host-side; pass rng"
            )
        return fedlecc_select_jax(
            jnp.asarray(self.labels),
            jnp.asarray(self._random_scores(rng), jnp.float32),
            m=min(self.m, self.K),
            J=max(1, min(self.J, self.n_clusters)),
            n_clusters=self.n_clusters,
        )

    def select_mask_traced(self, losses, key):
        """Fused-mode selection: the cluster/client permutations move
        from the host numpy stream onto the JAX PRNG stream (same
        integer-score composition, same Algorithm 1 core), so the whole
        draw lives inside the scanned round chunk.  Equally uniform over
        clusters and members, but a *different* random sequence than
        ``select``/``select_mask_jax`` for the same seed — fused
        clusterrandom runs are self-consistent, not host-lockstep."""
        import jax
        import jax.numpy as jnp

        del losses
        k_cluster, k_client = jax.random.split(key)
        labels = jnp.asarray(self.labels)
        cluster_rank = jax.random.permutation(k_cluster, self.n_clusters)
        client_rank = jax.random.permutation(k_client, self.K)
        scores = (
            (self.n_clusters - cluster_rank[labels]) * (self.K + 1)
            + (self.K - client_rank)
        ).astype(jnp.float32)
        return fedlecc_select_jax(
            labels, scores, m=min(self.m, self.K),
            J=max(1, min(self.J, self.n_clusters)),
            n_clusters=self.n_clusters,
        )


@register_strategy("fedlecc_adaptive")
@dataclass
class FedLECCAdaptive(FedLECC):
    """Beyond-paper: adaptive J (the paper's stated future work, §VII).

    Per round, J is chosen from the dispersion of cluster mean losses:
    when a few clusters clearly dominate the loss mass, concentrate
    (small J → deeper per-cluster sampling); when losses are flat,
    spread out (large J → maximal diversity).  Concretely J = number of
    clusters whose mean loss ≥ (min + 0.5·(max−min)), clipped to
    [2, min(m, J_max)] — no new hyperparameter beyond the threshold.
    """

    name: str = "fedlecc_adaptive"
    # J is data-dependent but enters fedlecc_select_jax as a *static*
    # argument, so the selection cannot run fully traced.
    supports_traced_selection = False

    def _round_J(self, losses: np.ndarray) -> int:
        clusters = np.unique(self.labels)
        means = np.array([losses[self.labels == c].mean() for c in clusters])
        if means.size <= 1:
            return 1
        thr = means.min() + 0.5 * (means.max() - means.min())
        J = int((means >= thr).sum())
        return max(2, min(J, self.m, self.n_clusters))


# Deprecated alias: the registry *is* the strategy table now.  Kept so
# legacy ``from repro.core.strategies import STRATEGIES`` consumers keep
# working — it behaves like the old name → class dict.
STRATEGIES = STRATEGY_REGISTRY


def get_strategy(name: str, m: int, **kwargs) -> SelectionStrategy:
    """Build a selection strategy by name via the engine registry."""
    return STRATEGY_REGISTRY.build(name, m=m, **kwargs)
