"""Algorithm 1 — cluster- and loss-guided client selection (FedLECC §IV-C).

Inputs per round: cluster labels (fixed after the one-time clustering),
per-client local empirical losses reported after local training, targets
``J`` (clusters) and ``m`` (clients).

Steps (verbatim from the paper):
  1. z = ceil(m / J)
  2. mean loss per cluster; rank clusters by mean loss (descending)
  3. take top-J clusters; inside each, take the z highest-loss clients
  4. if |S| < m, fill remaining slots with the highest-loss clients from
     the *following* clusters, in descending cluster-mean-loss order

Two implementations:
- ``fedlecc_select``      — numpy, exact, used by the simulation server
                            (selection state is host-side; K scalars/round).
- ``fedlecc_select_jax``  — jit-compatible (static J, m, K, max clusters),
                            used when selection must live inside a compiled
                            scale-out round (the participation mask is a
                            traced value).  Verified equivalent in tests.
- ``selection_weights``   — selected set -> aggregation weight vector
                            (w_i = p_i / sum_S p, zero outside S): the mask
                            that gates the client-axis all-reduce in the
                            scale-out regime (DESIGN.md §3b).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "fedlecc_select",
    "fedlecc_select_jax",
    "selection_weights",
    "cohort_indices",
]


def fedlecc_select(
    cluster_labels: np.ndarray,
    losses: np.ndarray,
    m: int,
    J: int,
) -> np.ndarray:
    """Algorithm 1.  Returns sorted int array of selected client indices, |S| = m."""
    cluster_labels = np.asarray(cluster_labels)
    losses = np.asarray(losses, np.float64)
    k = cluster_labels.shape[0]
    m = min(int(m), k)
    clusters = np.unique(cluster_labels)
    J = max(1, min(int(J), clusters.size))
    z = math.ceil(m / J)

    # Mean loss per cluster, clusters ranked descending.  Unavailable
    # clients enter as -inf (the engine's availability gate, DESIGN.md
    # §10): they are excluded from the cluster mean — one offline member
    # must not sink its whole cluster to rank-last — and the descending
    # within-cluster sort already visits them dead last, so they are
    # picked only when the available supply runs out.
    def _cluster_mean(c):
        member_losses = losses[cluster_labels == c]
        finite = member_losses > -np.inf
        return member_losses[finite].mean() if finite.any() else -np.inf

    mean_loss = np.array([_cluster_mean(c) for c in clusters])
    ranked = clusters[np.argsort(-mean_loss, kind="stable")]

    selected: list[int] = []
    # Top-J clusters: top-z clients by loss within each.
    for c in ranked[:J]:
        members = np.where(cluster_labels == c)[0]
        take = members[np.argsort(-losses[members], kind="stable")][:z]
        selected.extend(int(i) for i in take)
        if len(selected) >= m:
            break
    selected = selected[:m]

    # Backfill (Algorithm 1 line 13): highest-loss clients from the
    # *following* clusters in descending mean-loss order; if the whole
    # tail is exhausted, fall back to leftover members of the top-J.
    if len(selected) < m:
        chosen = set(selected)
        for c in list(ranked[J:]) + list(ranked[:J]):
            members = np.where(cluster_labels == c)[0]
            for i in members[np.argsort(-losses[members], kind="stable")]:
                if int(i) not in chosen:
                    selected.append(int(i))
                    chosen.add(int(i))
                    if len(selected) >= m:
                        break
            if len(selected) >= m:
                break

    return np.sort(np.array(selected[:m], dtype=np.int64))


@partial(jax.jit, static_argnames=("m", "J", "n_clusters"))
def fedlecc_select_jax(
    cluster_labels: jax.Array,
    losses: jax.Array,
    m: int,
    J: int,
    n_clusters: int,
) -> jax.Array:
    """Jit-compatible Algorithm 1 returning a (K,) boolean participation mask.

    Strategy: build a lexicographic sort key so that one ``argsort`` orders
    clients exactly as Algorithm 1 visits them, then take the first ``m``.

    Key (descending priority):
      1. clusters ranked by mean loss — rank r(c) of the client's cluster
      2. *within-cluster* loss rank q: the first z members of each top-J
         cluster come before every backfill slot
      3. loss itself for backfill ordering

    Phases: 0 = top-J cluster, within-cluster loss-rank < z (the main
    selection); 1 = members of the *following* clusters (backfill, line
    13); 2 = leftover members of top-J clusters (last resort when the
    tail is exhausted).  Sort by (phase, r, q), take first m.  Verified
    equivalent to ``fedlecc_select`` by property test.
    """
    losses = jnp.asarray(losses, jnp.float32)
    labels = jnp.asarray(cluster_labels, jnp.int32)
    k = losses.shape[0]
    z = -(-m // J)  # ceil

    onehot = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32)   # (K, C)
    # Cluster means over *available* members only: -inf entries are the
    # engine's availability gate (DESIGN.md §10) and must neither poison
    # the sum (0 · -inf = nan) nor sink their cluster to rank-last.
    # With no -inf present this reduces bit-for-bit to the plain mean.
    valid = (losses > -jnp.inf).astype(jnp.float32)                  # (K,)
    counts = jnp.maximum((onehot * valid[:, None]).sum(0), 1e-9)     # (C,)
    gated = jnp.where(valid > 0, losses, 0.0)
    mean_loss = (onehot * gated[:, None]).sum(0) / counts            # (C,)
    # Empty clusters (no members, or no available members) rank last.
    present = (onehot * valid[:, None]).sum(0) > 0
    mean_loss = jnp.where(present, mean_loss, -jnp.inf)
    # rank r(c): 0 = highest mean loss.  argsort of argsort gives ranks.
    order = jnp.argsort(-mean_loss, stable=True)
    rank_of_cluster = jnp.argsort(order, stable=True)                # (C,)
    r = rank_of_cluster[labels]                                      # (K,)

    # Within-cluster loss rank q (0 = highest loss in own cluster).
    # Sort clients by (cluster, -loss): two stable argsorts compose into a
    # lexicographic sort without precision-losing composite float keys.
    p1 = jnp.argsort(-losses, stable=True)
    p2 = jnp.argsort(r[p1], stable=True)
    perm = p1[p2]
    # position within the cluster = index among same-cluster predecessors
    sorted_r = r[perm]
    idx = jnp.arange(k)
    # q[perm[t]] = t - first position of its cluster block
    first_pos = jnp.full((n_clusters,), k, jnp.int32).at[sorted_r].min(
        idx.astype(jnp.int32), indices_are_sorted=False
    )
    q_sorted = idx.astype(jnp.int32) - first_pos[sorted_r]
    q = jnp.zeros((k,), jnp.int32).at[perm].set(q_sorted)

    top = r < J
    phase = jnp.where(top & (q < z), 0, jnp.where(~top, 1, 2)).astype(jnp.int32)
    # Lexicographic (phase, r, q) — all bounded by K so base-(K+1) encoding.
    base = k + 1
    final_key = (phase * base + r) * base + q
    take = jnp.argsort(final_key, stable=True)[:m]
    mask = jnp.zeros((k,), jnp.bool_).at[take].set(True)
    return mask


def selection_weights(
    selected_mask: jax.Array, client_sizes: jax.Array
) -> jax.Array:
    """FedAvg aggregation weights gated by the participation mask.

    w_i = N_i / sum_{j in S} N_j  for i in S, else 0.  This vector is the
    only thing the compiled scale-out round needs from the selection
    stage: aggregation is then ``psum(w_i * theta_i)`` over the client
    mesh axis (DESIGN.md §3b).
    """
    sizes = jnp.asarray(client_sizes, jnp.float32)
    mask = jnp.asarray(selected_mask)
    gated = jnp.where(mask, sizes, 0.0)
    return gated / jnp.maximum(gated.sum(), 1e-12)


def cohort_indices(selected_mask: jax.Array, m: int) -> jax.Array:
    """(m,) sorted client indices of the participation mask, computable
    inside jit (``m`` is static, so the shape is static and the gather
    that consumes it never retraces — DESIGN.md §8.6).

    Matches ``np.where(mask)[0]`` for the masks strategies produce
    (exactly ``m`` true entries, the property-tested invariant); if a
    mask ever carried fewer, the tail pads with index 0.
    """
    return jnp.nonzero(
        jnp.asarray(selected_mask), size=m, fill_value=0
    )[0].astype(jnp.int32)
