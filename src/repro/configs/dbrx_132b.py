"""dbrx-132b [moe] — 16 experts top-4, fine-grained routing.

40L d_model=6144 48H (kv=8) d_ff(expert)=10752 vocab=100352
[hf:databricks/dbrx-base]
"""

from repro.configs.base import ModelConfig, MoEConfig, register_config

register_config(
    ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab=100352,
        moe=MoEConfig(
            n_experts=16, top_k=4, d_expert=10752, n_shared=0,
            capacity_factor=1.25, impl="capacity",
        ),
        mlp_activation="swiglu",
        source="hf:databricks/dbrx-base",
    )
)
