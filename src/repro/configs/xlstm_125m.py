"""xlstm-125m [ssm] — sLSTM + mLSTM blocks.

12L d_model=768 4H d_ff=0 vocab=50304  [arXiv:2405.04517]
Blocks alternate 3 mLSTM : 1 sLSTM (pattern "MMMS"); d_ff=0 means the
recurrent core carries its own projections (no separate FFN).
"""

from repro.configs.base import ModelConfig, SSMConfig, register_config

register_config(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        block_type="xlstm",
        layer_pattern="MMMS",
        ssm=SSMConfig(n_heads=4, chunk=256, family="xlstm"),
        source="arXiv:2405.04517",
    )
)
