"""qwen3-14b [dense] — qk_norm, GQA.

40L d_model=5120 40H (kv=8) d_ff=17408 vocab=151936  [hf:Qwen/Qwen3-8B]
head_dim=128 (Qwen3 keeps 128 regardless of d_model/n_heads).
"""

from repro.configs.base import ModelConfig, register_config

register_config(
    ModelConfig(
        name="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=17408,
        vocab=151936,
        head_dim=128,
        qk_norm=True,
        rope_theta=1e6,
        mlp_activation="swiglu",
        source="hf:Qwen/Qwen3-8B",
    )
)
