"""glm4-9b [dense] — RoPE, GQA kv=2.

40L d_model=4096 32H (kv=2) d_ff=13696 vocab=151552  [hf:THUDM/glm-4-9b]
"""

from repro.configs.base import ModelConfig, register_config

register_config(
    ModelConfig(
        name="glm4-9b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab=151552,
        rope_fraction=0.5,          # GLM uses partial (2D) rotary
        mlp_activation="swiglu",
        source="hf:THUDM/glm-4-9b",
    )
)
