"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280  [arXiv:2412.19437]
MLA dims per the paper: q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
v_head=128.  All layers MoE per the assigned config (DeepSeek's first 3
dense layers folded into the uniform stack — DESIGN.md §5).
"""

from repro.configs.base import ModelConfig, MoEConfig, register_config

register_config(
    ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=2048,
        vocab=129280,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        moe=MoEConfig(
            n_experts=256, top_k=8, d_expert=2048, n_shared=1,
            capacity_factor=1.25, impl="capacity",
        ),
        mtp=True,
        mlp_activation="swiglu",
        source="arXiv:2412.19437",
    )
)
