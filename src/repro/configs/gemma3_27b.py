"""gemma3-27b [dense] — 5:1 local:global sliding-window pattern, 128k.

62L d_model=5376 32H (kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt]  Local layers: 1024-token sliding window,
theta=10k; global layers: full attention, theta=1M.  Tied embeddings
with sqrt(d) input scaling.
"""

from repro.configs.base import ModelConfig, register_config

register_config(
    ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab=262144,
        head_dim=128,
        sliding_window=1024,
        layer_pattern="LLLLLG",
        rope_theta=10_000.0,
        rope_theta_global=1_000_000.0,
        tie_embeddings=True,
        mlp_activation="geglu",
        source="hf:google/gemma-3-1b-pt",
    )
)
