"""Architecture configs.

``get_config(name)`` returns the full assigned configuration;
``get_config(name, reduced=True)`` returns the smoke-test variant
(2 layers, d_model ≤ 512, ≤ 4 experts) of the same family.

Every config cites its source in the module docstring.
"""

from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    list_configs,
    register_config,
    INPUT_SHAPES,
    InputShape,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "get_config",
    "list_configs",
    "register_config",
    "INPUT_SHAPES",
    "InputShape",
]
