"""internvl2-1b [vlm] — InternViT + InternLM2; LM backbone implemented.

24L d_model=896 14H (kv=2) d_ff=4864 vocab=151655  [arXiv:2404.16821]
The InternViT vision tower + MLP projector are a STUB: ``input_specs``
provides 256 precomputed patch embeddings at d_model prepended to the
text tokens; loss is masked to text positions (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig, register_config

register_config(
    ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151655,
        head_dim=64,
        input_mode="vlm",
        n_patches=256,
        mlp_activation="swiglu",
        source="arXiv:2404.16821",
    )
)
