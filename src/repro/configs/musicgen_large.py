"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048  [arXiv:2306.05284]
The EnCodec conv codec frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings at d_model (DESIGN.md §5); the decoder
predicts EnCodec codes (vocab 2048).
"""

from repro.configs.base import ModelConfig, register_config

register_config(
    ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=2048,
        norm="layernorm",
        mlp_activation="gelu",
        input_mode="frames",
        source="arXiv:2306.05284",
    )
)
