"""stablelm-3b [dense] — partial RoPE, MHA.

32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304
[hf:stabilityai/stablelm-2-1_6b]  LayerNorm + GeLU MLP + 25% rotary, per
the StableLM-2 card.
"""

from repro.configs.base import ModelConfig, register_config

register_config(
    ModelConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab=50304,
        rope_fraction=0.25,
        norm="layernorm",
        mlp_activation="gelu",
        source="hf:stabilityai/stablelm-2-1_6b",
    )
)
