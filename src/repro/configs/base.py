"""Config system: model configs, input shapes, registry.

Frozen dataclasses (hashable → usable as jit static args).  Each of the
10 assigned architectures registers itself via ``register_config`` from
its own module under ``repro.configs``; ``get_config`` imports lazily.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, replace
from typing import NamedTuple

__all__ = [
    "MoEConfig",
    "SSMConfig",
    "ModelConfig",
    "InputShape",
    "INPUT_SHAPES",
    "register_config",
    "get_config",
    "list_configs",
]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    n_shared: int = 0              # always-on shared experts (deepseek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001   # load-balance aux loss
    impl: str = "dense"            # dense | capacity (shard_map expert parallel)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    conv_kernel: int = 4
    expand: int = 2                # d_inner = expand * d_ssm_in (mamba)
    n_heads: int = 4               # xlstm heads
    chunk: int = 256               # chunked-scan length
    family: str = "mamba"          # mamba | xlstm
    fuse_contraction: bool = True  # §Perf: contract C inside the chunk loop
                                   # (False = paper-faithful baseline layout)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 → d_model // n_heads
    # --- attention ---
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0   # gemma3: separate theta for global layers
    rope_fraction: float = 1.0       # partial rotary (stablelm)
    qk_norm: bool = False            # qwen3
    sliding_window: int = 0          # 0 → full attention on "local" layers too
    layer_pattern: str = "G"         # repeating pattern, L=local-window G=global
    attn_logit_softcap: float = 0.0
    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- block structure ---
    block_type: str = "attn"         # attn | hymba (attn ∥ mamba) | xlstm
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    mlp_activation: str = "swiglu"   # swiglu | geglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    mtp: bool = False                # deepseek multi-token-prediction aux head
    mtp_weight: float = 0.3
    # --- modality ---
    input_mode: str = "tokens"       # tokens | frames (audio) | vlm
    n_patches: int = 0               # vlm image-prefix length
    # --- numerics / runtime ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    attn_impl: str = "chunked"       # chunked | naive
    attn_chunk: int = 512
    loss_chunk: int = 512            # CE computed over seq chunks of this size
    remat: bool = True
    scan_unroll: int = 1             # layer-scan unroll (cost-probe lowers use 2)
    act_shard: str = ""              # ""|"dp_all"|"dp_data": per-layer activation
                                     # sharding constraint (§Perf iteration 2)
    source: str = ""                 # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model ≤ 512, ≤ 4 experts —
        same family / block structure / attention flavour."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        hd = max(16, d // heads)
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=min(self.moe.d_expert, 128),
                n_shared=min(self.moe.n_shared, 1),
                impl="dense",
            )
        ssm = None
        if self.ssm is not None:
            ssm = replace(self.ssm, n_heads=min(self.ssm.n_heads, 2), chunk=64)
        kw = {}
        if self.use_mla:
            kw = dict(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=hd,
                      qk_rope_head_dim=16, v_head_dim=hd)
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            moe=moe,
            ssm=ssm,
            attn_chunk=64,
            loss_chunk=64,
            dtype="float32",
            **kw,
        )


class InputShape(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}

_ARCH_MODULES = [
    "deepseek_v3_671b", "glm4_9b", "hymba_1_5b", "stablelm_3b",
    "musicgen_large", "internvl2_1b", "dbrx_132b", "xlstm_125m",
    "qwen3_14b", "gemma3_27b",
]


def register_config(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all():
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown config {name!r}; available: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]
    return cfg.reduced() if reduced else cfg


def list_configs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)
