"""Input construction: ShapeDtypeStruct specs (dry-run) + dummy batches
(smoke tests) for every (arch × input shape) combination.

Audio/VLM carve-out (the one permitted stub): the modality frontend is
replaced by precomputed frame/patch embeddings of the right shape —
``input_specs`` emits them directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

__all__ = ["input_specs", "dummy_batch", "decode_specs", "dummy_decode_batch", "long_context_variant"]


def _f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape | str):
    """ShapeDtypeStruct stand-ins for a *full-sequence* batch
    (train/prefill).  For decode shapes use ``decode_specs``."""
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "tokens":
        batch = {"tokens": _f((b, s), jnp.int32)}
    elif cfg.input_mode == "frames":
        batch = {"frames": _f((b, s, cfg.d_model), jnp.bfloat16)}
    else:  # vlm: patches prefix + text tokens
        p = cfg.n_patches
        batch = {
            "patches": _f((b, p, cfg.d_model), jnp.bfloat16),
            "tokens": _f((b, s - p), jnp.int32),
        }
    if shape.kind == "train":
        batch["labels"] = _f((b, s), jnp.int32)
    return batch


def decode_specs(cfg: ModelConfig, shape: InputShape | str):
    """Specs for the one-token decode step (cache specs come from
    ``repro.models.transformer.init_cache`` via eval_shape)."""
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    b = shape.global_batch
    if cfg.input_mode == "frames":
        return {"frame": _f((b, 1, cfg.d_model), jnp.bfloat16)}
    return {"token": _f((b, 1), jnp.int32)}


def dummy_batch(cfg: ModelConfig, batch_size: int, seq_len: int, seed: int = 0):
    """Concrete random batch (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    if cfg.input_mode == "tokens":
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch_size, seq_len)), jnp.int32)}
    elif cfg.input_mode == "frames":
        batch = {
            "frames": jnp.asarray(
                rng.normal(0, 1, (batch_size, seq_len, cfg.d_model)), jnp.dtype(cfg.dtype)
            )
        }
    else:
        p = cfg.n_patches
        batch = {
            "patches": jnp.asarray(
                rng.normal(0, 1, (batch_size, p, cfg.d_model)), jnp.dtype(cfg.dtype)
            ),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch_size, seq_len - p)), jnp.int32
            ),
        }
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (batch_size, seq_len)), jnp.int32)
    return batch


def dummy_decode_batch(cfg: ModelConfig, batch_size: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    if cfg.input_mode == "frames":
        return {
            "frame": jnp.asarray(
                rng.normal(0, 1, (batch_size, 1, cfg.d_model)), jnp.dtype(cfg.dtype)
            )
        }
    return {"token": jnp.asarray(rng.integers(0, cfg.vocab, (batch_size, 1)), jnp.int32)}


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """The documented sliding-window variant used for ``long_500k`` on
    architectures whose citation is pure full attention (DESIGN.md §5).

    SSM/hybrid archs and gemma3 (native SWA pattern) are returned
    unchanged; everything else gets window=4096 on all layers.
    """
    from dataclasses import replace

    native_subquadratic = (
        cfg.block_type in ("xlstm", "hymba") or (cfg.sliding_window and "L" in cfg.layer_pattern)
    )
    if native_subquadratic:
        return cfg
    return replace(
        cfg,
        name=cfg.name + "+swa4k",
        sliding_window=4096,
        layer_pattern="L",
    )
