"""hymba-1.5b [hybrid] — parallel attention + Mamba heads per layer.

32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001 ssm_state=16
[arXiv:2411.13676]  Sliding-window attention everywhere except 3 global
layers (first / middle / last), per the Hymba paper.
"""

from repro.configs.base import ModelConfig, SSMConfig, register_config

_PATTERN = "".join("G" if i in (0, 15, 31) else "L" for i in range(32))

register_config(
    ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab=32001,
        head_dim=64,
        block_type="hymba",
        ssm=SSMConfig(d_state=16, conv_kernel=4, chunk=256, family="mamba"),
        sliding_window=1024,
        layer_pattern=_PATTERN,
        mlp_activation="swiglu",
        source="arXiv:2411.13676",
    )
)
