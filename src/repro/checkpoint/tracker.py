"""Metrics trackers: a pluggable seam for streaming ``RoundResult``s
somewhere durable.

The engine's in-memory ``history`` dict dies with the process; a
``MetricsTracker`` attached via ``make_engine(..., tracker=...)`` (or
``engine.trackers.append(...)``) receives every round — evaluated or
not — as it is committed, before any checkpoint fires for that round.

Delivery is **at-least-once** under resume: a killed run may have
logged rounds past the last checkpoint, so after a restore the same
round can appear twice in the stream. Rows carry the round index;
readers should dedupe on it, keeping the last occurrence.

``JsonlTracker`` is the reference implementation: one JSON object per
line, flushed per row so a kill loses at most the in-flight line.
"""

from __future__ import annotations

import json
import os
from typing import Any

import numpy as np

__all__ = ["MetricsTracker", "JsonlTracker"]


def _to_builtin(x: Any) -> Any:
    """Recursively convert numpy / jax scalars and arrays to plain
    Python so ``json`` (and msgpack meta) can serialize them."""
    if isinstance(x, dict):
        return {k: _to_builtin(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_to_builtin(v) for v in x]
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    if hasattr(x, "item") and hasattr(x, "dtype"):  # jax scalar arrays
        arr = np.asarray(x)
        return arr.item() if arr.ndim == 0 else arr.tolist()
    return x


class MetricsTracker:
    """Base tracker. Subclasses override ``log_round``; ``close`` is
    called by ``engine.close_trackers()`` / context-manager exits."""

    def log_round(self, result) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:
        pass


class JsonlTracker(MetricsTracker):
    """Append-only JSONL: one line per round.

    Schema per line: every ``RoundResult`` field (``round``, ``selected``
    as a list, ``mean_selected_loss``, ``comm_mb``, ``test_loss``/
    ``test_acc`` (null when the round wasn't evaluated), ``sim_clock``/
    ``n_dropped`` (null without a systems layer), and the flattened
    ``metrics`` dict under ``"metrics"``).
    """

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")

    def log_round(self, result) -> None:
        import dataclasses

        row = _to_builtin(dataclasses.asdict(result))
        self._f.write(json.dumps(row, sort_keys=True) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f.closed:
            return
        self._f.flush()
        try:
            os.fsync(self._f.fileno())
        except OSError:
            pass
        self._f.close()


def read_jsonl(path: str) -> list[dict]:
    """Read a tracker file back, deduping by round (last occurrence
    wins — the at-least-once contract under resume)."""
    by_round: dict[int, dict] = {}
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            by_round[int(row["round"])] = row
    return [by_round[r] for r in sorted(by_round)]
