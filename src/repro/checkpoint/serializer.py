"""Pytree checkpointing: magic header + msgpack envelope + raw
little-endian array bytes.

On-disk format::

    b"REPROCKPT\\x02"                       # magic + format version byte
    msgpack map {
      "version": 2,
      "treedef": <str(jax.tree.structure(pytree))>,
      "leaves": [{"dtype": str, "shape": [..], "data": bytes}, ...],
      "meta": {...user metadata, msgpack-safe...},
    }

Leaves are stored in ``jax.tree.flatten`` order; ``load_checkpoint``
restores into the structure of a caller-supplied ``like`` pytree (the
usual "init the model, then restore" pattern) and verifies, loudly:

- the magic header (a foreign / garbage file is rejected up front);
- the envelope unpacks (a truncated file fails with a clear error, not
  a bare msgpack exception);
- the stored treedef string equals the ``like`` treedef (a structure
  mismatch is an error, not a diagnostic footnote);
- leaf count, and per leaf: **dtype**, shape, and payload byte length
  against the ``like`` leaf — a dtype mismatch must never silently
  reinterpret bytes.

``save_checkpoint`` is crash-durable: the payload is written to a
sibling ``.tmp`` file which is fsync'd *before* the atomic
``os.replace``, and the containing directory is fsync'd after — so a
crash at any point leaves either the old checkpoint or the complete new
one, never a truncated file under the final name.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import msgpack
import numpy as np

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "load_meta",
    "CheckpointError",
    "FORMAT_VERSION",
]

FORMAT_VERSION = 2
_MAGIC = b"REPROCKPT\x02"


class CheckpointError(ValueError):
    """The checkpoint *file* is unusable — foreign, truncated, or
    corrupt (bad magic, unparseable envelope, wrong format version,
    payload-length mismatch).  Distinct from the plain ``ValueError``\\ s
    raised for structural mismatches against the caller's ``like`` /
    config, so resume logic can fall back to an older file on
    corruption without masking a wrong-experiment mistake."""


def _fsync_dir(path: str) -> None:
    """fsync the directory containing ``path`` so the rename itself is
    durable (POSIX; best-effort where directories can't be opened)."""
    dirname = os.path.dirname(os.path.abspath(path)) or "."
    try:
        fd = os.open(dirname, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(path: str, pytree: Any, meta: dict | None = None) -> None:
    leaves, treedef = jax.tree.flatten(pytree)
    payload = {
        "version": FORMAT_VERSION,
        "treedef": str(treedef),
        "leaves": [
            {
                "dtype": str(np.asarray(leaf).dtype),
                "shape": list(np.asarray(leaf).shape),
                "data": np.ascontiguousarray(np.asarray(leaf)).tobytes(),
            }
            for leaf in leaves
        ],
        "meta": meta or {},
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_MAGIC)
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())  # the payload must be on disk before the rename
    os.replace(tmp, path)  # atomic on POSIX
    _fsync_dir(path)       # ... and the rename must survive a crash too


def _read_payload(path: str) -> dict:
    """Read + verify the msgpack envelope (magic, unpack, version)."""
    with open(path, "rb") as f:
        raw = f.read()
    if not raw.startswith(_MAGIC):
        raise CheckpointError(
            f"{path!r} is not a repro checkpoint (bad magic header; "
            f"expected it to start with {_MAGIC!r})"
        )
    try:
        payload = msgpack.unpackb(raw[len(_MAGIC):], raw=False)
    except Exception as e:
        raise CheckpointError(
            f"checkpoint {path!r} is truncated or corrupt "
            f"(msgpack envelope failed to unpack: {e})"
        ) from None
    if not isinstance(payload, dict) or payload.get("version") != FORMAT_VERSION:
        got = payload.get("version") if isinstance(payload, dict) else None
        raise CheckpointError(
            f"unsupported checkpoint version {got!r} in {path!r} "
            f"(this reader supports version {FORMAT_VERSION})"
        )
    return payload


def load_meta(path: str) -> dict:
    """Read just the metadata dict of a checkpoint, without needing (or
    checking) a ``like`` structure.  The async engine uses this to learn
    the in-flight ledger's shape *before* building the ``like`` skeleton
    that ``load_checkpoint`` verifies the arrays against."""
    return _read_payload(path)["meta"]


def load_checkpoint(path: str, like: Any) -> tuple[Any, dict]:
    """Restore a checkpoint into the structure of ``like``; returns
    ``(pytree, meta)``.  Raises ``ValueError`` with an actionable message
    on any structural or per-leaf mismatch (see module docstring)."""
    payload = _read_payload(path)
    like_leaves, treedef = jax.tree.flatten(like)
    if payload["treedef"] != str(treedef):
        raise ValueError(
            "checkpoint treedef does not match the target structure — "
            "refusing to restore into a different pytree:\n"
            f"  checkpoint: {payload['treedef']}\n"
            f"  target:     {treedef}"
        )
    stored = payload["leaves"]
    if len(stored) != len(like_leaves):  # defense in depth behind treedef
        raise ValueError(
            f"leaf count mismatch: checkpoint has {len(stored)}, "
            f"target structure has {len(like_leaves)}"
        )
    out = []
    for i, (ref, item) in enumerate(zip(like_leaves, stored)):
        ref_arr = np.asarray(ref)
        dtype = np.dtype(item["dtype"])
        if dtype != ref_arr.dtype:
            raise ValueError(
                f"dtype mismatch at leaf {i}: checkpoint stores "
                f"{dtype}, target expects {ref_arr.dtype} — refusing to "
                f"reinterpret bytes; restore into a pytree with matching "
                f"dtypes (or re-save the checkpoint)"
            )
        shape = tuple(item["shape"])
        if shape != ref_arr.shape:
            raise ValueError(
                f"shape mismatch at leaf {i}: checkpoint stores {shape}, "
                f"target expects {ref_arr.shape}"
            )
        n_expected = dtype.itemsize * int(np.prod(shape, dtype=np.int64))
        if len(item["data"]) != n_expected:
            raise CheckpointError(
                f"payload length mismatch at leaf {i}: got "
                f"{len(item['data'])} bytes, expected {n_expected} "
                f"({dtype} × {shape}) — the checkpoint is corrupt"
            )
        out.append(np.frombuffer(item["data"], dtype=dtype).reshape(shape).copy())
    return jax.tree.unflatten(treedef, out), payload["meta"]
