"""Pytree checkpointing: msgpack envelope + raw little-endian array bytes.

Format (msgpack map):
  {"version": 1,
   "treedef": <str repr used only for mismatch diagnostics>,
   "leaves": [{"dtype": str, "shape": [..], "data": bytes}, ...],
   "meta": {...user metadata...}}

Leaves are stored in ``jax.tree.flatten`` order; ``load_checkpoint``
restores into the structure of a caller-supplied ``like`` pytree (the
usual "init the model, then restore" pattern), verifying dtype/shape.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import msgpack
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]


def save_checkpoint(path: str, pytree: Any, meta: dict | None = None) -> None:
    leaves, treedef = jax.tree.flatten(pytree)
    payload = {
        "version": 1,
        "treedef": str(treedef),
        "leaves": [
            {
                "dtype": str(np.asarray(leaf).dtype),
                "shape": list(np.asarray(leaf).shape),
                "data": np.ascontiguousarray(np.asarray(leaf)).tobytes(),
            }
            for leaf in leaves
        ],
        "meta": meta or {},
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)  # atomic on POSIX


def load_checkpoint(path: str, like: Any) -> tuple[Any, dict]:
    """Restore a checkpoint into the structure of ``like``; returns (pytree, meta)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    if payload["version"] != 1:
        raise ValueError(f"unsupported checkpoint version {payload['version']}")
    like_leaves, treedef = jax.tree.flatten(like)
    stored = payload["leaves"]
    if len(stored) != len(like_leaves):
        raise ValueError(
            f"leaf count mismatch: checkpoint has {len(stored)}, "
            f"target structure has {len(like_leaves)} "
            f"(checkpoint treedef: {payload['treedef']})"
        )
    out = []
    for ref, item in zip(like_leaves, stored):
        arr = np.frombuffer(item["data"], dtype=np.dtype(item["dtype"])).reshape(
            item["shape"]
        )
        ref_arr = np.asarray(ref)
        if tuple(arr.shape) != tuple(ref_arr.shape):
            raise ValueError(f"shape mismatch: {arr.shape} vs {ref_arr.shape}")
        out.append(arr.copy())
    return jax.tree.unflatten(treedef, out), payload["meta"]
