"""Checkpointing substrate (msgpack + raw ndarray bytes, no orbax offline)."""

from repro.checkpoint.serializer import save_checkpoint, load_checkpoint

__all__ = ["save_checkpoint", "load_checkpoint"]
