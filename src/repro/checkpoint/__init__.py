"""Checkpointing substrate (msgpack + raw ndarray bytes, no orbax
offline) plus save policies and the metrics-tracker seam.

- ``serializer`` — atomic, fsync-durable pytree save/load with loud
  dtype/shape/treedef verification.
- ``policy`` — ``CheckpointPolicy`` (every-N-rounds / every-T-seconds /
  keep-last) and ``Checkpointer`` driven from ``engine.rounds()``.
- ``tracker`` — ``MetricsTracker`` seam; ``JsonlTracker`` lands every
  streamed ``RoundResult`` durably.
"""

from repro.checkpoint.serializer import (
    CheckpointError,
    load_checkpoint,
    load_meta,
    save_checkpoint,
)
from repro.checkpoint.policy import (
    CheckpointPolicy,
    Checkpointer,
    checkpoint_paths,
    latest_checkpoint,
)
from repro.checkpoint.tracker import JsonlTracker, MetricsTracker, read_jsonl

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "load_meta",
    "CheckpointError",
    "CheckpointPolicy",
    "Checkpointer",
    "latest_checkpoint",
    "checkpoint_paths",
    "MetricsTracker",
    "JsonlTracker",
    "read_jsonl",
]
