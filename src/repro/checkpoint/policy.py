"""Checkpoint scheduling: when to save, where, and what to keep.

``CheckpointPolicy`` is the declarative half — *save every N rounds
and/or every T seconds, keep the last k checkpoints*. ``Checkpointer``
binds a policy to a directory and is driven from the engine's
``rounds()`` stream: ``maybe_save(engine, rnd)`` fires after round
``rnd`` has been committed to the engine state, writes atomically
through ``repro.checkpoint.serializer`` (tmp + fsync + rename), and
prunes old files per ``keep_last``.

Round triggers are **absolute**: a save fires after round ``rnd`` iff
``(rnd + 1) % every_rounds == 0`` — a pure function of the round index,
independent of where a ``rounds()`` call started. The fused backend
relies on this to align its scan-chunk boundaries with save points so a
resumed run replays the identical chunk pattern (DESIGN.md §12).

Checkpoint files are named ``round_<NNNNNNNN>.ckpt`` (the number is the
*next* round to run, i.e. ``engine._round`` at save time), so
``latest_checkpoint(dir)`` is a lexicographic max.
"""

from __future__ import annotations

import os
import re
import time
from dataclasses import dataclass
from typing import Callable

__all__ = [
    "CheckpointPolicy",
    "Checkpointer",
    "latest_checkpoint",
    "checkpoint_paths",
]

_CKPT_RE = re.compile(r"^round_(\d{8})\.ckpt$")


def _ckpt_name(next_round: int) -> str:
    return f"round_{next_round:08d}.ckpt"


def latest_checkpoint(directory: str) -> str | None:
    """Path of the most recent checkpoint in ``directory`` (highest
    round number), or ``None`` if there is none / no such directory."""
    try:
        entries = os.listdir(directory)
    except FileNotFoundError:
        return None
    hits = sorted(e for e in entries if _CKPT_RE.match(e))
    return os.path.join(directory, hits[-1]) if hits else None


def checkpoint_paths(directory: str) -> list[str]:
    """All checkpoint paths in ``directory``, newest first — the resume
    fallback order: ``make_engine(resume=dir)`` walks this list when the
    newest file turns out truncated or corrupt."""
    try:
        entries = os.listdir(directory)
    except FileNotFoundError:
        return []
    hits = sorted((e for e in entries if _CKPT_RE.match(e)), reverse=True)
    return [os.path.join(directory, e) for e in hits]


@dataclass(frozen=True)
class CheckpointPolicy:
    """Declarative save schedule.

    - ``every_rounds``: save after round ``rnd`` when
      ``(rnd + 1) % every_rounds == 0`` (absolute cadence). ``None``
      disables the round trigger.
    - ``every_seconds``: also save when at least this much wall time has
      passed since the last save. ``None`` disables the time trigger.
    - ``keep_last``: prune to the newest k checkpoint files after each
      save. ``None`` keeps everything.
    """

    every_rounds: int | None = 1
    every_seconds: float | None = None
    keep_last: int | None = None

    def __post_init__(self) -> None:
        if self.every_rounds is not None and self.every_rounds < 1:
            raise ValueError(f"every_rounds must be >= 1, got {self.every_rounds}")
        if self.every_seconds is not None and self.every_seconds <= 0:
            raise ValueError(f"every_seconds must be > 0, got {self.every_seconds}")
        if self.keep_last is not None and self.keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {self.keep_last}")
        if self.every_rounds is None and self.every_seconds is None:
            raise ValueError("policy has no trigger: set every_rounds or every_seconds")

    def round_due(self, rnd: int) -> bool:
        return self.every_rounds is not None and (rnd + 1) % self.every_rounds == 0

    def time_due(self, elapsed: float) -> bool:
        return self.every_seconds is not None and elapsed >= self.every_seconds


class Checkpointer:
    """Binds a :class:`CheckpointPolicy` to a directory and an engine.

    ``clock`` is injectable for tests (defaults to ``time.monotonic``).
    """

    def __init__(self, directory: str, policy: CheckpointPolicy | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.directory = directory
        self.policy = policy or CheckpointPolicy()
        self._clock = clock
        self._last_save_t = clock()
        os.makedirs(directory, exist_ok=True)

    # -- schedule ------------------------------------------------------
    def round_due(self, rnd: int) -> bool:
        """True iff the *round* trigger fires after round ``rnd``. The
        fused backend uses this (and only this — time triggers can't be
        predicted inside a scan) to align chunk boundaries."""
        return self.policy.round_due(rnd)

    def due(self, rnd: int) -> bool:
        return self.round_due(rnd) or self.policy.time_due(
            self._clock() - self._last_save_t
        )

    # -- actions -------------------------------------------------------
    def save(self, engine) -> str:
        """Unconditional save of the engine's committed state."""
        path = os.path.join(self.directory, _ckpt_name(engine._round))
        engine.save(path)
        self._last_save_t = self._clock()
        self._prune()
        return path

    def maybe_save(self, engine, rnd: int) -> str | None:
        """Save iff the policy says a save is due after round ``rnd``."""
        return self.save(engine) if self.due(rnd) else None

    def latest(self) -> str | None:
        return latest_checkpoint(self.directory)

    def _prune(self) -> None:
        k = self.policy.keep_last
        if k is None:
            return
        hits = sorted(e for e in os.listdir(self.directory) if _CKPT_RE.match(e))
        for stale in hits[:-k]:
            os.remove(os.path.join(self.directory, stale))
