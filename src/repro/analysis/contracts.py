"""Jaxpr / compile contract checks (tracecheck layer 2, DESIGN.md §11).

Where the AST lint (``repro.analysis.lint``) checks what the *source*
promises, this module checks what the *tracer and compiler* actually
produce, on tiny canonical configs:

- **mask-shape** — for every registered mask strategy × task shape,
  ``select_mask_jax`` (and ``select_mask_traced`` where supported)
  traces under ``jax.make_jaxpr`` / ``jax.eval_shape`` to a static
  ``(K,)`` boolean mask.  A shape or dtype drift here breaks the static
  cohort gather silently (wrong weights), not loudly.
- **no-callback** — the traced masks contain no host-callback
  primitives (``pure_callback`` / ``io_callback``) anywhere in the
  jaxpr, including nested pjit sub-jaxprs: a callback inside the fused
  chunk reintroduces the per-round host sync the fused engine exists to
  remove.
- **donation** — the fused chunk's *compiled* executable really aliases
  the donated ``(params, key)`` carry: its HLO text declares
  ``input_output_alias`` (the lowering-level marker; jax only emits it
  when ``donate_argnums`` survived to XLA).
- **retrace** — driving multi-round ``rounds()`` on each backend stays
  within ``RETRACE_BUDGET`` compilations per jitted callable, across
  *separate* ``rounds()`` calls; the fused engine compiles at most
  ``FUSED_CHUNK_BUDGET`` distinct chunk lengths (round-0 chunk,
  steady-state chunk, tail — see ``FusedEngine``).

Everything here needs jax and a few seconds of CPU compile time, so the
module is imported lazily by the CLI (never by ``repro.analysis``'s
package ``__init__``).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "BANNED_CALLBACK_PRIMITIVES",
    "ContractReport",
    "ContractResult",
    "FUSED_CHUNK_BUDGET",
    "RETRACE_BUDGET",
    "TASK_SHAPES",
    "run_contracts",
]

BANNED_CALLBACK_PRIMITIVES = ("pure_callback", "io_callback")

# One compile per jitted callable per engine lifetime — the budget the
# no-retrace guard tests pin per backend; violating it means a traced
# value (python scalar, changing shape) leaked into the trace signature.
RETRACE_BUDGET = 1
# Distinct fused chunk lengths with an aligned fuse_rounds/eval_every:
# the round-0 chunk, the steady-state chunk, and the tail.
FUSED_CHUNK_BUDGET = 3

# The task axis enters mask selection through its canonical shape
# triple: (K clients, cohort m, feature-histogram bins) — classification
# clusters on n_classes-bin label histograms, LM on hist_bins topic
# histograms (the conformance-grid configs in tests/conftest.py).
TASK_SHAPES: dict[str, tuple[int, int, int]] = {
    "classification": (12, 4, 10),
    "lm": (8, 3, 16),
}


@dataclass(frozen=True)
class ContractResult:
    """One contract check: ``name`` passed/failed/skipped with detail."""

    name: str
    ok: bool
    detail: str = ""
    skipped: bool = False

    def to_dict(self) -> dict:
        return {
            "name": self.name, "ok": self.ok,
            "skipped": self.skipped, "detail": self.detail,
        }

    def __str__(self) -> str:
        status = "SKIP" if self.skipped else ("ok" if self.ok else "FAIL")
        return f"[{status}] {self.name}" + (f" — {self.detail}" if self.detail else "")


@dataclass
class ContractReport:
    results: list[ContractResult] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(r.ok or r.skipped for r in self.results)

    def to_dict(self) -> dict:
        return {"ok": self.ok, "results": [r.to_dict() for r in self.results]}


class SkipContract(Exception):
    """Raised by a check that cannot run in this environment."""


def _run(report: ContractReport, name: str, fn) -> None:
    try:
        detail = fn() or ""
        report.results.append(ContractResult(name, True, detail))
    except SkipContract as e:
        report.results.append(ContractResult(name, True, str(e), skipped=True))
    except Exception as e:  # noqa: BLE001 — a contract check failing IS the signal
        report.results.append(
            ContractResult(name, False, f"{type(e).__name__}: {e}")
        )


# ---------------------------------------------------------------- fixtures
def _planted_histograms(K: int, C: int, G: int = 3, seed: int = 0) -> np.ndarray:
    """Label histograms with G planted modes (same construction as the
    cluster tests) so OPTICS-based strategies see real density structure."""
    rng = np.random.default_rng(seed)
    modes = rng.dirichlet(np.ones(C) * 0.2, size=G)
    assign = np.arange(K) % G
    return np.stack([rng.dirichlet(modes[g] * 200.0 + 1e-3) for g in assign])


def _strategy(name: str, K: int, m: int, C: int):
    from repro.core.strategies import get_strategy

    strat = get_strategy(name, m=m)
    rng = np.random.default_rng(0)
    strat.setup(_planted_histograms(K, C), rng.integers(20, 61, size=K))
    return strat


def _tiny_engine(**overrides):
    """A tiny classification engine (12 clients, 16-dim features) —
    seconds to compile, enough to exercise every jit in a backend."""
    from repro.data import make_classification
    from repro.engine import FLConfig, make_engine

    cfg_kw = dict(
        n_clients=12, m=4, rounds=4, strategy="fedlecc",
        strategy_kwargs={"J": 3}, hidden=(16,), eval_samples=16,
        eval_every=2, target_hd=0.8, seed=0,
    )
    cfg_kw.update(overrides)
    cfg = FLConfig(**cfg_kw)
    train = make_classification(240, n_features=16, n_classes=10, seed=0)
    test = make_classification(80, n_features=16, n_classes=10, seed=1)
    return make_engine(cfg, train, test, n_classes=10)


# ---------------------------------------------------------------- jaxpr walk
def _sub_jaxprs(val):
    if hasattr(val, "jaxpr") and hasattr(getattr(val, "jaxpr"), "eqns"):
        yield val.jaxpr  # ClosedJaxpr
    elif hasattr(val, "eqns"):
        yield val  # raw Jaxpr
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from _sub_jaxprs(item)


def _walk_eqns(jaxpr):
    """Every equation in a jaxpr, recursing into sub-jaxprs carried in
    eqn params (pjit bodies, scan bodies, cond branches, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from _walk_eqns(sub)


def _assert_no_callbacks(closed, what: str) -> None:
    for eqn in _walk_eqns(closed.jaxpr):
        if eqn.primitive.name in BANNED_CALLBACK_PRIMITIVES:
            raise AssertionError(
                f"{what}: banned host-callback primitive "
                f"{eqn.primitive.name!r} in the traced mask"
            )


def _assert_mask_aval(avals, K: int, what: str) -> None:
    import jax.numpy as jnp

    if len(avals) != 1:
        raise AssertionError(f"{what}: expected one output, got {len(avals)}")
    aval = avals[0]
    if tuple(aval.shape) != (K,):
        raise AssertionError(
            f"{what}: mask shape {tuple(aval.shape)} != static ({K},)"
        )
    if aval.dtype != jnp.bool_:
        raise AssertionError(f"{what}: mask dtype {aval.dtype} != bool")


# ---------------------------------------------------------------- checks
def _check_masks(report: ContractReport) -> None:
    import jax
    import jax.numpy as jnp

    from repro.engine.registry import (
        mask_selection_strategies,
        traced_selection_strategies,
    )

    traced_names = set(traced_selection_strategies())
    for task, (K, m, C) in TASK_SHAPES.items():
        losses = jnp.linspace(0.1, 2.0, K).astype(jnp.float32)
        for name in mask_selection_strategies():
            strat = _strategy(name, K, m, C)

            def compiled_check(strat=strat, name=name, task=task, K=K,
                               losses=losses):
                what = f"{name}×{task}.select_mask_jax"
                # Some strategies legitimately make *host* decisions from
                # the concrete loss vector before staging the mask math
                # (fedlecc's static J, the host-rng score draws): the
                # backends call select_mask_jax eagerly once per round.
                # Try the stronger abstract-losses trace first; fall back
                # to staging with losses held concrete (a nullary
                # make_jaxpr), which still proves the mask computation is
                # host-sync-free with a static (K,) bool output.
                try:
                    rng = np.random.default_rng(0)
                    closed = jax.make_jaxpr(
                        lambda l: strat.select_mask_jax(l, rng)
                    )(losses)
                    out = jax.eval_shape(
                        lambda l: strat.select_mask_jax(
                            l, np.random.default_rng(0)
                        ),
                        losses,
                    )
                    _assert_mask_aval([out], K, what + " (eval_shape)")
                    mode = "abstract losses"
                except (jax.errors.TracerArrayConversionError,
                        jax.errors.ConcretizationTypeError):
                    losses_np = np.asarray(losses)
                    rng = np.random.default_rng(0)
                    closed = jax.make_jaxpr(
                        lambda: strat.select_mask_jax(losses_np, rng)
                    )()
                    mode = "host-static losses"
                _assert_mask_aval(closed.out_avals, K, what)
                _assert_no_callbacks(closed, what)
                return f"(K,)=({K},) bool, no callbacks ({mode})"

            _run(report, f"mask-jaxpr/{task}/{name}/compiled", compiled_check)

            if name in traced_names:
                def traced_check(strat=strat, name=name, task=task, K=K,
                                 losses=losses):
                    what = f"{name}×{task}.select_mask_traced"
                    key = jax.random.PRNGKey(0)
                    closed = jax.make_jaxpr(strat.select_mask_traced)(
                        losses, key
                    )
                    _assert_mask_aval(closed.out_avals, K, what)
                    _assert_no_callbacks(closed, what)
                    out = jax.eval_shape(strat.select_mask_traced, losses, key)
                    _assert_mask_aval([out], K, what + " (eval_shape)")
                    return f"(K,)=({K},) bool, no callbacks"

                _run(report, f"mask-jaxpr/{task}/{name}/traced", traced_check)


def _check_donation(report: ContractReport) -> None:
    def donation() -> str:
        import jax

        eng = _tiny_engine(backend="compiled", fuse_rounds=2)
        step = eng._chunk_step(2)
        lowered = step.lower(eng.params, jax.random.PRNGKey(0))
        txt = lowered.compile().as_text()
        if "input_output_alias" not in txt:
            raise AssertionError(
                "fused chunk executable declares no input_output_alias — "
                "the (params, key) carry donation was dropped"
            )
        return "chunk(len=2) HLO declares input_output_alias for the carry"

    _run(report, "donation/fused-chunk-carry", donation)


def _drive_twice(eng, per_call: int = 2) -> None:
    """Two separate rounds() calls — retraces *across* calls are exactly
    the regression this guard exists for."""
    for _ in eng.rounds(per_call):
        pass
    for _ in eng.rounds(per_call):
        pass


def _check_retrace(report: ContractReport) -> None:
    def host() -> str:
        eng = _tiny_engine(backend="host")
        _drive_twice(eng)
        return _assert_budget(eng, ("_round_train", "_poll_losses", "_evaluate"))

    def compiled() -> str:
        eng = _tiny_engine(backend="compiled")
        _drive_twice(eng)
        return _assert_budget(
            eng, ("_train_cohort", "_masked_weights", "_poll_losses", "_evaluate")
        )

    def fused() -> str:
        eng = _tiny_engine(backend="compiled", fuse_rounds=2)
        # 4 rounds in one call hits both the round-0 length-1 chunk and
        # the steady-state length-2 chunk; the second call must reuse
        # both cache entries, not recompile.
        for _ in eng.rounds(4):
            pass
        for _ in eng.rounds(2):
            pass
        if len(eng._chunk_cache) > FUSED_CHUNK_BUDGET:
            raise AssertionError(
                f"{len(eng._chunk_cache)} distinct fused chunk lengths "
                f"compiled (budget {FUSED_CHUNK_BUDGET})"
            )
        sizes = {
            length: fn._cache_size() for length, fn in eng._chunk_cache.items()
        }
        over = {k: v for k, v in sizes.items() if v > RETRACE_BUDGET}
        if over:
            raise AssertionError(f"fused chunk retraced: {over}")
        extra = _assert_budget(eng, ("_poll_losses", "_evaluate"))
        return f"chunk lengths {sorted(sizes)} × 1 compile; {extra}"

    def scaleout() -> str:
        import jax

        if len(jax.devices()) < 2:
            raise SkipContract(
                "scaleout needs >1 device (covered by the tier-1 subprocess "
                "tests with XLA_FLAGS=--xla_force_host_platform_device_count)"
            )
        eng = _tiny_engine(backend="scaleout")
        _drive_twice(eng)
        return _assert_budget(eng, ("_round_fn", "_poll_losses", "_evaluate"))

    _run(report, "retrace/host", host)
    _run(report, "retrace/compiled", compiled)
    _run(report, "retrace/fused", fused)
    _run(report, "retrace/scaleout", scaleout)


def _assert_budget(eng, attrs: tuple[str, ...]) -> str:
    sizes = {}
    for attr in attrs:
        fn = getattr(eng, attr, None)
        if fn is None or not hasattr(fn, "_cache_size"):
            continue
        sizes[attr] = fn._cache_size()
    over = {k: v for k, v in sizes.items() if v > RETRACE_BUDGET}
    if over:
        raise AssertionError(
            f"compile budget {RETRACE_BUDGET} exceeded: {over} "
            f"(a traced value leaked into the trace signature)"
        )
    return ", ".join(f"{k}×{v}" for k, v in sorted(sizes.items()))


def run_contracts() -> ContractReport:
    """Run every contract check; never raises — failures land in the
    report (the CLI turns them into a non-zero exit)."""
    report = ContractReport()
    _check_masks(report)
    _check_donation(report)
    _check_retrace(report)
    return report
