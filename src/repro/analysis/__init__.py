"""repro.analysis — "tracecheck": static verification of the engine's
tracing, PRNG, and donation contracts (DESIGN.md §11).

The engine rests on invariants that nothing used to check until a test
happened to trip over them at runtime: no-retrace guarantees in the
compiled/fused paths, strict ``fold_in``/``split`` PRNG-stream
discipline across four backends, donated ``(params, key)`` carries, and
per-strategy capability flags that must agree with the methods actually
defined.  This package checks them *before any round runs*, in two
layers:

- **AST lint** (``repro.analysis.lint`` + ``repro.analysis.rules``) —
  repo-specific rules over the ``repro`` source tree: global-state RNG,
  host-sync idioms inside traced code in the jit hot paths, PRNG key
  derivation and single-consumption discipline, capability-flag ↔
  method consistency, and explicit static/donate decisions on every
  ``jax.jit``.  Pure ``ast`` — importing this layer never imports jax.
- **Trace/compile contract checks** (``repro.analysis.contracts``) —
  for every registered mask strategy, trace ``select_mask_jax`` /
  ``select_mask_traced`` per task and assert a static ``(K,)`` boolean
  mask whose jaxpr contains no callback primitives; verify the fused
  chunk executable actually donates the ``(params, key)`` carry; and a
  retrace sentinel that drives ``rounds()`` on every backend and fails
  if any jit compiles more than its documented budget.

CLI: ``python -m repro.analysis`` (exit non-zero on violations,
``--json`` report) — wired as the CI ``static`` job.  Suppress a lint
finding with an inline pragma: ``# tracecheck: disable=<rule>[,<rule>]``
on the offending line, or ``# tracecheck: disable-file[=<rules>]`` on a
line of its own.
"""

from repro.analysis.lint import (
    HOT_PATH_MODULES,
    LintReport,
    Violation,
    default_root,
    lint_paths,
    lint_source,
    run_lint,
)
from repro.analysis.rules import RULES, rule_catalog

__all__ = [
    "HOT_PATH_MODULES",
    "LintReport",
    "RULES",
    "Violation",
    "default_root",
    "lint_paths",
    "lint_source",
    "rule_catalog",
    "run_lint",
]
