"""AST lint driver for the tracecheck rules (DESIGN.md §11).

Stdlib-only by design: linting the tree must never import the modules
it checks (and must work in environments without jax).  Rules live in
``repro.analysis.rules`` and receive a parsed ``ast.Module`` plus a
``FileContext``; this module owns file discovery, pragma suppression,
and report assembly.

Suppression pragmas (comments, matched per physical line):

- ``# tracecheck: disable=<rule>[,<rule>...]`` — suppress the named
  rules on that line (attach to the offending line).
- ``# tracecheck: disable`` — suppress every rule on that line.
- ``# tracecheck: disable-file[=<rules>]`` — on a line of its own,
  suppress the named rules (or all) for the whole file.
"""

from __future__ import annotations

import ast
import re
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Iterable, Sequence

__all__ = [
    "FileContext",
    "HOT_PATH_MODULES",
    "LintReport",
    "Violation",
    "default_root",
    "lint_paths",
    "lint_source",
    "run_lint",
]

# Modules whose traced inner functions are jit hot paths: host-sync
# idioms inside their traced code are round-time performance bugs, not
# style (paths relative to the ``repro`` package root; a trailing ``/``
# marks a package prefix).
HOT_PATH_MODULES: tuple[str, ...] = (
    "engine/compiled.py",
    "engine/fused.py",
    "engine/scaleout.py",
    "core/selection.py",
    "kernels/",
)

_PRAGMA = re.compile(
    r"#\s*tracecheck:\s*disable(?P<scope>-file)?(?:=(?P<rules>[\w.,\- ]+))?"
)


@dataclass(frozen=True)
class Violation:
    """One lint finding: ``rule`` at ``path:line:col`` with a message."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def to_dict(self) -> dict:
        return asdict(self)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


@dataclass(frozen=True)
class FileContext:
    """Per-file information handed to every rule."""

    path: str                  # display path (repo-relative when possible)
    rel_module: str            # posix path relative to the package root
    source: str
    is_hot_path: bool


@dataclass
class LintReport:
    """All violations of one lint run plus the files covered."""

    violations: list[Violation] = field(default_factory=list)
    files_checked: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {
            "files_checked": self.files_checked,
            "violations": [v.to_dict() for v in self.violations],
        }


def default_root() -> Path:
    """The ``repro`` package directory — the library-code lint scope."""
    return Path(__file__).resolve().parent.parent


def _is_hot_path(rel_module: str) -> bool:
    for pat in HOT_PATH_MODULES:
        if pat.endswith("/"):
            if rel_module.startswith(pat):
                return True
        elif rel_module == pat:
            return True
    return False


def _pragma_suppressions(source: str) -> tuple[dict[int, set[str] | None], set[str] | None]:
    """Line → suppressed rule names (``None`` = all rules), plus the
    file-level suppression set (``None`` = all, empty set = none)."""
    per_line: dict[int, set[str] | None] = {}
    file_level: set[str] | None = set()
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA.search(text)
        if not m:
            continue
        rules_txt = m.group("rules")
        rules = (
            None if rules_txt is None
            else {r.strip() for r in rules_txt.split(",") if r.strip()}
        )
        if m.group("scope"):
            if rules is None or file_level is None:
                file_level = None
            else:
                file_level |= rules
        else:
            per_line[lineno] = rules
    return per_line, file_level


def _suppressed(v: Violation, per_line: dict[int, set[str] | None],
                file_level: set[str] | None) -> bool:
    if file_level is None or v.rule in file_level:
        return True
    rules = per_line.get(v.line, set())
    return rules is None or v.rule in (rules or set())


def lint_source(source: str, path: str = "<string>", *,
                rel_module: str = "", rules: Sequence[str] | None = None,
                hot_path: bool | None = None) -> list[Violation]:
    """Lint one source string (the unit-test entry point).

    ``rel_module`` is the package-relative posix path used for hot-path
    scoping; ``hot_path`` overrides the scoping decision outright.
    ``rules`` restricts the run to the named rules (default: all).
    """
    from repro.analysis.rules import RULES

    tree = ast.parse(source, filename=path)
    ctx = FileContext(
        path=path,
        rel_module=rel_module,
        source=source,
        is_hot_path=_is_hot_path(rel_module) if hot_path is None else hot_path,
    )
    selected = RULES if rules is None else {n: RULES[n] for n in rules}
    found: list[Violation] = []
    for rule in selected.values():
        found.extend(rule.check(tree, ctx))
    per_line, file_level = _pragma_suppressions(source)
    return sorted(
        (v for v in found if not _suppressed(v, per_line, file_level)),
        key=lambda v: (v.path, v.line, v.col, v.rule),
    )


def lint_paths(paths: Iterable[Path], root: Path, *,
               rules: Sequence[str] | None = None) -> LintReport:
    """Lint the given files, reporting paths relative to the repo root
    when possible (falling back to absolute)."""
    report = LintReport()
    repo_root = root.parent.parent if root.name == "repro" else root
    for p in sorted(paths):
        rel_module = p.relative_to(root).as_posix()
        try:
            display = str(p.relative_to(repo_root))
        except ValueError:
            display = str(p)
        source = p.read_text()
        try:
            report.violations.extend(
                lint_source(source, display, rel_module=rel_module, rules=rules)
            )
        except SyntaxError as e:
            report.violations.append(Violation(
                rule="parse-error", path=display, line=e.lineno or 0,
                col=e.offset or 0, message=f"cannot parse: {e.msg}",
            ))
        report.files_checked += 1
    return report


def run_lint(root: Path | None = None, *,
             rules: Sequence[str] | None = None) -> LintReport:
    """Lint every ``.py`` file under ``root`` (default: the installed
    ``repro`` package — library code only, not tests or benchmarks)."""
    root = root or default_root()
    files = [
        p for p in root.rglob("*.py") if "__pycache__" not in p.parts
    ]
    return lint_paths(files, root, rules=rules)
