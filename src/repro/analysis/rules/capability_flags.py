"""Rule ``capability-flags`` — strategy capability flags match methods.

The mask-gated backends dispatch on two class-level capability flags
(``repro/core/strategies.py``): ``supports_compiled_selection`` promises
``select_mask_jax`` and ``supports_traced_selection`` promises
``select_mask_traced``.  A flag without its method crashes the first
compiled/fused round that uses the strategy; a method without its flag
is silently never used.  Both directions are checked.

Resolution is over the *local* class chain — bases defined in the same
file are followed (so ``ClusterRandom(FedLECC)`` sees FedLECC's methods
and ``FedLECCAdaptive``'s explicit ``supports_traced_selection = False``
opt-out is honoured against the inherited method).  When any base is
imported from elsewhere, the "method missing" direction is skipped —
the runtime guard in ``repro.engine.registry.register_strategy``
performs the same check over the real MRO at import time.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Violation
from repro.analysis.rules import Rule, register_rule

_PAIRS = (
    ("supports_compiled_selection", "select_mask_jax"),
    ("supports_traced_selection", "select_mask_traced"),
)


def _own_flag(cls: ast.ClassDef, flag: str) -> bool | None:
    """The flag's literal bool value assigned in this class body, or None."""
    for stmt in cls.body:
        targets: list[ast.expr] = []
        value: ast.expr | None = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        for t in targets:
            if isinstance(t, ast.Name) and t.id == flag:
                if isinstance(value, ast.Constant) and isinstance(value.value, bool):
                    return value.value
    return None


def _own_method(cls: ast.ClassDef, method: str) -> bool:
    return any(
        isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
        and stmt.name == method
        for stmt in cls.body
    )


@register_rule
class CapabilityFlags(Rule):
    name = "capability-flags"
    description = (
        "supports_compiled_selection/supports_traced_selection must match "
        "select_mask_jax/select_mask_traced definitions, both directions"
    )

    def check(self, tree: ast.Module, ctx: FileContext) -> Iterable[Violation]:
        local: dict[str, ast.ClassDef] = {
            n.name: n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
        }

        def chain(cls: ast.ClassDef) -> tuple[list[ast.ClassDef], bool]:
            """(MRO-ordered local chain, every-base-resolved?)."""
            out, complete, todo = [], True, [cls]
            while todo:
                c = todo.pop(0)
                if c in out:
                    continue
                out.append(c)
                for base in c.bases:
                    if isinstance(base, ast.Name) and base.id == "object":
                        continue
                    if isinstance(base, ast.Name) and base.id in local:
                        todo.append(local[base.id])
                    else:
                        complete = False
            return out, complete

        for cls in local.values():
            mro, complete = chain(cls)
            for flag, method in _PAIRS:
                effective = next(
                    (v for c in mro if (v := _own_flag(c, flag)) is not None),
                    None,
                )
                in_chain = any(_own_method(c, method) for c in mro)
                if effective is True and not in_chain and complete:
                    yield self.violation(
                        ctx, cls,
                        f"class {cls.name!r} advertises {flag} = True but "
                        f"neither it nor its (local) bases define {method}()",
                    )
                if _own_method(cls, method) and _own_flag(cls, flag) is False:
                    yield self.violation(
                        ctx, cls,
                        f"class {cls.name!r} defines {method}() but sets "
                        f"{flag} = False in the same body — the backends "
                        f"will never call it",
                    )
                if (
                    _own_method(cls, method)
                    and effective is not True
                    and complete
                    and _own_flag(cls, flag) is not False
                ):
                    yield self.violation(
                        ctx, cls,
                        f"class {cls.name!r} defines {method}() but never "
                        f"sets {flag} = True — the mask-gated backends will "
                        f"silently skip it",
                    )
