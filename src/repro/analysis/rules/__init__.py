"""The tracecheck rule registry and shared AST helpers.

Each rule module defines a ``Rule`` subclass and registers an instance
with ``@register_rule``; ``RULES`` maps rule name → instance.  Rules are
pure functions of ``(ast.Module, FileContext)`` returning ``Violation``
lists — no imports of the code under analysis, no jax.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Violation

__all__ = [
    "RULES",
    "Rule",
    "dotted_name",
    "register_rule",
    "rule_catalog",
]

RULES: dict[str, "Rule"] = {}


class Rule:
    """Base class: ``name`` identifies the rule (and its pragma key),
    ``description`` feeds the catalog in DESIGN.md §11 / ``--list``."""

    name: str = ""
    description: str = ""

    def check(self, tree: ast.Module, ctx: FileContext) -> Iterable[Violation]:
        raise NotImplementedError

    def violation(self, ctx: FileContext, node: ast.AST, message: str) -> Violation:
        return Violation(
            rule=self.name, path=ctx.path,
            line=getattr(node, "lineno", 0), col=getattr(node, "col_offset", 0),
            message=message,
        )


def register_rule(cls: type[Rule]) -> type[Rule]:
    inst = cls()
    if not inst.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if inst.name in RULES:
        raise ValueError(f"duplicate rule {inst.name!r}")
    RULES[inst.name] = inst
    return cls


def rule_catalog() -> list[tuple[str, str]]:
    """Sorted (name, description) pairs for ``--list`` and the docs."""
    return sorted((r.name, r.description) for r in RULES.values())


# ---------------------------------------------------------------- helpers
def dotted_name(node: ast.AST) -> str | None:
    """``jax.random.split`` → ``"jax.random.split"`` (None for anything
    that is not a plain Name/Attribute chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve_aliases(tree: ast.Module) -> dict[str, str]:
    """Import-alias map: local name → canonical dotted module path.

    ``import jax.random as jr`` → ``{"jr": "jax.random"}``;
    ``from jax import random`` → ``{"random": "jax.random"}``;
    ``import numpy as np`` → ``{"np": "numpy"}``.
    """
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def canonical_call_name(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Resolve a call target through import aliases to its canonical
    dotted path (``jr.split`` → ``jax.random.split``)."""
    dotted = dotted_name(node)
    if dotted is None:
        return None
    head, _, rest = dotted.partition(".")
    root = aliases.get(head, head)
    return f"{root}.{rest}" if rest else root


# Rule modules register themselves on import (kept at the bottom so the
# helpers above exist when they do).
from repro.analysis.rules import (  # noqa: E402,F401
    capability_flags,
    global_rng,
    host_sync,
    jit_static,
    prng,
)
