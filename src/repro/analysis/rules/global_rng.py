"""Rule ``no-global-rng`` — no global-state RNG in library code.

Every random draw in the repro tree is reproducible because it comes
from an explicitly seeded stream: a ``np.random.default_rng(seed)``
generator or a jax PRNG key.  Calls that mutate or read the *module
level* numpy/stdlib RNG state (``np.random.normal``, ``np.random.seed``,
``random.random``, ...) silently couple components through hidden global
state and break the per-(seed, round) determinism the systems layer and
the conformance suite depend on.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Violation
from repro.analysis.rules import Rule, canonical_call_name, register_rule, resolve_aliases

# Constructors of *seeded, local* state are fine; everything else on
# numpy.random is a module-level draw or a global-state mutation.
_NUMPY_ALLOWED = {
    "default_rng", "Generator", "RandomState", "SeedSequence",
    "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64", "BitGenerator",
}


@register_rule
class NoGlobalRNG(Rule):
    name = "no-global-rng"
    description = (
        "no module-level RNG (np.random.* draws, random.*, random.seed) in "
        "library code — use a seeded np.random.default_rng or a jax PRNG key"
    )

    def check(self, tree: ast.Module, ctx: FileContext) -> Iterable[Violation]:
        aliases = resolve_aliases(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = canonical_call_name(node.func, aliases)
            if name is None:
                continue
            if name.startswith("numpy.random."):
                tail = name.split(".", 2)[2]
                if tail.split(".")[0] not in _NUMPY_ALLOWED:
                    yield self.violation(
                        ctx, node,
                        f"module-level numpy RNG call {name!r} draws from "
                        f"hidden global state; use a seeded "
                        f"np.random.default_rng(seed) generator",
                    )
            elif name.startswith("random.") and aliases.get("random", "") == "random":
                yield self.violation(
                    ctx, node,
                    f"stdlib global RNG call {name!r}; use a seeded "
                    f"np.random.default_rng(seed) or a jax PRNG key",
                )
