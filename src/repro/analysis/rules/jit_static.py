"""Rule ``jit-static-donate`` — every ``jax.jit`` states its decision.

A bare ``jax.jit(fn)`` in library code leaves two contracts implicit:
which arguments are static (retrace triggers hide here — an unmarked
python scalar retraces on every distinct value), and whether the input
buffers are donated (the fused engine's whole perf story is the donated
carry).  The rule requires every jit site to carry at least one of
``static_argnums`` / ``static_argnames`` / ``donate_argnums`` /
``donate_argnames`` — ``donate_argnums=()`` is the explicit "nothing
static, nothing donated" decision.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Violation
from repro.analysis.rules import Rule, canonical_call_name, register_rule, resolve_aliases

_JIT_NAMES = {"jax.jit", "jax.api.jit"}
_DECISION_KWARGS = {
    "static_argnums", "static_argnames", "donate_argnums", "donate_argnames",
}


def _is_jit(node: ast.AST, aliases: dict[str, str]) -> bool:
    return canonical_call_name(node, aliases) in _JIT_NAMES


@register_rule
class JitStaticDonate(Rule):
    name = "jit-static-donate"
    description = (
        "every jax.jit call/decorator must make its static/donate decision "
        "explicit (static_argnums/static_argnames/donate_argnums/"
        "donate_argnames; use donate_argnums=() for 'neither')"
    )

    def check(self, tree: ast.Module, ctx: FileContext) -> Iterable[Violation]:
        aliases = resolve_aliases(tree)

        bare_msg = (
            "bare jax.jit: state the static/donate decision explicitly "
            "(add static_argnums/static_argnames or donate_argnums — "
            "donate_argnums=() means 'nothing static, nothing donated')"
        )

        # Decorators that are the bare name (@jax.jit) are never calls.
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if not isinstance(dec, ast.Call) and _is_jit(dec, aliases):
                        yield self.violation(ctx, dec, bare_msg)

        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            kwargs: set[str] = set()
            is_jit_site = False
            if _is_jit(node.func, aliases):
                # jax.jit(fn, ...) or @jax.jit(...)
                is_jit_site = True
                kwargs = {k.arg for k in node.keywords if k.arg}
            elif canonical_call_name(node.func, aliases) in (
                "functools.partial", "partial",
            ) and node.args and _is_jit(node.args[0], aliases):
                # partial(jax.jit, ...) decorator form
                is_jit_site = True
                kwargs = {k.arg for k in node.keywords if k.arg}
            if is_jit_site and not (kwargs & _DECISION_KWARGS):
                yield self.violation(ctx, node, bare_msg)
