"""PRNG key-discipline rules: ``prng-key-reuse`` and ``prng-sampler-key``.

The whole repo's determinism story (DESIGN.md §4, §11) hangs on a
strict key discipline: one 3-way ``split`` per round off a persisted
carry, per-client keys via ``fold_in(key, client_index)``, and side
streams on fold tags ≥ K.  Two statically checkable contracts fall out:

- **prng-key-reuse** — a key is *consumed* by ``jax.random.split`` and
  by every sampler (``normal``, ``choice``, ``gumbel``, ...).  Consuming
  the same key twice silently correlates two draws that the paper's
  algorithm treats as independent.  ``fold_in`` / ``clone`` / ``PRNGKey``
  do not consume — deriving many tagged streams from one key is the
  idiom, not the bug.
- **prng-sampler-key** — a sampler must never eat a *root* key
  (``PRNGKey(seed)`` inline or via a local variable): root keys are for
  deriving streams with ``split``/``fold_in``, so every draw has an
  auditable position in the key tree.

The reuse tracker is deliberately definite-violations-only: it follows
local ``Name`` bindings through straight-line code, copies state across
``if`` branches, and walks loop bodies twice to catch cross-iteration
reuse.  Keys reaching a function as parameters, flowing through
attributes/subscripts, or passed to non-``jax.random`` callables are
left alone — those flows need runtime information.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Violation
from repro.analysis.rules import Rule, canonical_call_name, register_rule, resolve_aliases

# jax.random callables that do NOT consume their key argument.
_NONCONSUMING = {
    "PRNGKey", "key", "fold_in", "clone", "key_data", "wrap_key_data",
    "key_impl", "unsafe_rbg_key",
}
# Everything else on jax.random taking a key first consumes it;
# ``split`` consumes but is also the sanctioned deriver.
_ROOT_MAKERS = {"PRNGKey", "key"}
_DERIVERS = {"split", "fold_in", "clone"}

_FRESH = "fresh"
_CONSUMED = "consumed"
_ROOT = "root"  # fresh, but assigned straight from PRNGKey()


def _random_tail(node: ast.Call, aliases: dict[str, str]) -> str | None:
    """``jr.split`` → ``"split"`` if the call targets jax.random, else None."""
    name = canonical_call_name(node.func, aliases)
    if name is None or not name.startswith("jax.random."):
        return None
    tail = name[len("jax.random."):]
    return tail if "." not in tail else None


class _Tracker:
    """Per-function ordered walk over statements, tracking Name → key state."""

    def __init__(self, rule: Rule, ctx: FileContext, aliases: dict[str, str],
                 check_reuse: bool, check_root: bool):
        self.rule = rule
        self.ctx = ctx
        self.aliases = aliases
        self.check_reuse = check_reuse
        self.check_root = check_root
        self.state: dict[str, str] = {}
        self.violations: list[Violation] = []
        self._reported: set[tuple[int, int]] = set()

    # -- events ----------------------------------------------------------
    def _emit(self, node: ast.AST, message: str) -> None:
        pos = (getattr(node, "lineno", 0), getattr(node, "col_offset", 0))
        if pos not in self._reported:
            self._reported.add(pos)
            self.violations.append(self.rule.violation(self.ctx, node, message))

    def _consume(self, arg: ast.expr, call: ast.Call, what: str) -> None:
        if not isinstance(arg, ast.Name):
            return
        status = self.state.get(arg.id)
        if status == _CONSUMED:
            if self.check_reuse:
                self._emit(
                    call,
                    f"PRNG key {arg.id!r} is consumed a second time by "
                    f"jax.random.{what}; split or fold_in a fresh key for "
                    f"each independent draw",
                )
        else:
            if status == _ROOT and self.check_root and what != "split":
                self._emit(
                    call,
                    f"jax.random.{what} consumes root key {arg.id!r} "
                    f"(assigned from PRNGKey); derive a per-use key with "
                    f"split/fold_in first",
                )
            self.state[arg.id] = _CONSUMED

    def _visit_expr(self, expr: ast.expr) -> None:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            tail = _random_tail(node, self.aliases)
            if tail is None or tail in _NONCONSUMING or not node.args:
                continue
            if tail != "split" and self.check_root and isinstance(
                node.args[0], ast.Call
            ):
                inner = _random_tail(node.args[0], self.aliases)
                if inner in _ROOT_MAKERS:
                    self._emit(
                        node,
                        f"jax.random.{tail} consumes an inline "
                        f"jax.random.{inner}(...) root key; derive a "
                        f"per-use key with split/fold_in first",
                    )
            self._consume(node.args[0], node, tail)

    def _assign_target(self, target: ast.expr, status: str | None) -> None:
        """Re-binding a name resets its key state (``None`` = untrack)."""
        if isinstance(target, ast.Name):
            if status is None:
                self.state.pop(target.id, None)
            else:
                self.state[target.id] = status
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._assign_target(el, status)
        # attribute / subscript targets: untrackable, ignore

    def _value_status(self, value: ast.expr) -> str | None:
        if isinstance(value, ast.Call):
            tail = _random_tail(value, self.aliases)
            if tail in _ROOT_MAKERS:
                return _ROOT
            if tail in _DERIVERS:
                return _FRESH
        return None

    # -- statement walk --------------------------------------------------
    def run(self, body: list[ast.stmt]) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # separate scope; handled by its own tracker
        if isinstance(stmt, ast.Assign):
            self._visit_expr(stmt.value)
            status = self._value_status(stmt.value)
            for t in stmt.targets:
                self._assign_target(t, status)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._visit_expr(stmt.value)
            self._assign_target(stmt.target, self._value_status(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            self._visit_expr(stmt.value)
            self._assign_target(stmt.target, None)
        elif isinstance(stmt, ast.If):
            self._visit_expr(stmt.test)
            before = dict(self.state)
            self.run(stmt.body)
            after_body = self.state
            self.state = dict(before)
            self.run(stmt.orelse)
            after_else = self.state
            # keep only names whose state agrees across both branches
            self.state = {
                k: v for k, v in after_body.items()
                if after_else.get(k) == v
            }
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._visit_expr(stmt.iter)
            for _pass in range(2):  # second pass catches cross-iteration reuse
                self._assign_target(stmt.target, None)
                self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, ast.While):
            for _pass in range(2):
                self._visit_expr(stmt.test)
                self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._visit_expr(item.context_expr)
            self.run(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.run(stmt.body)
            saved = dict(self.state)
            for handler in stmt.handlers:
                self.state = dict(saved)
                self.run(handler.body)
            self.state = saved
            self.run(stmt.orelse)
            self.run(stmt.finalbody)
        elif isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self._visit_expr(stmt.value)
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._visit_expr(child)


def _function_bodies(tree: ast.Module):
    """Every function body plus the module top level, each a separate scope."""
    yield tree.body
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node.body


def _run_tracker(rule: Rule, tree: ast.Module, ctx: FileContext, *,
                 check_reuse: bool, check_root: bool) -> Iterable[Violation]:
    aliases = resolve_aliases(tree)
    for body in _function_bodies(tree):
        tracker = _Tracker(rule, ctx, aliases, check_reuse, check_root)
        tracker.run(body)
        yield from tracker.violations


@register_rule
class PRNGKeyReuse(Rule):
    name = "prng-key-reuse"
    description = (
        "no PRNG key consumed twice — split and every jax.random sampler "
        "consume their key; derive fresh keys with split/fold_in per draw"
    )

    def check(self, tree: ast.Module, ctx: FileContext) -> Iterable[Violation]:
        return _run_tracker(self, tree, ctx, check_reuse=True, check_root=False)


@register_rule
class PRNGSamplerKey(Rule):
    name = "prng-sampler-key"
    description = (
        "samplers must not consume a root PRNGKey directly — every "
        "jax.random draw derives its key via split/fold_in"
    )

    def check(self, tree: ast.Module, ctx: FileContext) -> Iterable[Violation]:
        return _run_tracker(self, tree, ctx, check_reuse=False, check_root=True)
