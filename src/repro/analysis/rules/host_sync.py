"""Rule ``no-host-sync`` — no host synchronization inside traced code.

Scope: the jit hot-path modules (``HOT_PATH_MODULES`` in
``repro.analysis.lint`` — the compiled/fused/scaleout engines, the
selection core, and the Pallas kernels).  Inside functions that are
*traced* — jit-decorated, passed to ``jax.jit`` / ``vmap`` / ``scan`` /
``shard_map`` / ``pallas_call``, or nested within one — the idioms that
force a device→host sync (or silently constant-fold a tracer) are bugs:

    float(x)   .item()   .tolist()   np.asarray(x)   np.array(x)
    jax.device_get(x)

On a traced value these either raise ``TracerConversionError`` at run
time or, worse, sync the device once per round inside what is supposed
to be a device-resident chunk.  The host-side halves of the same
modules (methods driving the round loop) use these idioms freely and
are out of scope.

Traced-function detection is a small flow analysis: direct decoration,
by-name wrapping (``jax.jit(f)``), and the builder pattern the fused
engine uses (``self._round_body = f`` in one method, ``body =
self._round_body; lax.scan(body, ...)`` in another).
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.lint import FileContext, Violation
from repro.analysis.rules import (
    Rule,
    canonical_call_name,
    register_rule,
    resolve_aliases,
)

# Wrappers whose first function argument is traced.
_TRACING_WRAPPERS = {
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat",
    "jax.lax.scan", "jax.lax.map", "jax.lax.fori_loop", "jax.lax.while_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.associative_scan",
    "jax.experimental.shard_map.shard_map", "jax.shard_map",
    "repro.jax_compat.shard_map",
    "jax.experimental.pallas.pallas_call", "pl.pallas_call",
    "jax.make_jaxpr", "jax.eval_shape",
}
# Unqualified names that count as wrappers too (e.g. the jax_compat
# re-export ``from repro.jax_compat import shard_map``).
_WRAPPER_TAILS = {"shard_map", "pallas_call"}

_SYNC_CALLS = {"float"}
_SYNC_METHODS = {"item", "tolist"}
_SYNC_DOTTED = {"numpy.asarray", "numpy.array", "jax.device_get"}


def _is_wrapper(name: str | None) -> bool:
    if name is None:
        return False
    return name in _TRACING_WRAPPERS or name.split(".")[-1] in _WRAPPER_TAILS


class _FnInfo:
    def __init__(self, node: ast.FunctionDef | ast.AsyncFunctionDef):
        self.node = node
        self.traced = False


@register_rule
class NoHostSync(Rule):
    name = "no-host-sync"
    description = (
        "no host-sync idioms (float()/.item()/.tolist()/np.asarray/"
        "jax.device_get) inside traced functions in the jit hot-path modules"
    )

    def check(self, tree: ast.Module, ctx: FileContext) -> Iterable[Violation]:
        if not ctx.is_hot_path:
            return
        aliases = resolve_aliases(tree)

        # -- collect every function definition, keyed by name (scope-blind:
        # shadowing across scopes is rare and over-marking only widens the
        # checked surface, never misses it) --
        fns: dict[str, list[_FnInfo]] = {}
        infos: list[_FnInfo] = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = _FnInfo(node)
                infos.append(info)
                fns.setdefault(node.name, []).append(info)

        def mark(name: str) -> None:
            for info in fns.get(name, []):
                info.traced = True

        # -- direct decoration: @jax.jit / @partial(jax.jit, ...) --
        for info in infos:
            for dec in info.node.decorator_list:
                target = dec
                if isinstance(dec, ast.Call):
                    name = canonical_call_name(dec.func, aliases)
                    if name in ("functools.partial", "partial") and dec.args:
                        target = dec.args[0]
                    else:
                        target = dec.func
                if _is_wrapper(canonical_call_name(target, aliases)) or (
                    canonical_call_name(target, aliases) in ("jax.jit",)
                ):
                    info.traced = True

        # -- by-name wrapping, plus the builder two-hop:
        #    self.attr = fn_name ... alias = self.attr ... scan(alias, ...)
        attr_fn: dict[str, str] = {}     # self.<attr> -> function name
        alias_attr: dict[str, str] = {}  # local alias -> self.<attr>
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt, val = node.targets[0], node.value
                if (
                    isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"
                    and isinstance(val, ast.Name)
                    and val.id in fns
                ):
                    attr_fn[tgt.attr] = val.id
                elif (
                    isinstance(tgt, ast.Name)
                    and isinstance(val, ast.Attribute)
                    and isinstance(val.value, ast.Name)
                    and val.value.id == "self"
                ):
                    alias_attr[tgt.id] = val.attr

        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            if not (
                _is_wrapper(canonical_call_name(node.func, aliases))
                or canonical_call_name(node.func, aliases) == "jax.jit"
            ):
                continue
            first = node.args[0]
            if isinstance(first, ast.Name):
                if first.id in fns:
                    mark(first.id)
                elif first.id in alias_attr and alias_attr[first.id] in attr_fn:
                    mark(attr_fn[alias_attr[first.id]])
            elif (
                isinstance(first, ast.Attribute)
                and isinstance(first.value, ast.Name)
                and first.value.id == "self"
                and first.attr in attr_fn
            ):
                mark(attr_fn[first.attr])

        # -- propagate: nested defs inside traced functions are traced --
        changed = True
        while changed:
            changed = False
            for info in infos:
                if not info.traced:
                    continue
                for sub in ast.walk(info.node):
                    if (
                        isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and sub is not info.node
                    ):
                        for other in fns.get(sub.name, []):
                            if other.node is sub and not other.traced:
                                other.traced = True
                                changed = True

        # -- flag sync idioms inside traced bodies --
        seen: set[int] = set()
        for info in infos:
            if not info.traced:
                continue
            for sub in ast.walk(info.node):
                if not isinstance(sub, ast.Call) or id(sub) in seen:
                    continue
                msg = None
                fname = canonical_call_name(sub.func, aliases)
                if isinstance(sub.func, ast.Name) and sub.func.id in _SYNC_CALLS:
                    msg = (
                        f"{sub.func.id}() on a value inside a traced function "
                        f"forces a host sync (or fails on a tracer)"
                    )
                elif (
                    isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in _SYNC_METHODS
                    and not sub.args
                ):
                    msg = (
                        f".{sub.func.attr}() inside a traced function forces "
                        f"a device→host sync"
                    )
                elif fname in _SYNC_DOTTED:
                    msg = (
                        f"{fname} inside a traced function pulls the value to "
                        f"host; use jnp.asarray / keep it on device"
                    )
                if msg is not None:
                    seen.add(id(sub))
                    yield self.violation(ctx, sub, msg)
