"""``python -m repro.analysis`` — the tracecheck CLI.

Runs the AST lint over the ``repro`` package and (unless ``--lint-only``)
the jaxpr/compile contract checks, printing human-readable findings or a
machine-readable JSON report (``--json``).  Exits non-zero on any
violation or failed contract, so CI can gate on it directly:

    python -m repro.analysis            # lint + contracts, human output
    python -m repro.analysis --json     # same, JSON on stdout
    python -m repro.analysis --lint-only --rules no-global-rng
    python -m repro.analysis --list     # rule catalog
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="tracecheck: static + tracing contract verification",
    )
    ap.add_argument("--json", action="store_true",
                    help="emit a JSON report on stdout")
    ap.add_argument("--lint-only", action="store_true",
                    help="skip the jaxpr/compile contract checks (no jax needed)")
    ap.add_argument("--contracts-only", action="store_true",
                    help="skip the AST lint")
    ap.add_argument("--list", action="store_true",
                    help="list the lint rules and exit")
    ap.add_argument("--root", type=Path, default=None,
                    help="lint this directory instead of the repro package")
    ap.add_argument("--rules", default=None,
                    help="comma-separated lint-rule subset")
    args = ap.parse_args(argv)

    from repro.analysis import rule_catalog, run_lint

    if args.list:
        for name, desc in rule_catalog():
            print(f"{name:24s} {desc}")
        return 0
    if args.lint_only and args.contracts_only:
        ap.error("--lint-only and --contracts-only are mutually exclusive")

    rules = (
        [r.strip() for r in args.rules.split(",") if r.strip()]
        if args.rules else None
    )

    payload: dict = {}
    ok = True

    if not args.contracts_only:
        lint = run_lint(args.root, rules=rules)
        payload["lint"] = lint.to_dict()
        ok &= lint.ok
        if not args.json:
            for v in lint.violations:
                print(v)
            print(
                f"lint: {len(lint.violations)} violation(s) across "
                f"{lint.files_checked} files"
            )

    if not args.lint_only:
        # Imported here: contracts need jax and compile tiny engines.
        from repro.analysis.contracts import run_contracts

        contracts = run_contracts()
        payload["contracts"] = contracts.to_dict()
        ok &= contracts.ok
        if not args.json:
            for r in contracts.results:
                print(r)
            n_fail = sum(1 for r in contracts.results if not r.ok and not r.skipped)
            print(f"contracts: {n_fail} failure(s) of {len(contracts.results)} checks")

    payload["ok"] = ok
    if args.json:
        print(json.dumps(payload, indent=2))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
