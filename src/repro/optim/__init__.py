"""Optimizer substrate — optax-like, pure JAX, built here (optax not offline).

``Optimizer`` is an (init, update) pair over pytrees.  ``update`` returns
*updates to add* to params (already scaled by -lr), matching optax
conventions so training loops read identically.

Federated local-objective modifiers (FedProx/FedDyn) live in
``fedmods``; they transform gradients given the round's global params and
per-client state, leaving the base optimizer untouched — exactly how the
paper frames them (regularization-based baselines, §II-A).
"""

from repro.optim.optimizers import Optimizer, sgd, adamw, clip_by_global_norm, chain
from repro.optim.schedules import constant, warmup_cosine
from repro.optim.fedmods import fedprox_grads, feddyn_grads, feddyn_update_state

__all__ = [
    "Optimizer",
    "sgd",
    "adamw",
    "chain",
    "clip_by_global_norm",
    "constant",
    "warmup_cosine",
    "fedprox_grads",
    "feddyn_grads",
    "feddyn_update_state",
]
