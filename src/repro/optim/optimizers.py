"""SGD / AdamW / gradient clipping — pure-JAX pytree optimizers."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adamw", "clip_by_global_norm", "chain"]

Schedule = Callable[[jax.Array], jax.Array] | float


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def _lr_at(lr: Schedule, count: jax.Array) -> jax.Array:
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr: Schedule, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    """SGD, optionally with (Nesterov) momentum.  The paper trains with
    plain SGD(lr=0.005) — momentum defaults off."""

    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else ()
        return {"count": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        del params
        step = _lr_at(lr, state["count"])
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            eff = (
                jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
                if nesterov
                else mu
            )
        else:
            mu, eff = (), grads
        updates = jax.tree.map(lambda g: (-step * g).astype(g.dtype), eff)
        return updates, {"count": state["count"] + 1, "mu": mu}

    return Optimizer(init, update)


def adamw(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """AdamW with fp32 moments regardless of param dtype (bf16-safe)."""

    def init(params):
        def f32(p):
            return jnp.zeros(p.shape, jnp.float32)

        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
        }

    def update(grads, state, params):
        c = state["count"] + 1
        step = _lr_at(lr, state["count"])
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads
        )
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(m_, v_, p):
            adam = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return (-step * (adam + weight_decay * p.astype(jnp.float32))).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"count": c, "m": m, "v": v}

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    """Gradient transform: rescale grads so the global L2 norm ≤ max_norm."""

    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), state

    return Optimizer(init, update)


def chain(*transforms: Optimizer) -> Optimizer:
    """Compose gradient transforms left→right (last one produces updates)."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s2 = t.update(grads, s, params)
            new_state.append(s2)
        return grads, tuple(new_state)

    return Optimizer(init, update)


def apply_updates(params, updates):
    """θ ← θ + updates (updates already carry the -lr scaling)."""
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
