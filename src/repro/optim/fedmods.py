"""Federated local-objective modifiers: FedProx and FedDyn.

These are the paper's regularization-based baselines (§II-A).  Both are
expressed as *gradient transforms* — ∇(extra term) added to the task
gradient — so they compose with any base optimizer:

FedProx  (Li et al., 2020):   + (mu/2)·‖θ − θ_g‖²
    → grads += mu · (θ − θ_g)

FedDyn   (Acar et al., 2021): − ⟨h_i, θ⟩ + (a/2)·‖θ − θ_g‖²
    → grads += −h_i + a · (θ − θ_g)
    with per-client state   h_i ← h_i − a · (θ_local_end − θ_g)
    and the server applying θ ← mean_k θ_k − (1/a)·mean_K h   (see
    ``repro.federated.aggregation.feddyn_server``).
"""

from __future__ import annotations

import jax

__all__ = ["fedprox_grads", "feddyn_grads", "feddyn_update_state"]


def fedprox_grads(grads, params, global_params, mu: float):
    return jax.tree.map(
        lambda g, p, gp: g + mu * (p - gp), grads, params, global_params
    )


def feddyn_grads(grads, params, global_params, h_state, alpha: float):
    return jax.tree.map(
        lambda g, p, gp, h: g - h + alpha * (p - gp),
        grads,
        params,
        global_params,
        h_state,
    )


def feddyn_update_state(h_state, local_params_end, global_params, alpha: float):
    """Per-client h_i update after finishing local training."""
    return jax.tree.map(
        lambda h, p, gp: h - alpha * (p - gp), h_state, local_params_end, global_params
    )
