"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "warmup_cosine"]


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    """Linear warmup to ``peak_lr`` then cosine decay to ``floor``."""

    def sched(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup_steps, 1)
        t = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak_lr - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(c < warmup_steps, warm, cos)

    return sched
