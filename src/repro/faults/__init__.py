"""``repro.faults`` — fault injection, update validation, and client
health for the federated engine (DESIGN.md §14).

Three layers, configured by ``FLConfig.faults = FaultConfig(...)``
(``None`` default keeps the engine bit-identical):

- **Injection** (``models``) — a ``@register_fault`` registry of
  per-client fault models, deterministic per (seed, round, client) on
  the dedicated ``FAULT_STREAM`` child rng, composable with
  ``repro.systems`` availability.
- **Defense** (``defense``) — a pure-``jnp`` server-side validation
  gate (non-finite screening + quantile norm clipping) plus the robust
  aggregators registered in ``repro.engine.aggregators``.
- **Feedback** (``health``) — the ``ClientHealth`` quarantine/backoff
  ledger fed into selection as a ``-inf`` gate and carried through the
  checkpoint seams.
"""

from repro.faults.config import FaultConfig
from repro.faults.defense import screen_norms, update_norms, validate_updates
from repro.faults.health import ClientHealth
from repro.faults.models import (
    FAULT_REGISTRY,
    FAULT_STREAM,
    FaultModel,
    build_fault,
    list_faults,
    register_fault,
)
from repro.faults.runtime import FaultInfo, FaultRuntime

__all__ = [
    "FaultConfig",
    "FaultRuntime",
    "FaultInfo",
    "FaultModel",
    "ClientHealth",
    "FAULT_REGISTRY",
    "FAULT_STREAM",
    "register_fault",
    "build_fault",
    "list_faults",
    "validate_updates",
    "update_norms",
    "screen_norms",
]
