"""Registered per-client fault models (DESIGN.md §14).

A fault model transforms the *trained* client payload before it reaches
the server: ``apply(stacked, fetched, u)`` maps the stacked cohort
params (leading axis = rows) plus the fetched global params to a
corrupted stack, purely in ``jnp`` so the same transform runs eagerly
(host/compiled rounds) and inside the fused ``lax.scan`` body.  The
engine mixes the transformed rows back in with a per-row kind mask, so
``apply`` never needs to know *which* rows are faulty.

Per-model randomness is a single scalar ``u`` per (round, client) drawn
host-side on the dedicated fault stream (``FAULT_STREAM``) — every model
draws exactly one uniform per client per round regardless of the fault
rate, so enabling faults at ``rate=0`` consumes no engine PRNG and
perturbs nothing.

``traced = False`` models (``stale_replay`` — it needs the cross-round
replay cache) are rejected with ``fuse_rounds > 0`` by ``FLConfig``
validation and handled host-side by ``FaultRuntime``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.registry import Registry

__all__ = [
    "FAULT_REGISTRY",
    "FAULT_STREAM",
    "FaultModel",
    "register_fault",
    "list_faults",
    "build_fault",
]

# Child-stream tag for the fault axis — sibling of the systems streams
# (PROFILE/AVAILABILITY/JITTER = 0x5E3D_0001..3, DESIGN.md §10).
FAULT_STREAM = 0x5E3D_0004

FAULT_REGISTRY = Registry("fault")
register_fault = FAULT_REGISTRY.register


def list_faults() -> list[str]:
    return FAULT_REGISTRY.names()


def build_fault(name: str, **kwargs):
    return FAULT_REGISTRY.build(name, **kwargs)


def _rowwise(u, leaf):
    """Reshape a per-row scalar vector for broadcasting against ``leaf``."""
    return u.reshape((-1,) + (1,) * (leaf.ndim - 1))


class FaultModel:
    """Base class: one registered client-fault behavior.

    - ``draw_param(rng, n)`` — one float per client from the dedicated
      fault rng; models that need no parameter still draw (fixed stream
      consumption keeps (seed, round, client) determinism independent of
      the configured model mix).
    - ``upload_fraction(u)`` — fraction of the update's bytes that reach
      the server (``CommModel`` partial-byte accounting); 1.0 for
      everything except ``truncated_upload``.
    - ``apply(stacked, fetched, u)`` — pure-``jnp`` corruption of the
      whole stack; the caller masks in the faulty rows.
    """

    name: str = ""
    traced: bool = True

    def draw_param(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.random(n)

    def upload_fraction(self, u: np.ndarray) -> np.ndarray:
        return np.ones_like(np.asarray(u, dtype=np.float64))

    def apply(self, stacked, fetched, u):
        raise NotImplementedError


@register_fault("nan_update")
class NanUpdate(FaultModel):
    """Client returns non-finite leaves (crashed optimizer, fp overflow)."""

    name = "nan_update"

    def apply(self, stacked, fetched, u):
        return jax.tree.map(lambda s: jnp.full_like(s, jnp.nan), stacked)


@register_fault("exploding")
class Exploding(FaultModel):
    """Client delta scaled by ``eta`` — the classic scaled-gradient
    poisoning / diverged-local-training failure."""

    name = "exploding"

    def __init__(self, eta: float = 100.0):
        if not eta > 1.0:
            raise ValueError(f"exploding eta must be > 1, got {eta}")
        self.eta = float(eta)

    def apply(self, stacked, fetched, u):
        def one(s, f):
            f32, g32 = s.astype(jnp.float32), f[None].astype(jnp.float32)
            return (g32 + self.eta * (f32 - g32)).astype(s.dtype)

        return jax.tree.map(one, stacked, fetched)


@register_fault("sign_flip")
class SignFlip(FaultModel):
    """Byzantine sign flip: θ′ = θ_g − (θ_i − θ_g).  Norm-preserving, so
    norm screening alone cannot catch it — the robust aggregators can."""

    name = "sign_flip"

    def apply(self, stacked, fetched, u):
        def one(s, f):
            f32, g32 = s.astype(jnp.float32), f[None].astype(jnp.float32)
            return (2.0 * g32 - f32).astype(s.dtype)

        return jax.tree.map(one, stacked, fetched)


@register_fault("label_flip")
class LabelFlip(FaultModel):
    """Proxy for label-flipped local training: the delta is replaced by a
    norm-preserving garbage direction (per-leaf reversed and negated), so
    the update looks statistically plausible but pulls the model toward
    a systematically wrong optimum."""

    name = "label_flip"

    def apply(self, stacked, fetched, u):
        def one(s, f):
            f32, g32 = s.astype(jnp.float32), f[None].astype(jnp.float32)
            delta = (f32 - g32).reshape(s.shape[0], -1)
            garbled = -jnp.flip(delta, axis=1)
            return (g32 + garbled.reshape(s.shape)).astype(s.dtype)

        return jax.tree.map(one, stacked, fetched)


@register_fault("stale_replay")
class StaleReplay(FaultModel):
    """Client re-sends its *previous* trained params instead of fresh
    work (stuck cache, duplicated upload).  Needs the cross-round replay
    cache held by ``FaultRuntime``, so it is host-tier (``traced=False``,
    rejected with ``fuse_rounds > 0``); ``apply`` is the first-offense
    fallback — nothing cached yet, the client echoes the fetched params
    (a zero delta)."""

    name = "stale_replay"
    traced = False

    def apply(self, stacked, fetched, u):
        return jax.tree.map(
            lambda s, f: jnp.broadcast_to(f[None], s.shape).astype(s.dtype),
            stacked,
            fetched,
        )


@register_fault("truncated_upload")
class TruncatedUpload(FaultModel):
    """Upload cut short at a uniform fraction ``u ∈ [min_frac, max_frac]``
    of the flattened payload: the first ``u·size`` entries of each leaf
    arrive, the tail keeps the fetched (stale) values.  Only the partial
    bytes are charged to ``CommModel`` via ``upload_fraction``."""

    name = "truncated_upload"

    def __init__(self, min_frac: float = 0.25, max_frac: float = 0.75):
        if not (0.0 <= min_frac <= max_frac <= 1.0):
            raise ValueError(
                f"need 0 <= min_frac <= max_frac <= 1, got "
                f"({min_frac}, {max_frac})"
            )
        self.min_frac = float(min_frac)
        self.max_frac = float(max_frac)

    def draw_param(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return self.min_frac + (self.max_frac - self.min_frac) * rng.random(n)

    def upload_fraction(self, u: np.ndarray) -> np.ndarray:
        return np.asarray(u, dtype=np.float64)

    def apply(self, stacked, fetched, u):
        def one(s, f):
            flat = s.reshape(s.shape[0], -1)
            got = f.reshape(-1)[None].astype(s.dtype)
            pos = jnp.arange(flat.shape[1], dtype=jnp.float32)[None, :]
            keep = pos < u[:, None].astype(jnp.float32) * flat.shape[1]
            return jnp.where(keep, flat, got).reshape(s.shape)

        return jax.tree.map(one, stacked, fetched)
