"""``ClientHealth`` — the per-client fault ledger (DESIGN.md §14.3).

The server cannot see *why* a client failed validation, only that it
did; the ledger turns repeated failures into temporary exclusion with
exponential backoff:

- each validation failure bumps the client's ``consecutive`` count;
- at ``fail_threshold`` consecutive failures the client is quarantined
  for ``quarantine_rounds · backoff**strikes`` rounds (strikes capped at
  ``max_backoff_exp``) and the counter resets;
- a clean arrival resets ``consecutive`` (but not ``strikes`` — a
  historically flaky client re-offending is quarantined longer).

``admitted(t)`` feeds selection as a ``-inf`` gate alongside
availability; the whole state rides the checkpoint through
``state_dict``/``load_state_dict`` so kill-and-resume mid-quarantine is
bit-identical.
"""

from __future__ import annotations

import numpy as np

__all__ = ["ClientHealth"]


class ClientHealth:
    def __init__(
        self,
        n_clients: int,
        *,
        quarantine_rounds: int = 2,
        backoff: float = 2.0,
        max_backoff_exp: int = 6,
        fail_threshold: int = 1,
    ):
        self.n = int(n_clients)
        self.quarantine_rounds = int(quarantine_rounds)
        self.backoff = float(backoff)
        self.max_backoff_exp = int(max_backoff_exp)
        self.fail_threshold = int(fail_threshold)
        self.consecutive = np.zeros(self.n, np.int64)
        self.strikes = np.zeros(self.n, np.int64)
        self.quarantined_until = np.zeros(self.n, np.int64)
        self.total_faults = np.zeros(self.n, np.int64)

    # -- queries --------------------------------------------------------
    def admitted(self, t: int) -> np.ndarray:
        """(K,) bool — clients allowed to participate in round ``t``."""
        return self.quarantined_until <= t

    def n_quarantined(self, t: int) -> int:
        """Clients still serving a quarantine after round ``t``."""
        return int((self.quarantined_until > t).sum())

    # -- updates --------------------------------------------------------
    def record(self, t: int, arrivals, flagged) -> None:
        """Fold one round's validation outcome into the ledger.

        ``arrivals`` — client ids whose updates reached the server this
        round; ``flagged`` — the subset that failed validation.
        """
        arrivals = np.asarray(arrivals, np.int64).reshape(-1)
        flagged = np.asarray(flagged, np.int64).reshape(-1)
        clean = np.setdiff1d(arrivals, flagged)
        self.consecutive[clean] = 0
        if len(flagged) == 0:
            return
        self.consecutive[flagged] += 1
        self.total_faults[flagged] += 1
        if self.quarantine_rounds <= 0:
            return
        trip = flagged[self.consecutive[flagged] >= self.fail_threshold]
        if len(trip) == 0:
            return
        exp = np.minimum(self.strikes[trip], self.max_backoff_exp)
        dur = np.rint(self.quarantine_rounds * self.backoff**exp).astype(np.int64)
        self.quarantined_until[trip] = t + 1 + np.maximum(dur, 1)
        self.strikes[trip] += 1
        self.consecutive[trip] = 0

    # -- checkpoint seam ------------------------------------------------
    def state_dict(self) -> dict:
        return {
            "consecutive": self.consecutive.tolist(),
            "strikes": self.strikes.tolist(),
            "quarantined_until": self.quarantined_until.tolist(),
            "total_faults": self.total_faults.tolist(),
        }

    def load_state_dict(self, d: dict) -> None:
        for name in ("consecutive", "strikes", "quarantined_until", "total_faults"):
            arr = np.asarray(d[name], np.int64)
            if arr.shape != (self.n,):
                raise ValueError(
                    f"ClientHealth.{name}: checkpoint has shape {arr.shape}, "
                    f"engine has {self.n} clients"
                )
            setattr(self, name, arr)
