"""Server-side update validation gate (DESIGN.md §14.2).

Two-stage screening of arrived client updates, purely in ``jnp`` so the
identical code runs eagerly, under ``jit`` (host/compiled rounds), and
inside the fused ``lax.scan`` body without host syncs:

1. **Non-finite screening** — any NaN/Inf leaf entry flags the row.
2. **Norm gating at a robust quantile** — with ``thr`` the
   ``clip_quantile`` of the finite valid cohort delta norms, rows with
   ``norm > norm_tolerance · thr`` are flagged (quarantine candidates),
   and rows in the band ``(thr, tol·thr]`` are norm-clipped back to
   ``thr``.

Invariants the tests pin down:

- Rows with ``norm <= thr`` pass through **bit-exactly** (the clip is a
  ``jnp.where(scale >= 1, original, ...)``), so with
  ``clip_quantile=1.0`` the defended path is bit-identical to the
  undefended one on an honest cohort.
- When *no* valid finite row exists the quantile is NaN and every valid
  row is flagged — the caller's all-quarantined round then leaves the
  params unchanged (graceful degradation, mirroring the all-dropped
  systems invariant).
- Flagged rows are never clipped (their aggregation weight is exactly
  zero anyway), and non-finite rows are *neutralized* — replaced by the
  fetched params — because a zero weight does not protect a mask-gated
  sum from ``0 · NaN = NaN``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["update_norms", "validate_updates", "screen_norms"]


def update_norms(stacked, fetched):
    """Per-row global L2 delta norm and all-finite flag.

    Returns ``(norm, finite)`` — ``norm`` is ``inf`` on non-finite rows
    so downstream comparisons never propagate NaN.
    """
    leaves = jax.tree.leaves(stacked)
    got = jax.tree.leaves(fetched)
    n = leaves[0].shape[0]
    sq = jnp.zeros((n,), jnp.float32)
    finite = jnp.ones((n,), bool)
    for s, f in zip(leaves, got):
        flat = s.astype(jnp.float32).reshape(n, -1)
        finite = finite & jnp.all(jnp.isfinite(flat), axis=1)
        d = flat - f.astype(jnp.float32).reshape(-1)[None]
        sq = sq + jnp.sum(jnp.square(d), axis=1)
    norm = jnp.sqrt(sq)
    return jnp.where(finite, norm, jnp.inf), finite


def validate_updates(stacked, fetched, valid, *, q: float, tol: float):
    """The full traced gate: screen + clip one stacked cohort.

    ``valid`` marks rows that actually arrived (systems survivors /
    admitted clients); invalid rows are ignored by the quantile and
    never flagged or clipped.

    Returns ``(clipped_stack, flagged, norm)``.
    """
    norm, finite = update_norms(stacked, fetched)
    masked = jnp.where(valid & finite, norm, jnp.float32(jnp.nan))
    thr = jnp.nanquantile(masked, q)
    # NaN thr (no valid finite row) makes `norm <= tol*thr` False for
    # every row -> all valid rows flagged, none clipped.
    flagged = valid & (~finite | ~(norm <= tol * thr))
    scale = jnp.where(norm > thr, thr / jnp.maximum(norm, 1e-30), jnp.float32(1.0))
    scale = jnp.where(flagged | ~valid, jnp.float32(1.0), scale)

    neutral = ~finite  # 0-weight gating cannot survive 0·NaN — replace

    def one(s, f):
        sc = scale.reshape((-1,) + (1,) * (s.ndim - 1))
        nt = neutral.reshape((-1,) + (1,) * (s.ndim - 1))
        f32, g32 = s.astype(jnp.float32), f[None].astype(jnp.float32)
        clipped = (g32 + (f32 - g32) * sc).astype(s.dtype)
        out = jnp.where(sc >= 1.0, s, clipped)
        return jnp.where(nt, jnp.broadcast_to(f[None], s.shape).astype(s.dtype), out)

    return jax.tree.map(one, stacked, fetched), flagged, norm


def screen_norms(norms, finite, valid, *, q: float, tol: float):
    """Host-side (numpy) twin of the norm gate for the async buffer,
    where candidate sets are small and data-dependent so a traced form
    would retrace per shape.  Same thresholds and flagging rule as
    ``validate_updates``; returns ``(flagged, scales, thr)`` with
    ``scales`` the per-row clip factor (1.0 where untouched)."""
    norms = np.asarray(norms, np.float64)
    finite = np.asarray(finite, bool)
    valid = np.asarray(valid, bool)
    ok = valid & finite
    thr = float(np.quantile(norms[ok], q)) if ok.any() else float("nan")
    if not np.isfinite(thr):
        return valid.copy(), np.ones_like(norms), thr
    flagged = valid & (~finite | ~(norms <= tol * thr))
    with np.errstate(divide="ignore", invalid="ignore"):
        scales = np.where(norms > thr, thr / norms, 1.0)
    scales = np.where(valid & ~flagged, scales, 1.0)
    return flagged, scales, thr
