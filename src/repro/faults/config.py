"""``FaultConfig`` — the validated, JSON-safe slot behind
``FLConfig.faults`` (DESIGN.md §14).

Mirrors the ``SystemsConfig`` contract: plain scalars/strings/kwargs
dicts that survive ``FLConfig.to_dict()``/``from_dict`` round-tripping,
with eager validation — fault-model names resolve against the registry
and every model is built once at config construction so a typo or bad
kwarg fails before any data is touched.  ``FLConfig.faults = None``
(the default) keeps the engine bit-identical to a build without this
subsystem.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, fields

__all__ = ["FaultConfig"]

_DEFENSES = ("none", "validate")


@dataclass
class FaultConfig:
    """The fault axis of one federated experiment.

    - ``rate`` — per-(round, client) probability of injecting a fault,
      drawn on the dedicated ``FAULT_STREAM`` child rng (``rate=0``
      exercises the whole machinery while perturbing nothing).
    - ``models`` / ``model_kwargs`` — registered fault models to mix
      (a hit picks one uniformly) and their per-model constructor
      kwargs, e.g. ``{"exploding": {"eta": 50.0}}``.
    - ``defense`` — ``"none"`` or ``"validate"`` (non-finite screening +
      norm clipping at ``clip_quantile`` of cohort norms, flagging past
      ``norm_tolerance`` × that threshold).
    - ``quarantine_rounds`` / ``backoff`` / ``max_backoff_exp`` /
      ``fail_threshold`` — the ``ClientHealth`` ledger: after
      ``fail_threshold`` consecutive flags a client sits out
      ``quarantine_rounds · backoff**strikes`` rounds (0 disables
      quarantine entirely).
    - ``seed`` — fault-stream seed; ``None`` inherits the engine seed.
    """

    rate: float = 0.0
    models: tuple = ("sign_flip",)
    model_kwargs: dict = field(default_factory=dict)
    defense: str = "none"
    clip_quantile: float = 0.9
    norm_tolerance: float = 3.0
    quarantine_rounds: int = 2
    backoff: float = 2.0
    max_backoff_exp: int = 6
    fail_threshold: int = 1
    seed: int | None = None

    def __post_init__(self) -> None:
        from repro.faults.models import build_fault, list_faults

        if not (
            isinstance(self.rate, (int, float))
            and math.isfinite(self.rate)
            and 0.0 <= self.rate <= 1.0
        ):
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate!r}")
        self.rate = float(self.rate)
        if isinstance(self.models, str):
            self.models = [self.models]
        self.models = list(self.models)
        if not self.models:
            raise ValueError("FaultConfig.models must name at least one model")
        if len(set(self.models)) != len(self.models):
            raise ValueError(f"duplicate fault models: {self.models}")
        known = list_faults()
        for name in self.models:
            if name not in known:
                raise ValueError(
                    f"unknown fault model {name!r}; available: {known}"
                )
        if not isinstance(self.model_kwargs, dict):
            raise ValueError("model_kwargs must be a {model: kwargs} dict")
        for name, kw in self.model_kwargs.items():
            if name not in self.models:
                raise ValueError(
                    f"model_kwargs for {name!r} but it is not in models="
                    f"{self.models}"
                )
            if not isinstance(kw, dict):
                raise ValueError(f"model_kwargs[{name!r}] must be a dict")
        # Eager build: constructor kwargs validated now, not mid-round.
        for name in self.models:
            build_fault(name, **self.model_kwargs.get(name, {}))
        if self.defense not in _DEFENSES:
            raise ValueError(
                f"unknown defense {self.defense!r}; available: {list(_DEFENSES)}"
            )
        if not (0.0 < self.clip_quantile <= 1.0):
            raise ValueError(
                f"clip_quantile must be in (0, 1], got {self.clip_quantile}"
            )
        self.clip_quantile = float(self.clip_quantile)
        if not self.norm_tolerance >= 1.0:
            raise ValueError(
                f"norm_tolerance must be >= 1, got {self.norm_tolerance}"
            )
        self.norm_tolerance = float(self.norm_tolerance)
        if not (isinstance(self.quarantine_rounds, int) and self.quarantine_rounds >= 0):
            raise ValueError(
                f"quarantine_rounds must be an int >= 0, got "
                f"{self.quarantine_rounds!r}"
            )
        if not self.backoff >= 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")
        self.backoff = float(self.backoff)
        if not (isinstance(self.max_backoff_exp, int) and self.max_backoff_exp >= 0):
            raise ValueError(
                f"max_backoff_exp must be an int >= 0, got "
                f"{self.max_backoff_exp!r}"
            )
        if not (isinstance(self.fail_threshold, int) and self.fail_threshold >= 1):
            raise ValueError(
                f"fail_threshold must be an int >= 1, got "
                f"{self.fail_threshold!r}"
            )
        if self.seed is not None and not isinstance(self.seed, int):
            raise ValueError(f"seed must be an int or None, got {self.seed!r}")

    @property
    def defended(self) -> bool:
        return self.defense != "none"

    @classmethod
    def from_dict(cls, d: dict) -> "FaultConfig":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FaultConfig keys: {sorted(unknown)}")
        return cls(**d)
