"""``FaultRuntime`` — builds the configured fault models once and owns
every engine-facing fault operation (DESIGN.md §14).

Determinism contract: all fault randomness comes from the dedicated
child stream ``default_rng([seed, FAULT_STREAM, round])`` with a fixed
draw order (hit vector, model pick, then one ``draw_param`` vector per
configured model), so the decision for (seed, round, client) is
reproducible in isolation, independent of cohort composition, and never
touches the engine's numpy or JAX PRNG streams — ``faults=None`` vs
``rate=0`` is bit-identical.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults.defense import screen_norms, update_norms, validate_updates
from repro.faults.health import ClientHealth
from repro.faults.models import FAULT_STREAM, build_fault

__all__ = ["FaultRuntime", "FaultInfo"]


@dataclass(frozen=True)
class FaultInfo:
    """What one eager round's fault processing did — feeds
    ``RoundResult`` and the comm model."""

    survivors: np.ndarray  # client ids passing arrival ∩ validation
    n_faulty: int  # injected-faulty among arrivals (ground truth)
    n_quarantined: int  # clients in quarantine after this round
    uploaded: float  # Σ upload fractions over arrivals (partial bytes)


class FaultRuntime:
    def __init__(self, cfg, *, n_clients: int, seed: int, params_template):
        self.cfg = cfg
        self.n = int(n_clients)
        self.seed = int(cfg.seed if cfg.seed is not None else seed)
        self.models = [
            build_fault(name, **cfg.model_kwargs.get(name, {}))
            for name in cfg.models
        ]
        self.defended = cfg.defended
        self.health = ClientHealth(
            n_clients,
            quarantine_rounds=cfg.quarantine_rounds,
            backoff=cfg.backoff,
            max_backoff_exp=cfg.max_backoff_exp,
            fail_threshold=cfg.fail_threshold,
        )
        # The traced gate, reused (jitted) by the eager paths and (inlined)
        # by the fused scan body.
        self.validate_traced = partial(
            validate_updates, q=cfg.clip_quantile, tol=cfg.norm_tolerance
        )
        self._validate_jit = jax.jit(self.validate_traced, donate_argnums=())
        self._norms_jit = jax.jit(update_norms, donate_argnums=())
        # stale_replay cross-round cache: last honest trained params per
        # client (+ a sent flag), host-tier only.
        self._stale_idx = next(
            (j for j, m in enumerate(self.models) if not m.traced), None
        )
        if self._stale_idx is not None:
            self._stale_cache = jax.tree.map(
                lambda p: jnp.zeros((self.n,) + p.shape, p.dtype), params_template
            )
            self._stale_sent = np.zeros(self.n, bool)

    # -- per-round decisions -------------------------------------------
    def decide(self, rnd: int) -> tuple[np.ndarray, np.ndarray]:
        """(kind, u) over the whole population for round ``rnd`` —
        ``kind[c]`` is the model index injected for client ``c`` (−1 =
        honest), ``u[c]`` its scalar parameter."""
        rng = np.random.default_rng([self.seed, FAULT_STREAM, int(rnd)])
        hit = rng.random(self.n) < self.cfg.rate
        which = rng.integers(0, len(self.models), self.n)
        us = np.stack([m.draw_param(rng, self.n) for m in self.models])
        kind = np.where(hit, which, -1).astype(np.int64)
        u = us[which, np.arange(self.n)].astype(np.float32)
        return kind, u

    def upload_fractions(self, kind_rows: np.ndarray, u_rows: np.ndarray) -> np.ndarray:
        """Per-row fraction of update bytes that reach the server."""
        fr = np.ones(len(kind_rows), np.float64)
        for j, m in enumerate(self.models):
            rows = kind_rows == j
            if rows.any():
                fr[rows] = m.upload_fraction(u_rows[rows])
        return fr

    # -- injection ------------------------------------------------------
    def apply_traced(self, stacked, fetched, kind_rows, u_rows):
        """Mix each traced model's corruption into its rows — pure jnp,
        shared by the eager paths and the fused scan body."""
        out = stacked
        u = jnp.asarray(u_rows, jnp.float32)
        for j, m in enumerate(self.models):
            if not m.traced:
                continue
            hit = jnp.asarray(kind_rows) == j
            bad = m.apply(stacked, fetched, u)
            out = jax.tree.map(
                lambda o, b: jnp.where(
                    hit.reshape((-1,) + (1,) * (o.ndim - 1)), b, o
                ),
                out,
                bad,
            )
        return out

    def inject_eager(self, rnd: int, clients: np.ndarray, arrived: np.ndarray,
                     stacked, fetched):
        """Corrupt the rows of ``stacked`` (row i trained by client
        ``clients[i]``) per this round's decisions.  Faults are
        properties of *uploads*, so only ``arrived`` rows are touched —
        a faulty-but-dropped client never reaches the server (and, on
        the compiled all-K payload, a zero-weight NaN row would still
        poison the mask-gated sum).  Zero work — and the unchanged input
        object — when nothing hits."""
        clients = np.asarray(clients, np.int64)
        arrived = np.asarray(arrived, bool)
        kind, u = self.decide(rnd)
        kind_rows = np.where(arrived, kind[clients], -1)
        u_rows = u[clients]
        if not (kind_rows >= 0).any():
            self._refresh_stale_cache(clients, arrived, stacked, kind_rows)
            return stacked, kind_rows, u_rows
        out = self.apply_traced(stacked, fetched, kind_rows, u_rows)
        if self._stale_idx is not None:
            out = self._apply_stale(out, clients, kind_rows, fetched)
        self._refresh_stale_cache(clients, arrived, stacked, kind_rows)
        return out, kind_rows, u_rows

    def _refresh_stale_cache(self, clients, arrived, stacked, kind_rows) -> None:
        # cache = the client's last *uploaded* honest params, so the
        # replay is identical whichever backend (and cohort shape) ran it
        if self._stale_idx is None:
            return
        fresh = arrived & (kind_rows != self._stale_idx)
        idx = clients[fresh]
        if len(idx) == 0:
            return
        rows = np.flatnonzero(fresh)
        self._stale_cache = jax.tree.map(
            lambda c, s: c.at[idx].set(s[rows].astype(c.dtype)),
            self._stale_cache,
            stacked,
        )
        self._stale_sent[idx] = True

    def _apply_stale(self, out, clients, kind_rows, fetched):
        for r in np.flatnonzero(kind_rows == self._stale_idx):
            c = int(clients[r])
            repl = (
                jax.tree.map(lambda cache: cache[c], self._stale_cache)
                if self._stale_sent[c]
                else fetched
            )
            out = jax.tree.map(
                lambda o, rp: o.at[r].set(rp.astype(o.dtype)), out, repl
            )
        return out

    # -- defense --------------------------------------------------------
    def screen(self, stacked, fetched, valid: np.ndarray):
        """Validation gate over one stacked cohort (jitted).  Returns
        ``(clipped_stack, flagged_rows)``; identity when undefended.

        One fused jit call on purpose: the gate's screen + clip are a
        single XLA program (elementwise chain fused into ~2 stack
        passes), so the defended round adds one dispatch and one small
        host read — splitting screen from repair doubles the work,
        because the norm clip touches the cohort's top-``clip_quantile``
        tail on *honest* rounds too (DESIGN.md §14.2)."""
        if not self.defended:
            return stacked, np.zeros(len(valid), bool)
        clipped, flagged, _ = self._validate_jit(
            stacked, fetched, jnp.asarray(np.asarray(valid, bool))
        )
        return clipped, np.asarray(flagged)

    def entry_norms(self, stacked, fetched) -> tuple[np.ndarray, np.ndarray]:
        """Per-row (norm, finite) for the async buffer's host-side
        screening (``screen_norms``)."""
        norm, finite = self._norms_jit(stacked, fetched)
        return np.asarray(norm), np.asarray(finite)

    def screen_entry_norms(self, norms, finite, valid):
        return screen_norms(
            norms,
            finite,
            valid,
            q=self.cfg.clip_quantile,
            tol=self.cfg.norm_tolerance,
        )

    # -- the eager one-stop ---------------------------------------------
    def process_begin(self, rnd: int, clients: np.ndarray,
                      arrived: np.ndarray, stacked, fetched):
        """Device half of :meth:`process`: inject and *dispatch* the
        gate without reading its verdict back.  Returns
        ``(new_stacked, pending)`` — the caller dispatches downstream
        device work (the optimistic aggregation) and only then resolves
        ``pending`` via :meth:`process_finish`, so the flagged read
        overlaps the device queue instead of stalling it
        (DESIGN.md §14.2)."""
        clients = np.asarray(clients, np.int64)
        arrived = np.asarray(arrived, bool)
        out, kind_rows, u_rows = self.inject_eager(
            rnd, clients, arrived, stacked, fetched
        )
        flagged = None
        if self.defended:
            out, flagged, _ = self._validate_jit(
                out, fetched, jnp.asarray(arrived)
            )
        return out, (rnd, clients, arrived, kind_rows, u_rows, flagged)

    def process_finish(self, pending) -> FaultInfo:
        """Host half of :meth:`process`: materialize the gate verdict,
        feed the health ledger, and build the round's ``FaultInfo``."""
        rnd, clients, arrived, kind_rows, u_rows, flagged = pending
        flagged_rows = (
            np.asarray(flagged) if flagged is not None
            else np.zeros(len(arrived), bool)
        )
        flagged_rows = flagged_rows & arrived
        surv = clients[arrived & ~flagged_rows]
        self.health.record(rnd, clients[arrived], clients[flagged_rows])
        fracs = self.upload_fractions(kind_rows, u_rows)
        return FaultInfo(
            survivors=surv,
            n_faulty=int((kind_rows >= 0).sum()),
            n_quarantined=self.health.n_quarantined(rnd),
            uploaded=float(fracs[arrived].sum()),
        )

    def process(self, rnd: int, clients: np.ndarray, arrived: np.ndarray, stacked, fetched):
        """Inject → screen → ledger for one eager round.

        ``clients[i]`` trained row ``i`` of ``stacked``; ``arrived[i]``
        marks rows that reached the server (systems survivors ∩ admitted
        clients).  Returns ``(new_stacked, FaultInfo)``.
        """
        out, pending = self.process_begin(rnd, clients, arrived, stacked, fetched)
        return out, self.process_finish(pending)

    # -- checkpoint seams -----------------------------------------------
    def meta_state(self) -> dict:
        return {"health": self.health.state_dict()}

    def load_meta_state(self, d: dict) -> None:
        self.health.load_state_dict(d["health"])

    @property
    def has_stale(self) -> bool:
        return self._stale_idx is not None

    def stale_state(self) -> dict:
        """Array-valued stale-replay state for ``_state_pytree`` (the
        ``sent`` flags ride as an int array leaf)."""
        return {
            "cache": self._stale_cache,
            "sent": jnp.asarray(self._stale_sent.astype(np.int8)),
        }

    def load_stale_state(self, d: dict) -> None:
        # the checkpoint loader hands back numpy leaves; the cache must be
        # jnp so `.at[].set` updates keep working after a resume
        self._stale_cache = jax.tree.map(jnp.asarray, d["cache"])
        self._stale_sent = np.asarray(d["sent"]).astype(bool)
