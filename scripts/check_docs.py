#!/usr/bin/env python
"""Internal documentation cross-reference checker (the CI `docs` job).

Three classes of reference are verified:

1. ``DESIGN.md §X`` citations — anywhere in the tree (module
   docstrings, tests, examples, benchmarks, and the root md docs) —
   must resolve to a literal ``§X`` heading in ``DESIGN.md``.
2. Bare ``§X`` (digit-leading) references *inside* ``DESIGN.md`` must
   resolve to one of its own headings.
3. Repo-relative file references in the root docs (README.md,
   DESIGN.md, ROADMAP.md) — markdown links and backticked paths like
   ``examples/federated_lm.py`` or ``ROADMAP.md`` — must exist.

Stdlib-only; exits nonzero listing every unresolved reference.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DESIGN = ROOT / "DESIGN.md"
ROOT_DOCS = ("README.md", "DESIGN.md", "ROADMAP.md")
CODE_DIRS = ("src", "tests", "examples", "benchmarks", "scripts")

SECTION_REF = r"§([0-9]+[a-z]?(?:\.[0-9]+)*)"
DESIGN_REF = re.compile(r"DESIGN\.md\s*" + SECTION_REF)
HEADING = re.compile(r"^#{1,6}\s*" + SECTION_REF, re.M)
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")
# backticked repo-relative path: at least a slash or an .md name, no
# spaces/globs, a recognizable file extension
TICKED_PATH = re.compile(
    r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)*"
    r"\.(?:md|py|yml|yaml|json|txt|ini))`"
)


def design_sections() -> set[str]:
    if not DESIGN.exists():
        return set()
    return set(HEADING.findall(DESIGN.read_text()))


def iter_code_files():
    for d in CODE_DIRS:
        yield from (ROOT / d).rglob("*.py")
    for name in ROOT_DOCS:
        p = ROOT / name
        if p.exists():
            yield p


def main() -> int:
    errors: list[str] = []
    sections = design_sections()
    if not DESIGN.exists():
        errors.append("DESIGN.md does not exist")

    # 1. DESIGN.md §X citations, tree-wide
    for path in iter_code_files():
        text = path.read_text(errors="replace")
        for sec in DESIGN_REF.findall(text):
            if sec not in sections:
                errors.append(
                    f"{path.relative_to(ROOT)}: cites DESIGN.md §{sec} "
                    f"but DESIGN.md has no §{sec} heading"
                )

    # 2. bare §X references inside DESIGN.md resolve internally
    if DESIGN.exists():
        for sec in re.findall(r"(?<![\w#])" + SECTION_REF, DESIGN.read_text()):
            if sec not in sections:
                errors.append(
                    f"DESIGN.md: internal reference §{sec} has no heading"
                )

    # 3. file references in the root docs
    for name in ROOT_DOCS:
        doc = ROOT / name
        if not doc.exists():
            errors.append(f"{name} does not exist")
            continue
        text = doc.read_text()
        refs = set(MD_LINK.findall(text)) | set(TICKED_PATH.findall(text))
        for ref in sorted(refs):
            if ref.startswith(("http://", "https://", "/")):
                continue
            if not (ROOT / ref).exists():
                errors.append(f"{name}: references {ref!r} which does not exist")

    if errors:
        print(f"check_docs: {len(errors)} unresolved reference(s):")
        for e in errors:
            print(f"  - {e}")
        return 1
    n = len(sections)
    print(f"check_docs: OK (DESIGN.md has {n} §-headings; all references resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
