"""Table II — test accuracy under high non-IID data (HD≈0.9).

Paper: FedLECC highest accuracy in most settings, up to +12% vs FedAvg /
strong baselines (MNIST/FMNIST; here synthetic Gaussian-mixture images —
relative claims validated, DESIGN.md §6).
"""

from __future__ import annotations

import numpy as np

from benchmarks.fl_common import ensure_runs, methods_for


def main(full: bool = False, rounds: int | None = None) -> list[tuple]:
    methods = methods_for(full)
    seeds = [0, 1] if full else [0]
    rounds = rounds or (100 if full else 60)
    runs = ensure_runs(methods, seeds, rounds)
    rows = []
    for method in methods:
        cells = [r for r in runs if r["method"] == method]
        finals = [r["history"]["test_acc"][-1] for r in cells]
        wall = np.mean([r["wall_s"] for r in cells])
        rows.append(
            (
                f"table2_acc/{method}",
                wall * 1e6 / rounds,          # us per federated round
                f"final_acc={np.mean(finals):.4f}±{np.std(finals):.4f}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
