"""Fig 3 — learning curves / rounds-to-accuracy (the −22%-rounds claim).

Reports, per method, the first round at which each target accuracy is
reached, and FedLECC's saving relative to FedAvg.
"""

from __future__ import annotations

import numpy as np

from benchmarks.fl_common import ensure_runs, methods_for
from repro.engine import rounds_to_accuracy


def main(full: bool = False, rounds: int | None = None,
         targets=(0.4, 0.5, 0.6)) -> list[tuple]:
    methods = methods_for(full)
    seeds = [0, 1] if full else [0]
    rounds = rounds or (100 if full else 60)
    runs = ensure_runs(methods, seeds, rounds)
    per_method: dict[str, list[float]] = {}
    rows = []
    for method in methods:
        cells = [r for r in runs if r["method"] == method]
        reached = []
        for t in targets:
            rts = [rounds_to_accuracy(r["history"], t) for r in cells]
            rts = [r_ for r_ in rts if r_ is not None]
            reached.append(float(np.mean(rts)) if rts else float("nan"))
        per_method[method] = reached
        detail = ";".join(
            f"r@{t}={v:.0f}" if np.isfinite(v) else f"r@{t}=never"
            for t, v in zip(targets, reached)
        )
        rows.append((f"fig3_rounds/{method}", 0.0, detail))
    if "fedavg" in per_method and "fedlecc" in per_method:
        savings = [
            1 - l / f
            for l, f in zip(per_method["fedlecc"], per_method["fedavg"])
            if np.isfinite(l) and np.isfinite(f) and f > 0
        ]
        if savings:
            rows.append(
                ("fig3_rounds/fedlecc_vs_fedavg_saving", 0.0,
                 f"mean_round_saving={np.mean(savings):.1%}")
            )
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
