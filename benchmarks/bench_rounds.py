"""Round benchmarks: rounds-to-accuracy (Fig 3) and round wall-clock.

Default mode — Fig 3 learning curves / rounds-to-accuracy (the
−22%-rounds claim): reports, per method, the first round at which each
target accuracy is reached, and FedLECC's saving relative to FedAvg.

``--wallclock`` — the engine-performance trajectory (DESIGN.md §8.6):
times the *same* canonical round on the execution variants

    host             numpy selection + vmapped cohort (paper-faithful)
    compiled_eager   legacy compiled: every client trains, mask-gated sum
    compiled_gather  compiled + static cohort gather (trains only m)
    fused            compiled + scan-fused round chunks, donated carry

for both registered tasks and writes ``BENCH_rounds.json`` — the
repo-root artifact the CI ``perf-smoke`` job regenerates and uploads so
the perf trajectory is tracked per commit.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.engine import rounds_to_accuracy

ROOT = os.path.join(os.path.dirname(__file__), "..")
BENCH_JSON = os.path.join(ROOT, "BENCH_rounds.json")

VARIANTS = ("host", "compiled_eager", "compiled_gather", "fused")


# -------------------------------------------------- fig3 (default mode)
def main(full: bool = False, rounds: int | None = None,
         targets=(0.4, 0.5, 0.6)) -> list[tuple]:
    from benchmarks.fl_common import ensure_runs, methods_for

    methods = methods_for(full)
    seeds = [0, 1] if full else [0]
    rounds = rounds or (100 if full else 60)
    runs = ensure_runs(methods, seeds, rounds)
    per_method: dict[str, list[float]] = {}
    rows = []
    for method in methods:
        cells = [r for r in runs if r["method"] == method]
        reached = []
        for t in targets:
            rts = [rounds_to_accuracy(r["history"], t) for r in cells]
            rts = [r_ for r_ in rts if r_ is not None]
            reached.append(float(np.mean(rts)) if rts else float("nan"))
        per_method[method] = reached
        detail = ";".join(
            f"r@{t}={v:.0f}" if np.isfinite(v) else f"r@{t}=never"
            for t, v in zip(targets, reached)
        )
        rows.append((f"fig3_rounds/{method}", 0.0, detail))
    if "fedavg" in per_method and "fedlecc" in per_method:
        savings = [
            1 - l / f
            for l, f in zip(per_method["fedlecc"], per_method["fedavg"])
            if np.isfinite(l) and np.isfinite(f) and f > 0
        ]
        if savings:
            rows.append(
                ("fig3_rounds/fedlecc_vs_fedavg_saving", 0.0,
                 f"mean_round_saving={np.mean(savings):.1%}")
            )
    return rows


# ------------------------------------------------------- wallclock mode
def _engine_for(variant: str, task: str, *, n_clients: int, m: int,
                rounds: int, smoke: bool):
    """One engine per (variant × task) cell, sharing a single seed/data
    regime so the timed rounds are the same federated computation."""
    from repro.engine import FLConfig, make_engine

    backend = "host" if variant == "host" else "compiled"
    fuse = rounds if variant == "fused" else 0
    kw = dict(
        n_clients=n_clients, m=m, rounds=rounds, seed=0, target_hd=0.9,
        backend=backend, fuse_rounds=fuse,
        # evaluate only at round 0 and the final round, so the timed
        # region measures the round loop, not the eval cadence
        eval_every=max(rounds, 1),
    )
    if task == "lm":
        from repro.data.synthetic import make_token_stream

        vocab = 32
        kw.update(
            task="lm",
            task_kwargs={
                "model": "stablelm-3b",
                "overrides": {"d_model": 32, "n_heads": 2, "n_kv_heads": 2,
                              "head_dim": 16, "d_ff": 64, "vocab": vocab,
                              "loss_chunk": 16, "attn_chunk": 16,
                              "remat": False},
                "hist_bins": 16,
            },
            batch_size=4, eval_samples=8, max_steps_cap=4,
        )
        train = make_token_stream(12 * n_clients, 16, vocab, seed=0)
        test = make_token_stream(16, 16, vocab, seed=1)
        n_classes = vocab
    else:
        from repro.data import make_classification

        n = 2_000 if smoke else 20_000
        kw.update(eval_samples=64 if not smoke else 16,
                  hidden=(64,) if smoke else (200, 200))
        train = make_classification(n, n_features=64, n_classes=10, seed=0)
        test = make_classification(max(n // 10, 200), n_features=64,
                                   n_classes=10, seed=1)
        n_classes = 10
    cfg = FLConfig(**kw)
    kwargs = {"cohort_gather": False} if variant == "compiled_eager" else {}
    return make_engine(cfg, train, test, n_classes=n_classes, **kwargs)


def _time_rounds(engine, rounds: int) -> float:
    """Wall-clock seconds for one ``rounds()`` call after an identical
    warm-up call.  A same-length warm-up call reproduces the exact fused
    chunk structure (round-0 chunk, steady-state chunks, tail), so every
    executable the timed call dispatches is already compiled.  Streaming
    results synchronize per round / per chunk, so the timed region
    includes every device→host edge the round loop actually pays."""
    for _ in engine.rounds(rounds):
        pass
    t0 = time.perf_counter()
    for _ in engine.rounds(rounds):
        pass
    return time.perf_counter() - t0


def wallclock_main(rounds: int, n_clients: int, m: int, tasks, smoke: bool,
                   out: str) -> dict:
    import jax

    results = []
    for task in tasks:
        base = None
        for variant in VARIANTS:
            engine = _engine_for(variant, task, n_clients=n_clients, m=m,
                                 rounds=rounds, smoke=smoke)
            wall = _time_rounds(engine, rounds)
            row = {
                "task": task, "variant": variant,
                "n_clients": n_clients, "m": m, "rounds": rounds,
                "wall_s": round(wall, 4),
                "s_per_round": round(wall / rounds, 5),
            }
            if variant == "compiled_eager":
                base = wall
            row["speedup_vs_compiled_eager"] = (
                round(base / wall, 2) if base else None
            )
            results.append(row)
            print(f"[wallclock] {task:>14s} {variant:<16s} "
                  f"{row['s_per_round']*1e3:9.1f} ms/round "
                  f"(x{row['speedup_vs_compiled_eager'] or '—'} vs eager)",
                  flush=True)
        del base
    payload = {
        "benchmark": "bench_rounds --wallclock",
        "smoke": smoke,
        "jax": jax.__version__,
        "device": str(jax.devices()[0].platform),
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {out}")
    return payload


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--wallclock", action="store_true",
                   help="time the execution variants instead of fig3")
    p.add_argument("--full", action="store_true", help="(fig3) full grid")
    p.add_argument("--rounds", type=int, default=None)
    p.add_argument("--n-clients", type=int, default=100)
    p.add_argument("--m", type=int, default=10)
    p.add_argument("--tasks", nargs="+", default=["classification", "lm"],
                   choices=["classification", "lm"])
    p.add_argument("--smoke", action="store_true",
                   help="(wallclock) tiny CI config: 12 clients, small "
                        "model/data — trajectory tracking, not absolute "
                        "numbers")
    p.add_argument("--out", default=BENCH_JSON)
    return p.parse_args(argv)


if __name__ == "__main__":
    args = _parse_args()
    if args.wallclock:
        if args.smoke:
            args.n_clients, args.m = 12, 4
            args.rounds = args.rounds or 4
        wallclock_main(args.rounds or 10, args.n_clients, args.m,
                       args.tasks, args.smoke, args.out)
    else:
        for r in main(full=args.full, rounds=args.rounds):
            print(",".join(str(x) for x in r))
