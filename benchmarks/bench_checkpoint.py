"""Checkpoint / resume overhead and the kill-and-resume contract as a
measured benchmark (DESIGN.md §12).

Two questions, per backend (``host`` and the scan-fused compiled mode):

- **overhead** — wall-clock per round with an every-round save policy +
  JSONL tracker vs the bare engine (checkpoint bytes and save latency
  reported alongside); and
- **fidelity** — a 2-chunk save→kill→resume run must land bit-identical
  to the uninterrupted run (params max |Δ| exactly 0.0, identical
  selections and history) — the acceptance bar of the checkpointing
  layer, here verified on the benchmark config rather than the tiny
  test fixtures.

Writes ``BENCH_checkpoint.json`` (repo root) and leaves the resumed
run's ``metrics.jsonl`` next to it for the CI artifact upload
(``--smoke`` on the ``perf-smoke`` job).
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

ROOT = os.path.join(os.path.dirname(__file__), "..")
BENCH_JSON = os.path.join(ROOT, "BENCH_checkpoint.json")

BACKENDS = {
    "host": dict(backend="host"),
    "fused": dict(backend="compiled", fuse_rounds=2),
}


def _cfg(smoke: bool, rounds: int, seed: int, **kw):
    from repro.engine import FLConfig

    return FLConfig(
        n_clients=24 if smoke else 100, m=6 if smoke else 10,
        rounds=rounds, seed=seed,
        strategy="fedlecc", strategy_kwargs={"J": 3},
        hidden=(64,) if smoke else (200, 200),
        eval_samples=16 if smoke else 64,
        eval_every=2, target_hd=0.8,
        **kw,
    )


def _max_abs_delta(a, b) -> float:
    import jax

    return max(
        float(np.max(np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64))))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def main(args) -> dict:
    import jax

    from repro.checkpoint import (
        Checkpointer, CheckpointPolicy, JsonlTracker, read_jsonl,
    )
    from repro.data import make_classification
    from repro.engine import make_engine

    n = 2_000 if args.smoke else 20_000
    train = make_classification(n, n_features=64, n_classes=10, seed=0)
    test = make_classification(max(n // 10, 200), n_features=64, n_classes=10,
                               seed=1)
    mk_cfg = lambda **kw: _cfg(args.smoke, args.rounds, args.seed, **kw)

    rows = []
    workdir = tempfile.mkdtemp(prefix="bench_ckpt_")
    jsonl_out = os.path.join(os.path.dirname(args.out), "metrics.jsonl")
    try:
        for name, bkw in BACKENDS.items():
            # untimed warmup runs: populate the in-process compile caches
            # for BOTH execution shapes (an every-round save policy clips
            # the fused engine to length-1 chunks — a different compiled
            # shape than the bare run) so the bare-vs-checkpointed
            # comparison isn't skewed by whichever engine traces first
            list(make_engine(mk_cfg(**bkw), train, test, n_classes=10).rounds())
            warm = make_engine(
                mk_cfg(**bkw), train, test, n_classes=10,
                checkpointer=Checkpointer(
                    os.path.join(workdir, f"{name}_warm"),
                    CheckpointPolicy(every_rounds=1, keep_last=1)),
            )
            list(warm.rounds())

            # bare reference: no checkpointing machinery at all
            bare = make_engine(mk_cfg(**bkw), train, test, n_classes=10)
            t0 = time.perf_counter()
            bare_results = list(bare.rounds())
            bare_s = time.perf_counter() - t0
            bare_params = jax.device_get(bare.params)

            # checkpointed run: every-round saves + JSONL tracker.
            # (The fused cell's save policy clips its chunks, so the bare
            # fused reference above uses a different chunk pattern — the
            # fidelity comparison below therefore runs its *own*
            # same-policy reference; the overhead ratio stays honest
            # because both cells do the same round math.)
            ckdir = os.path.join(workdir, name)
            mk_ck = lambda: Checkpointer(
                ckdir, CheckpointPolicy(every_rounds=1, keep_last=3))
            tracked = make_engine(
                mk_cfg(**bkw), train, test, n_classes=10,
                checkpointer=mk_ck(),
                tracker=JsonlTracker(os.path.join(ckdir, "metrics.jsonl")),
            )
            t0 = time.perf_counter()
            full_results = list(tracked.rounds())
            ckpt_s = time.perf_counter() - t0
            tracked.close_trackers()
            full_params = jax.device_get(tracked.params)
            ckpt_file = tracked.checkpointer.latest()
            ckpt_mb = os.path.getsize(ckpt_file) / 1e6

            # one timed save in isolation (the per-save latency)
            t0 = time.perf_counter()
            tracked.save(os.path.join(workdir, f"{name}_probe.ckpt"))
            save_s = time.perf_counter() - t0

            # 2-chunk kill-and-resume: run half, abandon, rebuild+resume
            half = args.rounds // 2
            shutil.rmtree(ckdir)
            killed = make_engine(
                mk_cfg(**bkw), train, test, n_classes=10,
                checkpointer=mk_ck(),
                tracker=JsonlTracker(os.path.join(ckdir, "metrics.jsonl")),
            )
            it = killed.rounds()
            pre = [next(it) for _ in range(half)]
            it.close()
            killed.close_trackers()
            t0 = time.perf_counter()
            resumed = make_engine(
                mk_cfg(**bkw), train, test, n_classes=10,
                resume=ckdir, checkpointer=mk_ck(),
                tracker=JsonlTracker(os.path.join(ckdir, "metrics.jsonl")),
            )
            restore_s = time.perf_counter() - t0
            post = list(resumed.rounds())
            resumed.close_trackers()

            delta = _max_abs_delta(full_params, jax.device_get(resumed.params))
            sel_match = (
                [r.selected for r in pre + post]
                == [r.selected for r in full_results]
            )
            rows.append({
                "backend": name,
                # the every-round policy clips fused chunks to length 1,
                # so the fused overhead number includes the cost (or, at
                # smoke scale, benefit) of the changed chunking — align
                # every_rounds with eval boundaries to keep fusion
                "note": ("every-round saves force length-1 chunks"
                         if name == "fused" else None),
                "rounds": args.rounds,
                "bare_s_per_round": round(bare_s / args.rounds, 4),
                "ckpt_s_per_round": round(ckpt_s / args.rounds, 4),
                "overhead_pct": round(100.0 * (ckpt_s - bare_s) / bare_s, 1),
                "save_s": round(save_s, 4),
                "restore_s": round(restore_s, 4),
                "ckpt_mb": round(ckpt_mb, 3),
                "resume_params_max_abs_delta": delta,
                "resume_selections_identical": sel_match,
                "resume_round": half,
            })
            print(f"[ckpt] {name:<6s} bare={rows[-1]['bare_s_per_round']:.3f}"
                  f"s/rnd ckpt={rows[-1]['ckpt_s_per_round']:.3f}s/rnd "
                  f"(+{rows[-1]['overhead_pct']:.1f}%) save={save_s*1e3:.1f}ms "
                  f"size={ckpt_mb:.2f}MB resumeΔ={delta:.1e} "
                  f"sel_ok={sel_match}", flush=True)

            # the resumed run's tracker file is the CI artifact: dedupe
            # shows the at-least-once contract converging to one history
            if name == "fused":
                shutil.copy(os.path.join(ckdir, "metrics.jsonl"), jsonl_out)
                assert [r["round"] for r in read_jsonl(jsonl_out)] == list(
                    range(args.rounds)
                )
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    ok = all(
        r["resume_params_max_abs_delta"] == 0.0
        and r["resume_selections_identical"] for r in rows
    )
    out = {
        "config": {"smoke": args.smoke, "rounds": args.rounds,
                   "seed": args.seed},
        "rows": rows,
        "summary": {"resume_bit_identical": ok},
        "metrics_jsonl": os.path.basename(jsonl_out),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"[ckpt] resume_bit_identical={ok} → {args.out}")
    if not ok:
        raise SystemExit("kill-and-resume fidelity check failed")
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--rounds", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="small model/data + few rounds (the CI config)")
    p.add_argument("--out", default=BENCH_JSON)
    args = p.parse_args()
    if args.rounds is None:
        args.rounds = 8 if args.smoke else 40
    main(args)
