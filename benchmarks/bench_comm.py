"""Table III — average communication overhead (MB, smaller is better).

The ledger (repro.core.comm_model) reproduces the paper's accounting:
model params down+up for selected clients, loss polling, one-time
histograms.  FedLECC's advantage appears when it reaches a target
accuracy with a smaller participation budget — we report both the
per-round MB at the paper's m and the MB-to-target-accuracy from the
shared simulation runs.
"""

from __future__ import annotations

import numpy as np

from benchmarks.fl_common import ensure_runs, methods_for
from repro.engine import rounds_to_accuracy


def main(full: bool = False, rounds: int | None = None, target: float = 0.5) -> list[tuple]:
    methods = methods_for(full)
    seeds = [0, 1] if full else [0]
    rounds = rounds or (100 if full else 60)
    runs = ensure_runs(methods, seeds, rounds)
    rows = []
    for method in methods:
        cells = [r for r in runs if r["method"] == method]
        per_round = np.mean(
            [r["history"]["comm_mb"][-1] / rounds for r in cells]
        )
        # MB spent until the target accuracy was first reached
        mbs = []
        for r in cells:
            h = r["history"]
            rt = rounds_to_accuracy(h, target)
            if rt is None:
                mbs.append(float("nan"))
            else:
                i = h["round"].index(rt)
                mbs.append(h["comm_mb"][i])
        mb_to_target = float(np.nanmean(mbs)) if not all(np.isnan(mbs)) else float("nan")
        rows.append(
            (
                f"table3_comm/{method}",
                0.0,
                f"mb_per_round={per_round:.2f};mb_to_acc{target}={mb_to_target:.1f}",
            )
        )

    # The paper's Table III headline (−50% overhead) comes from FedLECC
    # operating at a REDUCED participation budget: m=4 vs the baselines'
    # m=10 — fewer but better-chosen clients.
    small = ensure_runs(["fedlecc"], seeds, rounds, m=4)
    if small:
        per_round = np.mean([r["history"]["comm_mb"][-1] / rounds for r in small])
        accs = [r["history"]["test_acc"][-1] for r in small]
        mbs = []
        for r in small:
            h = r["history"]
            rt = rounds_to_accuracy(h, target)
            mbs.append(h["comm_mb"][h["round"].index(rt)] if rt is not None else float("nan"))
        mb_t = float(np.nanmean(mbs)) if not all(np.isnan(mbs)) else float("nan")
        rows.append(
            (
                "table3_comm/fedlecc_m4",
                0.0,
                f"mb_per_round={per_round:.2f};mb_to_acc{target}={mb_t:.1f};"
                f"final_acc={np.mean(accs):.3f}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
