"""Benchmark harness — one module per paper table/figure.

  bench_accuracy   — Table II   (final accuracy under severe label skew)
  bench_comm       — Table III  (communication overhead, MB)
  bench_rounds     — Fig 3      (rounds-to-target-accuracy, −22% claim)
  bench_selection  — "lightweight selection" claim (μs per selection stage)
  bench_kernels    — kernel substrate micro-benchmarks
  roofline         — EXPERIMENTS.md §Roofline from results/dryrun.jsonl

``python -m benchmarks.run`` executes all of them and prints
``name,us_per_call,derived`` CSV rows.
"""
