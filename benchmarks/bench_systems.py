"""Time-to-accuracy under the systems model (``repro.systems``,
DESIGN.md §10).

Runs the selection-strategy grid (fedlecc vs random vs poc vs haccs)
under the ``mobile_mix`` device profile and compares, per strategy,

- the **no-deadline baseline** (the server waits for every reachable
  client — each round costs the slowest dispatched device), against
- **deadline + over-selection** configurations (dispatch
  ``ceil(m·over_select)`` clients, drop stragglers past the deadline,
  reweight the survivors),

in *simulated wall-clock to the target accuracy* — the currency
cross-device FL actually optimizes — plus bytes-to-target from the
``CommModel`` ledger.  The deadline is derived from the profile itself
(a percentile of the jitter-free per-client round times), so one flag
scales across profile presets and model sizes.

This also exercises HACCS's profile-derived latency tiebreak: under a
systems config its per-cluster "fastest device first" rank comes from
the actual ``mobile_mix`` round times rather than the legacy lognormal
placeholder.

Writes ``BENCH_systems.json`` (repo root; the CI ``perf-smoke`` job
regenerates and uploads the ``--smoke`` config per commit).  The
summary block records, per strategy, the best deadline configuration's
speedup over the no-deadline baseline — the acceptance bar is that at
least one configuration reaches the target in less simulated time.

``--async`` switches to the sync-vs-async sweep (DESIGN.md §13): the
same ``mobile_mix``+markov environment, comparing the lock-step
no-deadline baseline and the deadline+over-selection configuration
against FedBuff-style async cells (``FLConfig.async_mode`` with
polynomial staleness discount) in simulated time-to-target.  Async
aggregation steps pop ``buffer_k`` arrivals instead of awaiting a
cohort, so async cells run proportionally more steps to keep the total
aggregated client work comparable.  Writes ``BENCH_async.json``; the
acceptance bar is an async cell reaching the target ≥ 1.5× faster in
simulated wall-clock than the sync deadline configuration for at least
one strategy.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

ROOT = os.path.join(os.path.dirname(__file__), "..")
BENCH_JSON = os.path.join(ROOT, "BENCH_systems.json")
BENCH_ASYNC_JSON = os.path.join(ROOT, "BENCH_async.json")

STRATEGIES = ("fedlecc", "random", "poc", "haccs")
# the async sweep adds the predicted-T_i strategy (follow-up (n)) —
# inside the async scheduler it dispatches the fastest idle clients
ASYNC_STRATEGIES = ("fedlecc", "random", "fedcs")
STRATEGY_KWARGS = {"fedlecc": {"J": 3}}


def _cfg(strategy: str, systems: dict | None, *, smoke: bool, rounds: int,
         n_clients: int, m: int, seed: int, async_mode: dict | None = None):
    from repro.engine import FLConfig

    return FLConfig(
        n_clients=n_clients, m=m, rounds=rounds, seed=seed,
        strategy=strategy,
        strategy_kwargs=dict(STRATEGY_KWARGS.get(strategy, {})),
        hidden=(64,) if smoke else (200, 200),
        eval_samples=16 if smoke else 64,
        eval_every=1 if smoke else 2,
        target_hd=0.8 if smoke else 0.9,
        systems=systems,
        async_mode=async_mode,
    )


def _systems(deadline_s: float | None, over_select: float) -> dict:
    return dict(
        profile="mobile_mix",
        availability="markov",
        availability_kwargs={"p_drop": 0.1, "p_join": 0.5},
        jitter_sigma=0.2,
        deadline_s=deadline_s,
        over_select=over_select,
    )


def _run(cfg, data):
    from repro.engine import make_engine

    train, test = data
    engine = make_engine(cfg, train, test, n_classes=10)
    results = list(engine.rounds())
    return engine, results


def _time_to(results, target: float):
    """(round, sim_clock, comm_mb) at the first evaluated round reaching
    the target accuracy, or None."""
    for r in results:
        if r.test_acc is not None and r.test_acc >= target:
            return r.round, r.sim_clock, r.comm_mb
    return None


def main(args) -> dict:
    from repro.data import make_classification

    n = 2_000 if args.smoke else 20_000
    data = (
        make_classification(n, n_features=64, n_classes=10, seed=0),
        make_classification(max(n // 10, 200), n_features=64, n_classes=10,
                            seed=1),
    )
    run_kw = dict(smoke=args.smoke, rounds=args.rounds,
                  n_clients=args.n_clients, m=args.m, seed=args.seed)

    # Derive the deadline from the profile: a percentile of the
    # jitter-free per-client round times.  The clock is fully determined
    # at engine construction (profile + steps + payload — no training
    # needed), so the probe engine never runs a round.
    from repro.engine import make_engine

    probe = make_engine(
        _cfg("random", _systems(None, 1.0), **{**run_kw, "rounds": 1}),
        data[0], data[1], n_classes=10,
    )
    base_times = probe._systems.clock.base_times()
    deadline = float(np.percentile(base_times, args.deadline_pct))

    scenarios = [("no_deadline", _systems(None, 1.0))]
    for os_f in args.over_select:
        scenarios.append(
            (f"deadline_p{args.deadline_pct}_os{os_f}",
             _systems(deadline, float(os_f)))
        )

    rows, curves = [], {}
    for strategy in args.strategies:
        for name, sysd in scenarios:
            engine, results = _run(_cfg(strategy, dict(sysd), **run_kw), data)
            evald = [r for r in results if r.test_acc is not None]
            curves[(strategy, name)] = results
            rows.append({
                "strategy": strategy,
                "scenario": name,
                "deadline_s": sysd["deadline_s"],
                "over_select": sysd["over_select"],
                "rounds": args.rounds,
                "final_acc": round(evald[-1].test_acc, 4),
                "best_acc": round(max(r.test_acc for r in evald), 4),
                "total_sim_s": round(results[-1].sim_clock, 2),
                "total_comm_mb": round(results[-1].comm_mb, 3),
                "mean_dropped_per_round": round(
                    float(np.mean([r.n_dropped for r in results])), 2
                ),
            })
            print(f"[systems] {strategy:<8s} {name:<22s} "
                  f"acc={rows[-1]['final_acc']:.3f} "
                  f"sim={rows[-1]['total_sim_s']:8.1f}s "
                  f"drop/rnd={rows[-1]['mean_dropped_per_round']:.1f}",
                  flush=True)

    # Per strategy: common reachable target, then time/bytes to it.
    summary = []
    for strategy in args.strategies:
        per = {n: curves[(strategy, n)] for n, _ in scenarios}
        target = args.target or 0.95 * min(
            max(r.test_acc for r in rs if r.test_acc is not None)
            for rs in per.values()
        )
        reach = {n: _time_to(rs, target) for n, rs in per.items()}
        base = reach["no_deadline"]
        best_name, best = None, None
        for n, hit in reach.items():
            if n == "no_deadline" or hit is None:
                continue
            if best is None or hit[1] < best[1]:
                best_name, best = n, hit
        for row in rows:
            if row["strategy"] == strategy:
                hit = reach[row["scenario"]]
                row["target_acc"] = round(target, 4)
                row["rounds_to_target"] = None if hit is None else hit[0]
                row["sim_s_to_target"] = None if hit is None else round(hit[1], 2)
                row["comm_mb_to_target"] = None if hit is None else round(hit[2], 3)
        summary.append({
            "strategy": strategy,
            "target_acc": round(target, 4),
            "no_deadline_sim_s": None if base is None else round(base[1], 2),
            "best_deadline_scenario": best_name,
            "best_deadline_sim_s": None if best is None else round(best[1], 2),
            "speedup": (
                None if base is None or best is None
                else round(base[1] / best[1], 2)
            ),
        })
        print(f"[systems] {strategy:<8s} target={target:.3f} "
              f"no-deadline={summary[-1]['no_deadline_sim_s']}s "
              f"best={best_name}={summary[-1]['best_deadline_sim_s']}s "
              f"(x{summary[-1]['speedup']})", flush=True)

    import jax

    payload = {
        "benchmark": "bench_systems",
        "smoke": args.smoke,
        "jax": jax.__version__,
        "device": str(jax.devices()[0].platform),
        "profile": "mobile_mix",
        "deadline_s": round(deadline, 2),
        "deadline_pct": args.deadline_pct,
        "results": rows,
        "summary": summary,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {args.out}")
    return payload


def main_async(args) -> dict:
    """The ``--async`` sweep: sync no-deadline / sync deadline vs
    FedBuff-style async cells under ``mobile_mix``+markov, compared in
    simulated time-to-target."""
    from repro.data import make_classification
    from repro.engine import make_engine

    n = 2_000 if args.smoke else 20_000
    data = (
        make_classification(n, n_features=64, n_classes=10, seed=0),
        make_classification(max(n // 10, 200), n_features=64, n_classes=10,
                            seed=1),
    )
    run_kw = dict(smoke=args.smoke, n_clients=args.n_clients, m=args.m,
                  seed=args.seed)

    probe = make_engine(
        _cfg("random", _systems(None, 1.0), rounds=1, **run_kw),
        data[0], data[1], n_classes=10,
    )
    base_times = probe._systems.clock.base_times()
    deadline = float(np.percentile(base_times, args.deadline_pct))

    # async cells pop buffer_k ≤ m arrivals per step, so they run
    # proportionally more steps to aggregate comparable client work
    k = max(args.m // 2, 1)
    conc = 2 * args.m
    acfg = dict(staleness="polynomial", staleness_kwargs={"a": 0.5})
    scenarios = [
        ("sync_no_deadline", _systems(None, 1.0), None, args.rounds),
        (f"sync_deadline_p{args.deadline_pct:g}_os1.3",
         _systems(deadline, 1.3), None, args.rounds),
        (f"async_k{k}", _systems(None, 1.0),
         dict(acfg, buffer_k=k, concurrency=conc),
         args.rounds * max(args.m // k, 1)),
        (f"async_k{args.m}", _systems(None, 1.0),
         dict(acfg, buffer_k=args.m, concurrency=conc), args.rounds),
    ]

    rows, curves = [], {}
    for strategy in args.strategies:
        for name, sysd, async_mode, rounds in scenarios:
            cfg = _cfg(strategy, dict(sysd), rounds=rounds,
                       async_mode=async_mode and dict(async_mode), **run_kw)
            engine, results = _run(cfg, data)
            evald = [r for r in results if r.test_acc is not None]
            curves[(strategy, name)] = results
            rows.append({
                "strategy": strategy,
                "scenario": name,
                "async_mode": async_mode,
                "deadline_s": sysd["deadline_s"],
                "over_select": sysd["over_select"],
                "rounds": rounds,
                "final_acc": round(evald[-1].test_acc, 4),
                "best_acc": round(max(r.test_acc for r in evald), 4),
                "total_sim_s": round(results[-1].sim_clock, 2),
                "total_comm_mb": round(results[-1].comm_mb, 3),
                "final_params_version": results[-1].params_version,
                "mean_staleness": round(
                    float(np.mean([r.staleness for r in results])), 3
                ),
            })
            print(f"[async] {strategy:<8s} {name:<24s} "
                  f"acc={rows[-1]['best_acc']:.3f} "
                  f"sim={rows[-1]['total_sim_s']:8.1f}s "
                  f"stal={rows[-1]['mean_staleness']:.2f}", flush=True)

    # Per strategy: common reachable target, then sim-time to it; the
    # acceptance ratio is async-vs-sync-deadline.
    summary = []
    ddl_name = scenarios[1][0]
    for strategy in args.strategies:
        per = {n_: curves[(strategy, n_)] for n_, *_ in scenarios}
        target = args.target or 0.95 * min(
            max(r.test_acc for r in rs if r.test_acc is not None)
            for rs in per.values()
        )
        reach = {n_: _time_to(rs, target) for n_, rs in per.items()}
        best_name, best = None, None
        for n_, hit in reach.items():
            if not n_.startswith("async") or hit is None:
                continue
            if best is None or hit[1] < best[1]:
                best_name, best = n_, hit
        for row in rows:
            if row["strategy"] == strategy:
                hit = reach[row["scenario"]]
                row["target_acc"] = round(target, 4)
                row["rounds_to_target"] = None if hit is None else hit[0]
                row["sim_s_to_target"] = None if hit is None else round(hit[1], 2)
                row["comm_mb_to_target"] = None if hit is None else round(hit[2], 3)
        ddl = reach[ddl_name]
        summary.append({
            "strategy": strategy,
            "target_acc": round(target, 4),
            "sync_no_deadline_sim_s": (
                None if reach["sync_no_deadline"] is None
                else round(reach["sync_no_deadline"][1], 2)
            ),
            "sync_deadline_sim_s": None if ddl is None else round(ddl[1], 2),
            "best_async_scenario": best_name,
            "best_async_sim_s": None if best is None else round(best[1], 2),
            "async_vs_deadline_speedup": (
                None if ddl is None or best is None
                else round(ddl[1] / best[1], 2)
            ),
        })
        print(f"[async] {strategy:<8s} target={target:.3f} "
              f"deadline={summary[-1]['sync_deadline_sim_s']}s "
              f"best={best_name}={summary[-1]['best_async_sim_s']}s "
              f"(x{summary[-1]['async_vs_deadline_speedup']})", flush=True)

    import jax

    payload = {
        "benchmark": "bench_systems_async",
        "smoke": args.smoke,
        "jax": jax.__version__,
        "device": str(jax.devices()[0].platform),
        "profile": "mobile_mix",
        "deadline_s": round(deadline, 2),
        "deadline_pct": args.deadline_pct,
        "buffer_k": k,
        "concurrency": conc,
        "results": rows,
        "summary": summary,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {args.out}")
    return payload


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--async", dest="async_sweep", action="store_true",
                   help="run the sync-vs-async sweep (FLConfig.async_mode) "
                        "instead of the deadline/over-selection grid; "
                        "writes BENCH_async.json")
    p.add_argument("--strategies", nargs="+", default=None,
                   choices=sorted(set(STRATEGIES) | set(ASYNC_STRATEGIES)))
    p.add_argument("--rounds", type=int, default=None)
    p.add_argument("--n-clients", type=int, default=100)
    p.add_argument("--m", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--deadline-pct", type=float, default=60.0,
                   help="deadline = this percentile of the profile's "
                        "jitter-free per-client round times")
    p.add_argument("--over-select", nargs="+", type=float,
                   default=[1.0, 1.3, 1.6])
    p.add_argument("--target", type=float, default=None,
                   help="explicit target accuracy; default: 95%% of the "
                        "worst scenario's best accuracy, per strategy")
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI config: 12 clients, small model/data — "
                        "trajectory tracking, not absolute numbers")
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)
    if args.strategies is None:
        args.strategies = list(ASYNC_STRATEGIES if args.async_sweep
                               else STRATEGIES)
    if args.out is None:
        args.out = BENCH_ASYNC_JSON if args.async_sweep else BENCH_JSON
    if args.smoke:
        args.n_clients, args.m = 12, 4
        args.rounds = args.rounds or 10
    else:
        args.rounds = args.rounds or 60
    return args


if __name__ == "__main__":
    args = _parse_args()
    if args.async_sweep:
        main_async(args)
    else:
        main(args)
