"""Population-scale selection + training (``repro.population``,
DESIGN.md §15).

Sweeps the client population K ∈ {10³, 10⁴, 10⁵, 10⁶} (``--smoke``:
{10³, 10⁴}) and measures, per K:

- **store build**    — ``ShardedStore`` summary construction (sizes +
  label histograms for every shard, *no* feature synthesis);
- **selector build** — shard clustering (OPTICS over the blocked HD
  matrix up to 2048 shards, on-demand k-medoids beyond — K = 10⁶
  exercises the k-medoids path);
- **per-round selection** — ``begin_round`` (shard-level Algorithm 1 +
  member concat), ``observe`` (estimate update), ``select_cohort``
  (resident-local top-m) — the full server-side selection loop a
  population round runs;
- **memory** — bytes device-gathered per round (resident poll rows +
  cohort rows; *flat in K* because the shard size and shards_per_round
  are fixed) against the flat engine's device-resident full stack and
  the dense K² HD matrix (both population-proportional).

K = 10³ additionally runs the *end-to-end engines* — flat fedlecc vs
hierarchical population fedlecc on the same synthetic task — and
reports final-accuracy parity (the acceptance bar: the hierarchy's
restriction to resident shards costs ~nothing at equal m).  K ≥ 10⁵
rows are selection-only (no engine training) and say so in-row.

Writes ``BENCH_population.json`` (repo root; CI ``perf-smoke``
regenerates and uploads the ``--smoke`` config per commit — the
committed file is a full run).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

ROOT = os.path.join(os.path.dirname(__file__), "..")
BENCH_JSON = os.path.join(ROOT, "BENCH_population.json")

# fixed shard geometry: resident rows per round stay constant across K,
# which is exactly the flat-device-memory claim the sweep demonstrates
SHARD_SIZE = 256
SHARDS_PER_ROUND = 4
J_SHARDS = 3
M_COHORT = 32

_MB = 1024.0 * 1024.0


def _mb(n_bytes: float) -> float:
    return round(n_bytes / _MB, 4)


def _row_bytes(n_features: int, n_max: int) -> int:
    # one packed client row: xs (N_max, F) f32 + ys (N_max,) i32 +
    # mask (N_max,) f32
    return n_max * (n_features * 4 + 4 + 4)


def selection_row(K: int, rounds: int, seed: int = 0) -> dict:
    """Selection-only sweep cell: store summaries + hierarchy + the
    per-round selection loop, with simulated member losses standing in
    for the poll (no training, no device work — noted in-row)."""
    from repro.population import (
        HierarchicalSelector,
        PopulationConfig,
        ShardedStore,
        SyntheticShardLoader,
    )

    n_shards = max(SHARDS_PER_ROUND, K // SHARD_SIZE)
    n_feat, n_max = 64, 16

    t0 = time.perf_counter()
    store = ShardedStore(
        SyntheticShardLoader(seed=seed, n_features=n_feat, n_classes=10,
                             samples=(8, n_max)),
        n_clients=K, n_shards=n_shards,
    )
    t_store = time.perf_counter() - t0

    cfg = PopulationConfig(n_shards=n_shards,
                           shards_per_round=min(SHARDS_PER_ROUND, n_shards),
                           j_shards=J_SHARDS)
    t0 = time.perf_counter()
    sel = HierarchicalSelector(cfg, store, seed=seed, needs_losses=True)
    t_selector = time.perf_counter() - t0

    rng = np.random.default_rng(seed)
    t_rounds = []
    resident = 0
    for rnd in range(rounds):
        t0 = time.perf_counter()
        _, members = sel.begin_round(rnd)
        # simulated poll: the loss vector only exists for the residents
        member_losses = rng.random(len(members)).astype(np.float32)
        losses = np.full(store.n_clients, -np.inf, np.float32)
        losses[members] = member_losses
        sel.observe(losses)
        cohort = sel.select_cohort(member_losses, m=M_COHORT)
        t_rounds.append(time.perf_counter() - t0)
        resident = len(members)
        assert len(cohort) == min(M_COHORT, resident)

    rb = _row_bytes(n_feat, n_max)
    return {
        "K": K,
        "mode": "selection-only",
        "note": ("no training at this scale — selection loop + summaries "
                 "only; losses simulated in place of the device poll"),
        "n_shards": n_shards,
        "shard_size": int(np.ceil(K / n_shards)),
        "resident_clients_per_round": resident,
        "shard_cluster_algo": ("optics" if n_shards <= 2048 else "kmedoids"),
        "t_store_build_s": round(t_store, 3),
        "t_selector_build_s": round(t_selector, 3),
        "t_round_select_ms": round(float(np.mean(t_rounds)) * 1e3, 3),
        # memory story: what a round moves to device vs what the flat
        # engine keeps device-resident, and the dense-HD matrix neither
        # side ever builds
        "gather_mb_per_round": _mb((resident + M_COHORT) * rb),
        "flat_full_stack_mb": _mb(K * rb),
        "dense_hd_matrix_mb": _mb(K * K * 4.0),
        "poll_bytes_per_round": int(resident * 4),
        "flat_poll_bytes_per_round": int(K * 4),
        "materialized_shards": len(store.materialized_shards()),
    }


def training_row(K: int, rounds: int, smoke: bool, seed: int = 0) -> dict:
    """End-to-end engine cell at K = 10³: flat fedlecc vs hierarchical
    population fedlecc on one synthetic task — accuracy parity is the
    acceptance bar."""
    from repro.data import make_classification
    from repro.engine import FLConfig, make_engine

    n = 32 * K
    train = make_classification(n, n_features=64, n_classes=10, seed=seed)
    test = make_classification(1000, n_features=64, n_classes=10,
                               seed=seed + 1)
    # finer shards than the selection-only geometry so residency is
    # genuinely partial at K = 10³ (16 shards, 4 resident per round)
    n_shards = max(8, K // 64)

    def _cfg(population):
        return FLConfig(
            n_clients=K, m=M_COHORT, rounds=rounds, seed=seed,
            strategy="fedlecc", strategy_kwargs={"J": 5},
            hidden=(64,), eval_samples=8 if smoke else 16,
            eval_every=max(rounds // 4, 1), target_hd=0.8,
            batch_size=16, local_epochs=2, lr=0.05,
            population=population,
        )

    out: dict = {"K": K, "mode": "train+selection", "rounds": rounds,
                 "n_shards": n_shards}
    for name, population in (
        ("flat", None),
        ("population", {"n_shards": n_shards,
                        "shards_per_round": min(SHARDS_PER_ROUND, n_shards),
                        "j_shards": J_SHARDS}),
    ):
        eng = make_engine(_cfg(population), train, test, n_classes=10)
        t0 = time.perf_counter()
        results = list(eng.rounds())
        wall = time.perf_counter() - t0
        evald = [r for r in results if r.test_acc is not None]
        out[f"{name}_final_acc"] = round(evald[-1].test_acc, 4)
        out[f"{name}_best_acc"] = round(max(r.test_acc for r in evald), 4)
        out[f"{name}_comm_mb"] = round(results[-1].comm_mb, 3)
        out[f"{name}_wall_s_per_round"] = round(wall / rounds, 3)
        if population is not None:
            members = eng._pop_members
            rb = _row_bytes(64, int(eng._store._xs.shape[1]))
            out["resident_clients_per_round"] = int(len(members))
            out["gather_mb_per_round"] = _mb((len(members) + M_COHORT) * rb)
            out["flat_full_stack_mb"] = _mb(K * rb)
        print(f"[population] K={K} {name:<10s} "
              f"acc={out[f'{name}_final_acc']:.3f} "
              f"comm={out[f'{name}_comm_mb']:.1f}MB "
              f"wall={out[f'{name}_wall_s_per_round']:.2f}s/rnd", flush=True)
    out["acc_gap"] = round(
        abs(out["flat_final_acc"] - out["population_final_acc"]), 4
    )
    return out


def main(args) -> dict:
    ks = (1_000, 10_000) if args.smoke else (1_000, 10_000, 100_000, 1_000_000)
    rows = []
    for K in ks:
        if K <= 1_000:
            rows.append(
                training_row(K, rounds=args.train_rounds, smoke=args.smoke,
                             seed=args.seed)
            )
            # the same K also gets a selection-only cell so the sweep's
            # timing/memory columns are comparable across every K
        rows.append(selection_row(K, rounds=args.select_rounds,
                                  seed=args.seed))
        r = rows[-1]
        print(f"[population] K={K:>9,d} shards={r['n_shards']:>6d} "
              f"select={r['t_round_select_ms']:8.3f}ms/rnd "
              f"gather={r['gather_mb_per_round']:8.2f}MB "
              f"(flat stack {r['flat_full_stack_mb']:11.1f}MB)", flush=True)

    sel_rows = [r for r in rows if r["mode"] == "selection-only"]
    k0, k1 = sel_rows[0], sel_rows[-1]
    train_rows = [r for r in rows if r["mode"] == "train+selection"]
    summary = {
        # sub-linear selection: time grows far slower than K
        "k_growth": round(k1["K"] / k0["K"], 1),
        "select_time_growth": round(
            k1["t_round_select_ms"] / max(k0["t_round_select_ms"], 1e-6), 1
        ),
        # flat device memory: per-round gather is constant across K
        "gather_mb_min": min(r["gather_mb_per_round"] for r in sel_rows),
        "gather_mb_max": max(r["gather_mb_per_round"] for r in sel_rows),
        "acc_gap_at_1k": (train_rows[0]["acc_gap"] if train_rows else None),
    }
    payload = {
        "bench": "population",
        "smoke": bool(args.smoke),
        "shard_size": SHARD_SIZE,
        "shards_per_round": SHARDS_PER_ROUND,
        "m": M_COHORT,
        "rows": rows,
        "summary": summary,
    }
    out = args.out or BENCH_JSON
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[population] wrote {out}: select-time x"
          f"{summary['select_time_growth']} over Kx{summary['k_growth']}, "
          f"gather {summary['gather_mb_min']}-{summary['gather_mb_max']}MB, "
          f"acc gap {summary['acc_gap_at_1k']}", flush=True)
    return payload


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="K in {1e3, 1e4} with a short training run (CI)")
    p.add_argument("--train-rounds", type=int, default=None)
    p.add_argument("--select-rounds", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None)
    a = p.parse_args()
    if a.train_rounds is None:
        a.train_rounds = 12 if a.smoke else 30
    if a.select_rounds is None:
        a.select_rounds = 20 if a.smoke else 40
    main(a)
