"""Selection-stage cost — the paper's "lightweight" claim.

Times each server-side stage (HD matrix, OPTICS, Algorithm 1, baselines)
at the paper's scales K ∈ {100, 250}.  All stages are O(K²) or better
and sit in the microsecond-to-millisecond band — vanishingly small next
to a training round.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import extract_clusters, optics
from repro.core.hellinger import hellinger_matrix
from repro.core.selection import fedlecc_select, fedlecc_select_jax


def _time(fn, reps=20):
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def main(full: bool = False) -> list[tuple]:
    rows = []
    for K in (100, 250):
        rng = np.random.default_rng(K)
        hists = rng.dirichlet(np.ones(10) * 0.1, size=K)
        h_j = jnp.asarray(hists)

        t_hd = _time(lambda: jax.block_until_ready(hellinger_matrix(h_j)))
        d = hellinger_matrix(h_j)
        t_optics = _time(lambda: jax.block_until_ready(optics(d).reachability))
        res = optics(d)
        t_extract = _time(lambda: extract_clusters(res))
        labels = extract_clusters(res)
        losses = rng.uniform(0.5, 3.0, K).astype(np.float32)
        t_select = _time(lambda: fedlecc_select(labels, losses, m=10, J=5))
        nclu = int(labels.max()) + 1
        lab_j, los_j = jnp.asarray(labels), jnp.asarray(losses)
        t_select_jax = _time(
            lambda: jax.block_until_ready(
                fedlecc_select_jax(lab_j, los_j, m=10, J=min(5, nclu), n_clusters=nclu)
            )
        )
        total = t_hd + t_optics + t_extract + t_select
        rows += [
            (f"selection/hellinger_K{K}", round(t_hd, 1), f"K={K};C=10"),
            (f"selection/optics_K{K}", round(t_optics, 1), f"clusters={nclu}"),
            (f"selection/extract_K{K}", round(t_extract, 1), ""),
            (f"selection/algorithm1_K{K}", round(t_select, 1), "numpy"),
            (f"selection/algorithm1_jax_K{K}", round(t_select_jax, 1), "jit"),
            (f"selection/total_stage_K{K}", round(total, 1),
             "one-time clustering amortized over rounds"),
        ]
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
