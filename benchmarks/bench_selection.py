"""Selection-stage cost — the paper's "lightweight" claim.

Times each server-side stage (HD matrix, OPTICS, Algorithm 1, baselines)
at the paper's scales K ∈ {100, 250}.  All stages are O(K²) or better
and sit in the microsecond-to-millisecond band — vanishingly small next
to a training round.

``--clients`` sweeps other population sizes instead (e.g.
``--clients 1000 10000``): past 2048 clients the HD build switches to
the blocked strip assembly and the clustering to on-demand k-medoids
(``repro.population`` / DESIGN.md §15 — the dense matrix + OPTICS pair
stops being the right tool there), and the rows say which path ran.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import extract_clusters, kmedoids_hists, optics
from repro.core.hellinger import hellinger_blocked, hellinger_matrix
from repro.core.selection import fedlecc_select, fedlecc_select_jax

# past this K the dense-matrix + OPTICS pair gives way to the blocked /
# k-medoids population path (matches repro.population.hierarchy)
_DENSE_MAX_K = 2048


def _time(fn, reps=20):
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def main(full: bool = False, clients: list[int] | None = None) -> list[tuple]:
    rows = []
    for K in (clients if clients else (100, 250)):
        rng = np.random.default_rng(K)
        hists = rng.dirichlet(np.ones(10) * 0.1, size=K)
        reps = 20 if K <= 2048 else 3

        if K <= _DENSE_MAX_K:
            h_j = jnp.asarray(hists)
            t_hd = _time(
                lambda: jax.block_until_ready(hellinger_matrix(h_j)), reps
            )
            d = hellinger_matrix(h_j)
            t_cluster = _time(
                lambda: jax.block_until_ready(optics(d).reachability), reps
            )
            res = optics(d)
            t_extract = _time(lambda: extract_clusters(res), reps)
            labels = extract_clusters(res)
            hd_name, clu_name = "hellinger", "optics"
        else:
            # population scale: the dense K² matrix never materializes —
            # strips via hellinger_blocked, clusters via k-medoids over
            # on-demand rows (DESIGN.md §15)
            t_hd = _time(lambda: hellinger_blocked(hists, block=1024), reps)
            k_clu = max(8, K // 64)
            t_cluster = _time(
                lambda: kmedoids_hists(hists, k=k_clu, seed=0, iters=5), reps
            )
            t_extract = 0.0
            labels = kmedoids_hists(hists, k=k_clu, seed=0, iters=5)
            hd_name, clu_name = "hellinger_blocked", "kmedoids"

        losses = rng.uniform(0.5, 3.0, K).astype(np.float32)
        t_select = _time(lambda: fedlecc_select(labels, losses, m=10, J=5),
                         reps)
        nclu = int(labels.max()) + 1
        lab_j, los_j = jnp.asarray(labels), jnp.asarray(losses)
        t_select_jax = _time(
            lambda: jax.block_until_ready(
                fedlecc_select_jax(lab_j, los_j, m=10, J=min(5, nclu), n_clusters=nclu)
            ),
            reps,
        )
        total = t_hd + t_cluster + t_extract + t_select
        rows += [
            (f"selection/{hd_name}_K{K}", round(t_hd, 1), f"K={K};C=10"),
            (f"selection/{clu_name}_K{K}", round(t_cluster, 1),
             f"clusters={nclu}"),
            (f"selection/extract_K{K}", round(t_extract, 1), ""),
            (f"selection/algorithm1_K{K}", round(t_select, 1), "numpy"),
            (f"selection/algorithm1_jax_K{K}", round(t_select_jax, 1), "jit"),
            (f"selection/total_stage_K{K}", round(total, 1),
             "one-time clustering amortized over rounds"),
        ]
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--clients", type=int, nargs="+", default=None,
                    help="population sizes to sweep instead of {100, 250}")
    args = ap.parse_args()
    for r in main(full=args.full, clients=args.clients):
        print(",".join(str(x) for x in r))
