"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows.  Default mode keeps the FL
tables to 3 methods × 1 seed × 60 rounds (CPU-friendly); ``--full`` runs
all 9 methods × 2 seeds × 100 rounds (the EXPERIMENTS.md numbers).
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="run a single bench: selection|kernels|accuracy|comm|rounds|roofline")
    args = ap.parse_args()

    from benchmarks import (
        bench_accuracy, bench_comm, bench_kernels, bench_rounds,
        bench_selection, roofline,
    )

    benches = {
        "selection": bench_selection.main,
        "kernels": bench_kernels.main,
        "accuracy": bench_accuracy.main,
        "comm": bench_comm.main,
        "rounds": bench_rounds.main,
        "roofline": roofline.main,
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        try:
            for row in fn(full=args.full):
                print(",".join(str(x) for x in row), flush=True)
        except Exception as e:
            failed.append(name)
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
