"""Accuracy and overhead under injected client faults (``repro.faults``,
DESIGN.md §14).

Sweeps fault rate × defense × selection strategy on the classification
task with the ``sign_flip`` Byzantine model — norm-preserving, so the
validation gate alone cannot catch it and the robust aggregators have
to carry the recovery:

- **rates** {0, 5%, 20%} of (round, client) pairs faulted;
- **defenses** ``none`` (fedavg, no gate), ``validate`` (non-finite
  screening + quantile norm clip, fedavg), and ``validate+trimmed_mean``
  (the gate plus the coordinate-wise trimmed mean);
- **strategies** fedlecc vs random.

Each strategy also runs a ``faults=None`` baseline — the engine without
the fault axis constructed at all.  Per cell the sweep records the final
accuracy, its **recovery fraction** (final acc ÷ the same strategy's
fault-free final acc), and the steady-state wall-clock per round
(first round excluded, so one-off jit compilation does not pollute the
overhead comparison).

Writes ``BENCH_robustness.json`` (repo root; the CI ``perf-smoke`` job
regenerates and uploads the ``--smoke`` config per commit).  Acceptance
bars, evaluated in the summary block:

- at 20% sign_flip, fedlecc with ``validate+trimmed_mean`` recovers
  ≥ 90% of the fault-free final accuracy;
- with defenses on at rate 0, steady-state wall-clock stays within 2%
  of the ``faults=None`` engine.
"""

from __future__ import annotations

import argparse
import json
import os
import time

ROOT = os.path.join(os.path.dirname(__file__), "..")
BENCH_JSON = os.path.join(ROOT, "BENCH_robustness.json")

STRATEGIES = ("fedlecc", "random")
STRATEGY_KWARGS = {"fedlecc": {"J": 3}}
RATES = (0.0, 0.05, 0.2)
FAULT_MODELS = ("sign_flip",)

# defense label -> (FaultConfig.defense, aggregator, aggregator_kwargs)
DEFENSES = {
    "none": ("none", "fedavg", {}),
    "validate": ("validate", "fedavg", {}),
    "validate+trimmed_mean": ("validate", "trimmed_mean",
                              {"trim_frac": 0.25}),
}
TIMING_REPEATS = 2  # overhead phase: chunk-paired trials per strategy
TIMING_ROUNDS = 240  # timing horizon (non-smoke); ~120 pairs per trial


def _cfg(strategy: str, *, smoke: bool, rounds: int, n_clients: int, m: int,
         seed: int, faults: dict | None = None, aggregator: str = "fedavg",
         aggregator_kwargs: dict | None = None):
    from repro.engine import FLConfig

    # Low label heterogeneity on purpose: coordinate-wise robust rules
    # need the honest cohort deltas roughly aligned for a reflected
    # (sign-flipped) row to land in the trim zone; under extreme
    # non-IID skew the honest spread swallows the attack and the rules
    # lose signal without gaining robustness (documented in DESIGN.md
    # §14.2).  The fault axis composes with any target_hd — this sweep
    # measures the defenses where they are meant to operate.
    return FLConfig(
        n_clients=n_clients, m=m, rounds=rounds, seed=seed,
        strategy=strategy,
        strategy_kwargs=dict(STRATEGY_KWARGS.get(strategy, {})),
        hidden=(32,) if smoke else (256,),
        local_epochs=1 if smoke else 5,
        lr=0.005 if smoke else 0.05,
        eval_samples=16 if smoke else 500,
        eval_every=2 if smoke else 5,
        target_hd=0.8 if smoke else 0.1,
        aggregator=aggregator,
        aggregator_kwargs=dict(aggregator_kwargs or {}),
        faults=faults,
    )


def _run(cfg, data):
    """Run one cell; walltime excludes the first round (jit warmup)."""
    from repro.engine import make_engine

    train, test = data
    engine = make_engine(cfg, train, test, n_classes=10)
    it = engine.rounds()
    results = [next(it)]
    t0 = time.perf_counter()
    results.extend(it)
    steady_s = (time.perf_counter() - t0) / max(len(results) - 1, 1)
    return engine, results, steady_s


def _overhead(mk_baseline, mk_defended, data, repeats: int,
              chunk: int = 2) -> tuple[float, float, float]:
    """Steady-state per-round overhead of the defended rate-0 engine over
    ``faults=None``.  A 2% budget is far below the run-to-run drift of a
    shared box, so whole-run timings (even interleaved) cannot resolve
    it; instead both engines run live side by side, alternating
    ``chunk``-round slices, and the overhead is the *median of per-chunk
    time ratios* — thermal / scheduler drift hits temporally adjacent
    chunks of both arms alike and cancels in the ratio.  The arm order
    flips every chunk so within-pair drift (turbo decay, cache warmth)
    does not systematically bias the second arm.  Returns
    ``(baseline_s_per_round, defended_s_per_round, median_ratio)``."""
    import numpy as np

    from repro.engine import make_engine

    train, test = data
    ratios, base_ts, def_ts = [], [], []
    for _ in range(max(repeats, 1)):
        arms = []
        for mk in (mk_baseline, mk_defended):
            engine = make_engine(mk(), train, test, n_classes=10)
            for _r in engine.rounds(1):  # jit warmup round
                pass
            arms.append(engine)
        remaining = arms[0].cfg.rounds - 1
        for c in range(remaining // chunk):
            ts = [0.0, 0.0]
            order = (0, 1) if c % 2 == 0 else (1, 0)
            for arm in order:
                t0 = time.perf_counter()
                for _r in arms[arm].rounds(chunk):
                    pass
                ts[arm] = time.perf_counter() - t0
            ratios.append(ts[1] / ts[0])
            base_ts.append(ts[0] / chunk)
            def_ts.append(ts[1] / chunk)
    return (
        float(np.median(base_ts)),
        float(np.median(def_ts)),
        float(np.median(ratios)),
    )


def main(args) -> dict:
    from repro.data import make_classification

    n = 1_200 if args.smoke else 20_000
    data = (
        make_classification(n, n_features=64, n_classes=10, seed=0),
        make_classification(max(n // 5, 200), n_features=64, n_classes=10,
                            seed=1),
    )
    run_kw = dict(smoke=args.smoke, rounds=args.rounds,
                  n_clients=args.n_clients, m=args.m, seed=args.seed)

    rows = []
    baseline_acc: dict[str, float] = {}
    baseline_s: dict[str, float] = {}
    for strategy in args.strategies:
        _, results, per_round_s = _run(_cfg(strategy, **run_kw), data)
        evald = [r for r in results if r.test_acc is not None]
        baseline_acc[strategy] = evald[-1].test_acc
        baseline_s[strategy] = per_round_s
        rows.append({
            "strategy": strategy,
            "scenario": "faults_none",
            "rate": None,
            "defense": None,
            "aggregator": "fedavg",
            "final_acc": round(evald[-1].test_acc, 4),
            "best_acc": round(max(r.test_acc for r in evald), 4),
            "recovery": 1.0,
            "steady_s_per_round": round(per_round_s, 5),
            "total_faulty": 0,
            "max_quarantined": 0,
        })
        print(f"[robust] {strategy:<8s} faults=None              "
              f"acc={rows[-1]['final_acc']:.3f} "
              f"{per_round_s * 1e3:7.1f} ms/round", flush=True)

        for rate in args.rates:
            for label, (defense, aggregator, agg_kw) in DEFENSES.items():
                faults = dict(rate=rate, models=list(FAULT_MODELS),
                              defense=defense)
                _, results, cell_s = _run(
                    _cfg(strategy, faults=faults, aggregator=aggregator,
                         aggregator_kwargs=agg_kw, **run_kw),
                    data,
                )
                evald = [r for r in results if r.test_acc is not None]
                acc = evald[-1].test_acc
                rows.append({
                    "strategy": strategy,
                    "scenario": f"rate{rate:g}_{label}",
                    "rate": rate,
                    "defense": label,
                    "aggregator": aggregator,
                    "final_acc": round(acc, 4),
                    "best_acc": round(max(r.test_acc for r in evald), 4),
                    "recovery": round(acc / baseline_acc[strategy], 4),
                    "steady_s_per_round": round(cell_s, 5),
                    "total_faulty": sum(r.n_faulty for r in results),
                    "max_quarantined": max(r.n_quarantined for r in results),
                })
                print(f"[robust] {strategy:<8s} rate={rate:<4g} "
                      f"{label:<22s} acc={rows[-1]['final_acc']:.3f} "
                      f"rec={rows[-1]['recovery']:.3f} "
                      f"faulty={rows[-1]['total_faulty']}", flush=True)

    def _cell(strategy, rate, defense):
        for row in rows:
            if (row["strategy"] == strategy and row["rate"] == rate
                    and row["defense"] == defense):
                return row
        return None

    summary = []
    timing_kw = dict(run_kw)
    if not args.smoke:
        timing_kw["rounds"] = max(args.rounds, TIMING_ROUNDS)
    for strategy in args.strategies:
        attacked = _cell(strategy, 0.2, "none")
        defended = _cell(strategy, 0.2, "validate+trimmed_mean")
        base_s, defended_s, ratio = _overhead(
            lambda s=strategy: _cfg(s, **timing_kw),
            lambda s=strategy: _cfg(
                s, faults={"rate": 0.0, "models": list(FAULT_MODELS),
                           "defense": "validate"},
                **timing_kw,
            ),
            data, TIMING_REPEATS,
        )
        overhead = ratio - 1.0
        summary.append({
            "strategy": strategy,
            "baseline_acc": round(baseline_acc[strategy], 4),
            "attacked_recovery": attacked["recovery"],
            "defended_recovery": defended["recovery"],
            "baseline_s_per_round": round(base_s, 5),
            "rate0_defended_s_per_round": round(defended_s, 5),
            "rate0_defended_overhead": round(overhead, 4),
        })
        print(f"[robust] {strategy:<8s} 20% sign_flip: undefended "
              f"rec={attacked['recovery']:.3f} -> defended "
              f"rec={defended['recovery']:.3f}; rate-0 overhead "
              f"{overhead * 100:+.1f}%", flush=True)

    # ISSUE acceptance bars are stated for fedlecc on the classification
    # task; other strategies' rows are informational.  (The optimistic
    # aggregation overlaps the gate's host sync with the aggregation
    # dispatch, leaving fedlecc at ~1.5%; leaner strategies with less
    # per-round host work to hide the gate behind (random) still show
    # ~3% — DESIGN.md §14.2.)
    accept = next((s for s in summary if s["strategy"] == "fedlecc"),
                  summary[0])
    acceptance = {
        "strategy": accept["strategy"],
        "recovery_bar_ge_0.9": accept["defended_recovery"] >= 0.9,
        "overhead_bar_le_0.02": accept["rate0_defended_overhead"] <= 0.02,
    }
    print(f"[robust] acceptance ({acceptance['strategy']}): "
          f"recovery>=0.9 {acceptance['recovery_bar_ge_0.9']}, "
          f"overhead<=2% {acceptance['overhead_bar_le_0.02']}", flush=True)

    import jax

    payload = {
        "benchmark": "bench_robustness",
        "smoke": args.smoke,
        "jax": jax.__version__,
        "device": str(jax.devices()[0].platform),
        "fault_models": list(FAULT_MODELS),
        "rates": list(args.rates),
        "defenses": list(DEFENSES),
        "results": rows,
        "summary": summary,
        "acceptance": acceptance,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {args.out}")
    return payload


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--strategies", nargs="+", default=list(STRATEGIES),
                   choices=STRATEGIES)
    p.add_argument("--rates", nargs="+", type=float, default=list(RATES))
    p.add_argument("--rounds", type=int, default=None)
    p.add_argument("--n-clients", type=int, default=40)
    p.add_argument("--m", type=int, default=20)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI config: 12 clients, small model/data — "
                        "trajectory tracking, not absolute numbers")
    p.add_argument("--out", default=BENCH_JSON)
    args = p.parse_args(argv)
    if args.smoke:
        args.n_clients, args.m = 12, 4
        args.rounds = args.rounds or 8
    else:
        args.rounds = args.rounds or 60
    return args


if __name__ == "__main__":
    main(_parse_args())
