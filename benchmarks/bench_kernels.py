"""Kernel-substrate micro-benchmarks (CPU reference timings of the jit'd
pure-JAX twins; the Pallas kernels themselves are TPU-target and are
validated, not timed, on this container)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hellinger import hellinger_matrix
from repro.federated.aggregation import fedavg
from repro.models.attention import flash_attention


def _time(fn, reps=10):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def main(full: bool = False) -> list[tuple]:
    rng = np.random.default_rng(0)
    rows = []

    h = jnp.asarray(rng.dirichlet(np.ones(10) * 0.1, size=256))
    f = jax.jit(hellinger_matrix)
    rows.append(("kernel/hellinger_jnp_256x10",
                 round(_time(lambda: jax.block_until_ready(f(h))), 1),
                 "256x256 HD matrix"))

    b, s, hh, d = 1, 1024, 4, 64
    q = jnp.asarray(rng.normal(0, 1, (b, s, hh, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, hh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, hh, d)), jnp.float32)
    fa = jax.jit(lambda q, k, v: flash_attention(q, k, v, chunk_q=256, chunk_k=256))
    flops = 4 * b * hh * s * s * d
    us = _time(lambda: jax.block_until_ready(fa(q, k, v)), reps=5)
    rows.append(("kernel/flash_attention_1k",
                 round(us, 1), f"gflops={flops / us / 1e3:.2f}"))

    stacked = {"w": jnp.asarray(rng.normal(0, 1, (10, 200, 1000)), jnp.float32)}
    w = jnp.asarray(rng.uniform(0, 1, 10), jnp.float32)
    w = w / w.sum()
    ag = jax.jit(fedavg)
    us = _time(lambda: jax.block_until_ready(ag(stacked, w)["w"]))
    mb = 10 * 200 * 1000 * 4 / 1e6
    rows.append(("kernel/fedavg_reduce_2M",
                 round(us, 1), f"gbps={mb / us * 1e3 / 1e3:.2f}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
