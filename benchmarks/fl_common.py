"""Shared FL-experiment runner for the paper-table benchmarks.

Runs each (method × seed) cell once and caches the full history in
results/fl_runs.json so Table II / Table III / Fig 3 benchmarks share one
set of simulations (exactly how the paper derives all three artifacts
from the same runs).

Methods are the registered ``ExperimentPreset``s (``repro.engine.presets``)
— one named (strategy × client_mode × aggregator) cell each — and every
cell runs through ``repro.engine.make_engine``, so the benchmarks, the
examples, and ad-hoc scripts all exercise the same engine API.  Each
cached record embeds ``cfg`` (``FLConfig.to_dict()``) so a cell is fully
reproducible from the cache alone via ``FLConfig.from_dict``.
"""

from __future__ import annotations

import json
import os
import time

from repro.data import make_classification
from repro.engine import make_engine
from repro.engine.presets import get_preset, list_presets

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "fl_runs.json")

# Bump whenever the simulator's numerics change so stale cached cells are
# re-run instead of silently mixed with new ones.  2 = engine API PR:
# per-client PRNG keys moved from cohort split to fold_in-by-client-index.
# 3 = systems PR: the `random` strategy's draw moved from rng.choice to
# host-drawn uniform scores (jit-maskable), changing its selection
# sequence for a given seed (uniformity unchanged).
CACHE_VERSION = 3

# Deprecated compat views over the preset registry, preserving the old
# METHODS value shape — name → (strategy, client_mode, aggregator, mu,
# strategy_kwargs) — so legacy tuple-unpacking consumers keep working;
# new code should use methods_for()/get_preset() directly.
METHODS = {
    name: (p.strategy, p.client_mode, p.aggregator, p.mu,
           dict(p.strategy_kwargs))
    for name, p in ((n, get_preset(n)) for n in list_presets())
}
FAST_METHODS = list_presets(fast_only=True)


def methods_for(full: bool) -> list[str]:
    """Benchmark method set: every registered preset, or the fast subset."""
    return list_presets() if full else list_presets(fast_only=True)


def _cell_data(cfg, data_seed: int):
    """Task-appropriate (train, test, n_classes) for one benchmark cell:
    Gaussian-mixture images for classification, Markov token streams for
    the LM task (vocab taken from the preset's task model config)."""
    if cfg.task == "lm":
        from repro.data.synthetic import make_token_stream
        from repro.engine.tasks import build_task

        vocab = build_task(cfg).model_cfg.vocab
        train = make_token_stream(24 * cfg.n_clients, 64, vocab, seed=data_seed)
        test = make_token_stream(64, 64, vocab, seed=data_seed + 1)
        return train, test, vocab
    train = make_classification(20_000, seed=data_seed)
    test = make_classification(2_000, seed=data_seed + 1)
    return train, test, 10


def run_cell(method: str, seed: int, rounds: int, n_clients: int = 100,
             m: int = 10, data_seed: int = 0) -> dict:
    cfg = get_preset(method).make_config(
        n_clients=n_clients, m=m, rounds=rounds, seed=seed,
        target_hd=0.9, eval_every=5,
    )
    train, test, n_classes = _cell_data(cfg, data_seed)
    engine = make_engine(cfg, train, test, n_classes=n_classes)
    t0 = time.time()
    hist = engine.run()
    return {
        "method": method, "seed": seed, "rounds": rounds,
        "n_clients": n_clients, "m": m,
        "cache_version": CACHE_VERSION,
        "cfg": cfg.to_dict(),
        "alpha": engine.alpha,
        "n_params": engine.n_params,
        "wall_s": round(time.time() - t0, 1),
        "needs_losses": engine.strategy.needs_losses,
        "needs_histograms": engine.strategy.needs_histograms,
        "history": hist,
    }


def load_runs() -> list[dict]:
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            return json.load(f)
    return []


def save_runs(runs: list[dict]) -> None:
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(runs, f)


def ensure_runs(methods: list[str], seeds: list[int], rounds: int,
                m: int = 10, verbose: bool = True) -> list[dict]:
    runs = load_runs()
    # drop cells from an older simulator version — numerically incomparable
    stale = [r for r in runs if r.get("cache_version") != CACHE_VERSION]
    if stale:
        print(f"# dropping {len(stale)} cached cells from an older "
              f"simulator version (cache_version != {CACHE_VERSION})",
              flush=True)
        runs = [r for r in runs if r.get("cache_version") == CACHE_VERSION]
    have = {(r["method"], r["seed"], r["rounds"], r.get("m", 10)) for r in runs}
    for method in methods:
        for seed in seeds:
            if (method, seed, rounds, m) in have:
                continue
            if verbose:
                print(f"# running {method} seed={seed} rounds={rounds} m={m} ...",
                      flush=True)
            runs.append(run_cell(method, seed, rounds, m=m))
            save_runs(runs)
    return [r for r in runs if r["method"] in methods and r["seed"] in seeds
            and r["rounds"] == rounds and r.get("m", 10) == m]
