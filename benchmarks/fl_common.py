"""Shared FL-experiment runner for the paper-table benchmarks.

Runs each (strategy × seed) cell once and caches the full history in
results/fl_runs.json so Table II / Table III / Fig 3 benchmarks share one
set of simulations (exactly how the paper derives all three artifacts
from the same runs).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.data import make_classification
from repro.federated import FLConfig, FederatedSimulation

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "fl_runs.json")

# method name → (strategy, client_mode, aggregator, mu, strategy_kwargs)
METHODS = {
    "fedavg": ("random", "plain", "fedavg", 0.0, {}),
    "fedprox": ("random", "fedprox", "fedavg", 0.01, {}),
    "fednova": ("random", "plain", "fednova", 0.0, {}),
    "feddyn": ("random", "feddyn", "feddyn", 0.1, {}),
    "haccs": ("haccs", "plain", "fedavg", 0.0, {}),
    "fedcls": ("fedcls", "plain", "fedavg", 0.0, {}),
    "fedcor": ("fedcor", "plain", "fedavg", 0.0, {}),
    "poc": ("poc", "plain", "fedavg", 0.0, {}),
    # J=10 (z=1: one client per label-mode cluster) is the tuned setting on
    # the shards partition (J sweep in EXPERIMENTS §Claims; the paper's §VII
    # sensitivity caveat reproduced: J=5 froze on a degenerate partition)
    "fedlecc": ("fedlecc", "plain", "fedavg", 0.0, {"J": 10}),
    # beyond-paper: adaptive J (the paper's stated future work)
    "fedlecc_adaptive": ("fedlecc_adaptive", "plain", "fedavg", 0.0, {}),
}

FAST_METHODS = ["fedavg", "poc", "fedlecc"]


def run_cell(method: str, seed: int, rounds: int, n_clients: int = 100,
             m: int = 10, data_seed: int = 0) -> dict:
    train = make_classification(20_000, seed=data_seed)
    test = make_classification(2_000, seed=data_seed + 1)
    strategy, mode, agg, mu, skw = METHODS[method]
    cfg = FLConfig(
        n_clients=n_clients, m=m, rounds=rounds, seed=seed, strategy=strategy,
        client_mode=mode, aggregator=agg, mu=mu, strategy_kwargs=skw,
        target_hd=0.9, eval_every=5,
    )
    sim = FederatedSimulation(cfg, train, test, n_classes=10)
    t0 = time.time()
    hist = sim.run()
    return {
        "method": method, "seed": seed, "rounds": rounds,
        "n_clients": n_clients, "m": m,
        "alpha": sim.alpha,
        "n_params": sim.n_params,
        "wall_s": round(time.time() - t0, 1),
        "needs_losses": sim.strategy.needs_losses,
        "needs_histograms": sim.strategy.needs_histograms,
        "history": hist,
    }


def load_runs() -> list[dict]:
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            return json.load(f)
    return []


def save_runs(runs: list[dict]) -> None:
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(runs, f)


def ensure_runs(methods: list[str], seeds: list[int], rounds: int,
                m: int = 10, verbose: bool = True) -> list[dict]:
    runs = load_runs()
    have = {(r["method"], r["seed"], r["rounds"], r.get("m", 10)) for r in runs}
    for method in methods:
        for seed in seeds:
            if (method, seed, rounds, m) in have:
                continue
            if verbose:
                print(f"# running {method} seed={seed} rounds={rounds} m={m} ...",
                      flush=True)
            runs.append(run_cell(method, seed, rounds, m=m))
            save_runs(runs)
    return [r for r in runs if r["method"] in methods and r["seed"] in seeds
            and r["rounds"] == rounds and r.get("m", 10) == m]
