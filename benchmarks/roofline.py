"""Roofline analysis (EXPERIMENTS.md §Roofline) from results/dryrun.jsonl.

Per (arch × shape) on the single-pod mesh (256 chips):

  compute term    = HLO_FLOPs / (chips × 197e12 FLOP/s bf16)
  memory term     = HLO_bytes / (chips × 819e9 B/s HBM)
  collective term = collective_bytes × ring_factor / (chips × 50e9 B/s link)

HLO_FLOPs / HLO_bytes / collective_bytes are the loop-corrected totals
from the probe lowers (dryrun.probe_costs; XLA cost_analysis counts scan
bodies once, so the production scan lowering under-reports — see the
methodology note in EXPERIMENTS.md §Dry-run).  cost_analysis numbers are
per-device executables, so terms are per-chip directly (no ÷chips).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens processed.
"""

from __future__ import annotations

import json
import os

import numpy as np

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
RING = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
        "all-to-all": 1.0, "collective-permute": 1.0}

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.jsonl")

# active params (N) per arch; tokens per shape computed from the shape
N_PARAMS = {
    "deepseek-v3-671b": 37e9,   # active (671B total, top-8+shared of 256)
    "glm4-9b": 9e9,
    "hymba-1.5b": 1.5e9,
    "stablelm-3b": 3e9,
    "musicgen-large": 1.5e9,
    "internvl2-1b": 0.8e9,
    "dbrx-132b": 36e9,          # active (132B total, top-4 of 16)
    "xlstm-125m": 0.125e9,
    "qwen3-14b": 14e9,
    "gemma3-27b": 27e9,
}
TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
          "decode_32k": 128, "long_500k": 1}


def load(path: str = RESULTS) -> list[dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return rows


def analyze(rec: dict) -> dict | None:
    if rec.get("error") or rec.get("mesh") != "single":
        return None
    probes = rec.get("probes") or {}
    total = probes.get("total") if isinstance(probes, dict) else None
    if not total:  # fall back to raw (under-reported) numbers, flagged
        total = {"flops": rec["flops"], "bytes": rec["bytes_accessed"],
                 "coll": rec["collective_bytes"]}
        corrected = False
    else:
        corrected = True
    t_comp = total["flops"] / PEAK_FLOPS
    t_mem = total["bytes"] / HBM_BW
    coll_line = sum(RING.get(k, 1.0) * v for k, v in total["coll"].items())
    t_coll = coll_line / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])[0]
    n_dev = rec.get("n_devices", 256)
    # 6·N·D for training (fwd 2ND + bwd 4ND); 2·N·D inference-only
    mult = 6.0 if rec["kind"] == "train" else 2.0
    model_flops = mult * N_PARAMS[rec["arch"]] * TOKENS[rec["shape"]] / n_dev
    ratio = model_flops / total["flops"] if total["flops"] else float("nan")
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_dev": model_flops,
        "hlo_flops_per_dev": total["flops"],
        "useful_ratio": ratio,
        "corrected": corrected,
        "mem_temp_gb": rec["memory"]["temp_size"] / 2**30,
        "mem_args_gb": rec["memory"]["argument_size"] / 2**30,
    }


def table(rows: list[dict]) -> str:
    hdr = (f"| {'arch':22s} | {'shape':11s} | {'compute s':>10s} | "
           f"{'memory s':>10s} | {'collect s':>10s} | {'bound':>10s} | "
           f"{'useful':>7s} | {'args GB':>8s} |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']:22s} | {r['shape']:11s} | {r['t_compute_s']:10.3e} | "
            f"{r['t_memory_s']:10.3e} | {r['t_collective_s']:10.3e} | "
            f"{r['dominant']:>10s} | {r['useful_ratio']:7.2f} | "
            f"{r['mem_args_gb']:8.2f} |"
        )
    return "\n".join(out)


def main(full: bool = False) -> list[tuple]:
    recs = load()
    rows = [a for a in (analyze(r) for r in recs) if a]
    # de-dup (keep last per arch×shape)
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"])] = r
    rows = sorted(seen.values(), key=lambda r: (r["shape"], r["arch"]))
    out = []
    for r in rows:
        t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        out.append(
            (
                f"roofline/{r['arch']}/{r['shape']}",
                round(t_dom * 1e6, 1),     # dominant-term us per step
                f"bound={r['dominant']};useful={r['useful_ratio']:.2f};"
                f"comp={r['t_compute_s']:.2e};mem={r['t_memory_s']:.2e};"
                f"coll={r['t_collective_s']:.2e}",
            )
        )
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(os.path.join(os.path.dirname(RESULTS), "roofline.md"), "w") as f:
        f.write(table(rows) + "\n")
    return out


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
