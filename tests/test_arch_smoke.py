"""Per-architecture smoke tests (deliverable f): every assigned arch in a
REDUCED variant (2 layers, d_model ≤ 512, ≤ 4 experts) runs one forward +
one train step + prefill/decode on CPU with finite outputs and correct
shapes.  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.configs.inputs import dummy_batch, dummy_decode_batch
from repro.models.transformer import (
    decode_step, forward, init_transformer, loss_fn, prefill, transformer_specs,
)

ARCHS = list_configs()
B, S = 2, 64


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCHS:
        cfg = get_config(name, reduced=True)
        out[name] = (cfg, init_transformer(jax.random.PRNGKey(0), cfg))
    return out


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    fams = {get_config(a).family for a in ARCHS}
    assert fams == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_constraints(name):
    cfg = get_config(name, reduced=True)
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_specs_structure_matches_params(name, built):
    cfg, params = built[name]
    specs = transformer_specs(cfg)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple)
    )


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_train_step(name, built):
    cfg, params = built[name]
    batch = dummy_batch(cfg, B, S, seed=0)
    h, mask, aux, _ = forward(params, cfg, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch
    )
    assert np.isfinite(float(loss))
    new = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2, _ = loss_fn(new, cfg, batch)
    assert np.isfinite(float(loss2))
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert gn > 0  # gradient actually flows


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_shapes(name, built):
    cfg, params = built[name]
    batch = dummy_batch(cfg, B, S, seed=1)
    batch.pop("labels")
    logits, cache = prefill(params, cfg, batch, max_len=S + 4)
    assert logits.shape == (B, cfg.vocab)
    db = dummy_decode_batch(cfg, B)
    logits2, cache2 = decode_step(params, cfg, db, cache, jnp.int32(S))
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize(
    "name",
    ["qwen3-14b", "gemma3-27b", "deepseek-v3-671b", "hymba-1.5b", "xlstm-125m",
     "musicgen-large", "internvl2-1b"],
)
def test_prefill_decode_matches_forward(name, built):
    """decode(prefill(x[:-1]), x[-1]) ≡ forward(x) at the last position."""
    cfg, params = built[name]
    batch = dummy_batch(cfg, B, S, seed=2)
    fb = {k: v for k, v in batch.items() if k != "labels"}
    from repro.models.transformer import _logits

    h_full, _, _, _ = forward(params, cfg, fb)
    want = _logits(params, cfg, h_full[:, -1])
    if cfg.input_mode == "tokens":
        fb_pre = {"tokens": fb["tokens"][:, :-1]}
        db = {"token": fb["tokens"][:, -1:]}
    elif cfg.input_mode == "frames":
        fb_pre = {"frames": fb["frames"][:, :-1]}
        db = {"frame": fb["frames"][:, -1:]}
    else:
        fb_pre = {"patches": fb["patches"], "tokens": fb["tokens"][:, :-1]}
        db = {"token": fb["tokens"][:, -1:]}
    _, cache = prefill(params, cfg, fb_pre, max_len=S + 4)
    got, _ = decode_step(params, cfg, db, cache, jnp.int32(S - 1))
    scale = float(jnp.max(jnp.abs(want))) + 1.0
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=2e-2 * scale,
    )
