"""repro.engine: registries, FLConfig validation/round-trip, the typed
round protocol, and host ↔ compiled backend equivalence."""

import dataclasses

import numpy as np
import pytest

from conftest import fl_cfg as _cfg, lm_fl_cfg as _lm_cfg
from repro.engine import (
    FLConfig,
    Registry,
    RoundResult,
    make_engine,
    list_aggregators,
    list_client_modes,
    list_strategies,
    list_tasks,
)
from repro.engine.aggregators import get_aggregator
from repro.engine.presets import get_preset, list_presets


# ---------------------------------------------------------------- registry
def test_registries_populated():
    assert "fedlecc" in list_strategies() and "random" in list_strategies()
    assert list_aggregators() == [
        "coordinate_median", "fedavg", "feddyn", "fednova", "trimmed_mean",
    ]
    assert list_client_modes() == ["feddyn", "fedprox", "plain"]
    assert list_tasks() == ["classification", "lm"]


def test_custom_registration_does_not_hide_builtins():
    # registering a custom component must not short-circuit provider
    # population (regression: the populate gate was "items non-empty",
    # so a custom-first registration hid every built-in)
    from repro.engine.registry import STRATEGY_REGISTRY, register_strategy

    @register_strategy("_test_custom")
    class Custom:
        pass

    try:
        names = list_strategies()
        assert "_test_custom" in names and "fedlecc" in names
    finally:
        del STRATEGY_REGISTRY["_test_custom"]  # legacy dict-style del
    # the gate is an explicit flag, not an item-count check
    reg = Registry("widget-" + "x")
    reg.register("mine")(Custom)
    assert reg.names() == ["mine"] and reg._populated


def test_same_component_reregistration_allowed():
    reg = Registry("widget")

    def make():
        @reg.register("a")
        class A:
            pass

        return A

    first, second = make(), make()  # same qualname/module, new class objects
    assert reg["a"] is second  # reload-style overwrite, no ValueError


def test_registry_decorator_and_errors():
    reg = Registry("widget")

    @reg.register("a")
    class A:
        pass

    assert reg["a"] is A and "a" in reg and len(reg) == 1
    with pytest.raises(ValueError, match="duplicate"):
        reg.register("a")(int)
    with pytest.raises(KeyError, match="unknown widget 'b'"):
        reg["b"]
    assert reg.build("a").__class__ is A


# ------------------------------------------------------------------ config
def test_flconfig_validation():
    with pytest.raises(ValueError, match="backend"):
        _cfg(backend="gpu")
    with pytest.raises(ValueError, match="unknown strategy"):
        _cfg(strategy="nope")
    with pytest.raises(ValueError, match="unknown aggregator"):
        _cfg(aggregator="nope")
    with pytest.raises(ValueError, match="unknown client_mode"):
        _cfg(client_mode="nope")
    with pytest.raises(ValueError, match="unknown task"):
        _cfg(task="vision")
    with pytest.raises(ValueError, match="task_kwargs must be a dict"):
        _cfg(task_kwargs=[1, 2])
    with pytest.raises(ValueError, match="m must be"):
        _cfg(m=50)  # > n_clients
    with pytest.raises(ValueError, match="partition"):
        _cfg(partition="iid")


def test_flconfig_dict_round_trip():
    cfg = _cfg(backend="compiled", alpha_dirichlet=0.3, hidden=(32, 16))
    d = cfg.to_dict()
    assert d["hidden"] == [32, 16]  # JSON-safe
    import json

    restored = FLConfig.from_dict(json.loads(json.dumps(d)))
    assert restored == cfg
    assert restored.hidden == (32, 16)
    with pytest.raises(ValueError, match="unknown FLConfig keys"):
        FLConfig.from_dict({**d, "bogus": 1})


def test_flconfig_lm_task_round_trip():
    """task / task_kwargs (nested dicts) survive the JSON round-trip."""
    import json

    cfg = _lm_cfg(backend="scaleout")
    assert cfg.task == "lm"
    d = cfg.to_dict()
    restored = FLConfig.from_dict(json.loads(json.dumps(d)))
    assert restored == cfg
    assert restored.task_kwargs["overrides"]["d_model"] == 32


# ----------------------------------------------------------------- presets
def test_presets_build_configs():
    assert "fedlecc" in list_presets()
    assert set(list_presets(fast_only=True)) == {"fedavg", "poc", "fedlecc"}
    p = get_preset("feddyn")
    cfg = p.make_config(n_clients=12, m=4, rounds=2, hidden=(16,))
    assert cfg.aggregator == "feddyn" and cfg.client_mode == "feddyn"
    assert cfg.mu == pytest.approx(0.1)
    # overrides win
    assert get_preset("fedlecc").make_config(
        n_clients=12, m=4, strategy_kwargs={"J": 2}
    ).strategy_kwargs == {"J": 2}


# ---------------------------------------------------- typed round protocol
def test_rounds_stream_and_callback(data):
    train, test = data
    engine = make_engine(_cfg(eval_every=2), train, test, n_classes=10)
    seen = []
    results = list(engine.rounds(3, callback=seen.append))
    assert [r.round for r in results] == [0, 1, 2]
    assert results == seen
    for r in results:
        assert isinstance(r, RoundResult)
        assert len(r.selected) == 4 and len(set(r.selected)) == 4
        assert np.isfinite(r.mean_selected_loss)
        with pytest.raises(dataclasses.FrozenInstanceError):
            r.round = 99
    # eval_every=2 over 3 rounds: rounds 0, 2 evaluated (2 also last)
    assert [r.evaluated for r in results] == [True, False, True]
    assert results[1].test_acc is None
    # the ledger is monotone and matches the engine's running total
    assert results[-1].comm_mb == pytest.approx(engine.comm_mb)


def test_chunked_rounds_keep_absolute_eval_cadence(data):
    """rounds(5)+rounds(5) must evaluate on the *identical* absolute
    schedule as rounds(10): the cadence plus the configured terminal
    round, never a chunk's own last round (DESIGN.md §12 — resumed runs
    must reproduce contiguous histories exactly)."""
    train, test = data

    def evaluated_rounds(chunks):
        engine = make_engine(_cfg(rounds=10, eval_every=5), train, test,
                             n_classes=10)
        out = []
        for c in chunks:
            out += [r.round for r in engine.rounds(c) if r.evaluated]
        return out, engine

    contiguous, e1 = evaluated_rounds([10])
    chunked, e2 = evaluated_rounds([5, 5])
    assert contiguous == [0, 5, 9]
    assert chunked == contiguous  # no per-call final-round force-eval
    # and the training trajectory itself is identical
    import jax
    import jax.numpy as jnp

    err = max(
        float(jnp.max(jnp.abs(x - y)))
        for x, y in zip(jax.tree.leaves(e1.params), jax.tree.leaves(e2.params))
    )
    assert err == 0.0


def test_run_history_matches_legacy_shape(data):
    train, test = data
    engine = make_engine(_cfg(), train, test, n_classes=10)
    h = engine.run()
    assert sorted(h) == ["comm_mb", "mean_selected_loss", "round",
                         "selected", "test_acc", "test_loss"]
    assert h["round"] == [0, 1, 2]
    assert all(len(s) == 4 for s in h["selected"])


def test_feddyn_state_lives_in_aggregator(data):
    train, test = data
    cfg = _cfg(strategy="random", aggregator="feddyn", client_mode="feddyn",
               mu=0.1, rounds=2)
    engine = make_engine(cfg, train, test, n_classes=10)
    assert engine.aggregator.needs_state and engine.agg_state is not None
    assert engine.client_mode.needs_h and engine.h_clients is not None
    import jax

    before = jax.tree.leaves(engine.agg_state)[0].copy()
    list(engine.rounds(2))
    after = jax.tree.leaves(engine.agg_state)[0]
    assert float(np.abs(np.asarray(after - before)).max()) > 0  # h moved


def test_aggregator_objects_standalone(data):
    cfg = _cfg(strategy="random", aggregator="fedavg")
    agg = get_aggregator("fedavg", cfg)
    assert agg.init_state(None) is None and not agg.needs_state


# ----------------------------------------------------- task-axis engine
# Golden values captured from the pre-task-axis engine (commit 3dcf2ea)
# for the canonical tiny config: the default task="classification" path
# must reproduce them exactly — the Task refactor is a pure re-plumbing.
_GOLDEN_SELECTED = [(0, 2, 4, 5), (4, 5, 9, 10), (5, 7, 9, 10)]
_GOLDEN_W0_ROW0 = [0.07630947977304459, -0.2940053939819336,
                   -0.06507953256368637, -0.21803271770477295]


def test_default_task_matches_pre_refactor_golden(data):
    """Same selections and final params (one seed) as before the Task
    registry axis existed — the default config is a zero-behavior-change
    refactor."""
    import jax

    train, test = data
    engine = make_engine(_cfg(), train, test, n_classes=10)
    results = list(engine.rounds(3))
    assert [r.selected for r in results] == _GOLDEN_SELECTED
    w0 = next(np.asarray(x) for x in jax.tree.leaves(engine.params)
              if np.asarray(x).ndim == 2)
    np.testing.assert_allclose(w0[0, :4], _GOLDEN_W0_ROW0, atol=1e-6)


def test_task_owns_clustering_features(data, lm_data):
    """classification clusters on (K, n_classes) label histograms; lm
    clusters on (K, hist_bins) token histograms — both row-normalized."""
    train, test = data
    eng = make_engine(_cfg(), train, test, n_classes=10)
    assert eng.hists.shape == (12, 10)
    lm_train, lm_test = lm_data
    lm_eng = make_engine(_lm_cfg(), lm_train, lm_test, n_classes=32)
    assert lm_eng.hists.shape == (8, 16)  # hist_bins=16 in the tiny cfg
    for h in (eng.hists, lm_eng.hists):
        np.testing.assert_allclose(h.sum(axis=1), 1.0, atol=1e-9)


def test_lm_task_rejects_non_token_models():
    """Modality stubs and the MTP head are not wired into the federated
    loss — the task must fail at construction, not mid-round."""
    with pytest.raises(ValueError, match="input_mode"):
        _lm_cfg(task_kwargs={"model": "stablelm-3b",
                             "overrides": {"input_mode": "frames"}})
    with pytest.raises(ValueError, match="MTP"):
        _lm_cfg(task_kwargs={"model": "stablelm-3b",
                             "overrides": {"mtp": True}})
    # unknown model names / bad kwargs surface as ValueError, keeping
    # the fail-with-ValueError-at-construction contract
    with pytest.raises(ValueError, match="invalid task_kwargs"):
        _lm_cfg(task_kwargs={"model": "nope"})
    with pytest.raises(ValueError, match="invalid task_kwargs"):
        _lm_cfg(task_kwargs={"bogus_kwarg": 1})


def test_partition_labels_override(data):
    """The make_engine task-data override: a caller-provided label axis
    drives the non-IID split instead of the task's derived labels."""
    train, test = data
    default = make_engine(_cfg(), train, test, n_classes=10)
    override = make_engine(_cfg(), train, test, n_classes=10,
                           partition_labels=np.asarray(train.y))
    for a, b in zip(default.client_idx, override.client_idx):
        np.testing.assert_array_equal(a, b)  # same labels → same split
    with pytest.raises(ValueError, match="partition_labels"):
        make_engine(_cfg(), train, test, n_classes=10,
                    partition_labels=np.zeros(3, np.int64))


# ------------------------------------------------- cross-backend parity
def test_backend_masks_identical_for_same_losses(data):
    """HostEngine and CompiledEngine must select the same participation
    set for fedlecc given the same labels/losses (engine-level extension
    of the fedlecc_select ↔ fedlecc_select_jax property)."""
    train, test = data
    host = make_engine(_cfg(backend="host"), train, test, n_classes=10)
    comp = make_engine(_cfg(backend="compiled"), train, test, n_classes=10)
    np.testing.assert_array_equal(host.strategy.labels, comp.strategy.labels)
    rng = np.random.default_rng(3)
    for rnd in range(4):
        losses = rng.uniform(0.1, 5.0, 12).astype(np.float32)
        np.testing.assert_array_equal(
            host.select(rnd, losses), comp.select(rnd, losses)
        )


def test_backends_run_fedlecc_end_to_end_equivalently(data):
    """Both backends run >=2 full fedlecc rounds; per-client fold_in keys
    + exact-zero gating make the compiled round numerically match the
    host round (selections identical, params equal to f32 tolerance)."""
    import jax
    import jax.numpy as jnp

    train, test = data
    host = make_engine(_cfg(backend="host"), train, test, n_classes=10)
    comp = make_engine(_cfg(backend="compiled"), train, test, n_classes=10)
    rh = list(host.rounds(3))
    rc = list(comp.rounds(3))
    assert len(rh) == len(rc) == 3
    for a, b in zip(rh, rc):
        assert a.selected == b.selected
        assert a.comm_mb == pytest.approx(b.comm_mb)
        assert a.mean_selected_loss == pytest.approx(b.mean_selected_loss,
                                                     rel=1e-4)
    err = max(
        float(jnp.max(jnp.abs(x - y)))
        for x, y in zip(jax.tree.leaves(host.params),
                        jax.tree.leaves(comp.params))
    )
    assert err < 1e-5


def test_mask_backends_reject_unsupported_combos_at_config_time():
    """A strategy without select_mask_jax on a mask-gated backend must
    fail at FLConfig construction (not mid-engine-build), and the error
    must name the strategies that do support it."""
    from repro.engine import mask_selection_strategies

    supported = mask_selection_strategies()
    assert "fedlecc" in supported and "poc" in supported
    for backend in ("compiled", "scaleout"):
        with pytest.raises(ValueError, match="jit-compatible selection") as ei:
            _cfg(backend=backend, strategy="fedcor")
        for name in supported:  # actionable: lists every working strategy
            assert name in str(ei.value)
        with pytest.raises(ValueError, match="client_mode"):
            _cfg(backend=backend, client_mode="fedprox", mu=0.1)
    # previously-rejected-at-engine-build combos now never construct;
    # strategies WITH a jit mask still build fine on both backends
    _cfg(backend="compiled", strategy="poc")
    _cfg(backend="scaleout", strategy="haccs")


def test_scaleout_backend_requires_fedavg_aggregator():
    # rejected up front at config construction, like the strategy check
    with pytest.raises(ValueError, match="fedavg"):
        _cfg(backend="scaleout", aggregator="fednova")


def test_scaleout_backend_rejects_mesh_without_pod_axis(data):
    train, test = data
    from repro.launch.mesh import make_host_mesh

    with pytest.raises(ValueError, match="pod"):
        make_engine(_cfg(backend="scaleout"), train, test, n_classes=10,
                    mesh=make_host_mesh(data=1, model=1))


# --------------------------------------------------------- legacy shim
def test_federated_simulation_shim_deprecated_but_working(data):
    train, test = data
    from repro.federated import FederatedSimulation
    from repro.federated.simulation import FLConfig as ShimConfig

    assert ShimConfig is FLConfig
    with pytest.warns(DeprecationWarning, match="FederatedSimulation"):
        sim = FederatedSimulation(_cfg(rounds=2), train, test, n_classes=10)
    h = sim.run()
    assert len(h["test_acc"]) >= 1 and np.isfinite(h["test_loss"][-1])
