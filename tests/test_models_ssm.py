"""SSM blocks: chunked-scan vs naive recurrence (hypothesis), mLSTM/sLSTM
and Mamba sequence-vs-decode equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)

from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.ssm import (
    chunked_linear_scan, init_mamba, init_xlstm, mamba_decode, mamba_seq,
    mlstm_decode, mlstm_seq, slstm_decode, slstm_seq,
)


@given(
    st.integers(1, 3),                     # batch
    st.sampled_from([4, 8, 16, 32]),       # seq
    st.sampled_from([1, 2, 4, 8]),         # chunk
    st.integers(1, 6),                     # feature dim
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_chunked_linear_scan_matches_naive(b, s, chunk, d, seed):
    if s % chunk:
        chunk = s
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0.2, 0.99, (b, s, d)), jnp.float32)
    drive = jnp.asarray(rng.normal(0, 1, (b, s, d)), jnp.float32)
    h0 = jnp.asarray(rng.normal(0, 1, (b, d)), jnp.float32)
    got, fin = chunked_linear_scan(a, drive, h0, chunk)
    # naive recurrence
    h = np.asarray(h0)
    outs = []
    for t in range(s):
        h = np.asarray(a[:, t]) * h + np.asarray(drive[:, t])
        outs.append(h.copy())
    want = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), want[:, -1], atol=1e-4)


def _xlstm_cfg(chunk=16):
    return ModelConfig(
        name="x", family="ssm", n_layers=1, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=100, dtype="float32", block_type="xlstm",
        ssm=SSMConfig(n_heads=4, chunk=chunk, family="xlstm"),
    )


@pytest.mark.parametrize("chunk", [4, 16, 32])
def test_mlstm_seq_matches_recurrent(chunk):
    cfg = _xlstm_cfg(chunk)
    p = init_xlstm(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 32, 64)), jnp.float32)
    yseq, st_seq = mlstm_seq(p, cfg, x)
    st = (jnp.zeros((2, 4, 16, 16)), jnp.zeros((2, 4, 16)), jnp.full((2, 4), -1e30))
    ys = []
    for t in range(32):
        y, st = mlstm_decode(p, cfg, x[:, t : t + 1], st)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(yseq), atol=1e-4
    )
    for a, b in zip(st_seq, st):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_slstm_seq_matches_recurrent():
    cfg = _xlstm_cfg()
    p = init_xlstm(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 32, 64)), jnp.float32)
    yseq, _ = slstm_seq(p, cfg, x)
    st = (jnp.zeros((2, 4, 16)), jnp.zeros((2, 4, 16)), jnp.full((2, 4), -1e30))
    ys = []
    for t in range(32):
        y, st = slstm_decode(p, cfg, x[:, t : t + 1], st)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(yseq), atol=1e-4
    )


def _mamba_cfg(chunk=8):
    return ModelConfig(
        name="m", family="hybrid", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=100, dtype="float32", block_type="hymba",
        ssm=SSMConfig(d_state=8, conv_kernel=4, chunk=chunk, family="mamba"),
    )


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_mamba_seq_matches_recurrent(chunk):
    cfg = _mamba_cfg(chunk)
    p = init_mamba(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 32, 32)), jnp.float32)
    yseq, (hf, tailf) = mamba_seq(p, cfg, x)
    h = jnp.zeros((2, 32, 8))
    tail = jnp.zeros((2, 3, 32))
    ys = []
    for t in range(32):
        y, (h, tail) = mamba_decode(p, cfg, x[:, t : t + 1], h, tail)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(yseq), atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h), atol=1e-4)
    np.testing.assert_allclose(np.asarray(tailf), np.asarray(tail), atol=1e-5)


def test_mamba_state_handoff():
    """mamba_seq(state=...) continues exactly where a previous call ended."""
    cfg = _mamba_cfg(4)
    p = init_mamba(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 0.5, (1, 16, 32)), jnp.float32)
    y_all, _ = mamba_seq(p, cfg, x)
    y1, (h, tail) = mamba_seq(p, cfg, x[:, :8])
    y2, _ = mamba_seq(p, cfg, x[:, 8:], state=h, conv_tail=tail)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_all), atol=1e-4
    )
