"""Hypothesis property tests: communication ledger + compression invariants."""

import jax
import jax.numpy as jnp
import pytest
import numpy as np

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)

from hypothesis import given, settings, strategies as st

from repro.core.comm_model import CommModel
from repro.federated.compression import dequantize_delta, quantize_delta


@given(
    st.integers(1, 10**7),   # n_params
    st.integers(2, 500),     # K
    st.integers(2, 100),     # classes
    st.integers(1, 300),     # rounds
    st.integers(1, 64),      # m
    st.booleans(),           # losses polled
    st.booleans(),           # histograms
)
@settings(max_examples=50, deadline=None)
def test_comm_model_invariants(n_params, K, C, rounds, m, losses, hists):
    m = min(m, K)
    cm = CommModel(n_params, K, C)
    total = cm.total_mb(rounds, m, losses, hists)
    per = cm.round_mb(m, losses)
    # totals decompose exactly
    assert abs(total - (cm.one_time_mb(hists) + rounds * per)) < 1e-9
    # monotone in every argument
    assert cm.round_mb(m, losses) <= cm.round_mb(min(m + 1, K), losses) + 1e-12
    assert cm.total_mb(rounds, m, losses, hists) <= cm.total_mb(
        rounds + 1, m, losses, hists
    )
    # model traffic dominates protocol overhead for real model sizes
    if n_params * 4 > 100 * K * C:
        assert cm.round_mb(m, True) < 1.5 * cm.round_mb(m, False) + cm.one_time_mb(True)


@given(
    st.integers(1, 400),             # leaf size
    st.floats(1e-4, 10.0),           # delta scale
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_quantization_error_bounded_by_one_step(n, scale, seed):
    rng = np.random.default_rng(seed)
    delta = {"w": jnp.asarray(rng.normal(0, scale, (n,)), jnp.float32)}
    qt = quantize_delta(delta, jax.random.PRNGKey(seed % 7919), bits=8)
    deq = dequantize_delta(qt)
    step = float(jnp.max(jnp.abs(delta["w"]))) / 127 + 1e-9
    assert float(jnp.max(jnp.abs(deq["w"] - delta["w"]))) <= step * (1 + 1e-5)
    # int8 range respected
    q = np.asarray(qt.q["w"])
    assert q.min() >= -128 and q.max() <= 127


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_quantization_zero_is_exact(seed):
    delta = {"w": jnp.zeros((64,), jnp.float32)}
    deq = dequantize_delta(quantize_delta(delta, jax.random.PRNGKey(seed)))
    np.testing.assert_allclose(np.asarray(deq["w"]), 0.0, atol=1e-9)
