"""Optimizers, schedules, federated gradient modifiers, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.optim import (
    adamw, chain, clip_by_global_norm, constant, fedprox_grads, feddyn_grads,
    sgd, warmup_cosine,
)
from repro.optim.optimizers import apply_updates


def _quadratic_descends(opt, steps=200):
    target = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target["w"]) ** 2)

    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss(params))


@pytest.mark.parametrize(
    "opt",
    [
        sgd(0.1),
        sgd(0.05, momentum=0.9),
        sgd(0.05, momentum=0.9, nesterov=True),
        adamw(0.05),
        chain(clip_by_global_norm(1.0), sgd(0.1)),
    ],
    ids=["sgd", "sgd-mom", "sgd-nesterov", "adamw", "clip+sgd"],
)
def test_optimizers_minimize_quadratic(opt):
    assert _quadratic_descends(opt) < 1e-2


def test_clip_by_global_norm():
    clip = clip_by_global_norm(1.0)
    g = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    out, _ = clip.update(g, clip.init(g), None)
    assert abs(float(jnp.linalg.norm(out["a"])) - 1.0) < 1e-6
    g2 = {"a": jnp.asarray([0.3, 0.4])}          # norm 0.5 → untouched
    out2, _ = clip.update(g2, clip.init(g2), None)
    np.testing.assert_allclose(np.asarray(out2["a"]), [0.3, 0.4], atol=1e-7)


def test_schedules():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) < 1e-6
    assert float(constant(0.3)(jnp.asarray(7))) == pytest.approx(0.3)


def test_fedprox_pulls_toward_global():
    p = {"w": jnp.asarray([1.0])}
    gl = {"w": jnp.asarray([0.0])}
    g = {"w": jnp.asarray([0.0])}
    out = fedprox_grads(g, p, gl, mu=0.5)
    assert float(out["w"][0]) == pytest.approx(0.5)  # mu·(θ−θg)


def test_feddyn_grad_terms():
    p = {"w": jnp.asarray([2.0])}
    gl = {"w": jnp.asarray([1.0])}
    h = {"w": jnp.asarray([0.3])}
    g = {"w": jnp.asarray([1.0])}
    out = feddyn_grads(g, p, gl, h, alpha=0.1)
    assert float(out["w"][0]) == pytest.approx(1.0 - 0.3 + 0.1 * 1.0)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": [jnp.ones((2,), jnp.int32), {"c": jnp.asarray(2.5, jnp.bfloat16)}],
    }
    path = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(path, tree, meta={"step": 7})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = load_checkpoint(path, like)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(path, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"a": jnp.ones((4,))})
