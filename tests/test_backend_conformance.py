"""Cross-backend conformance: every mask-capable strategy must select the
same clients and land on (all)close final params on every backend —
host, compiled, and scaleout — from the same seed, for every registered
task (the MLP classification task and the transformer LM task run the
identical grid).  Also guards the streaming-API contract:
``engine.rounds()`` yields frozen ``RoundResult``s with a stable field
set on all backends.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from conftest import LM_VOCAB, fl_cfg as _cfg, lm_fl_cfg as _lm_cfg
from repro.engine import (
    BACKENDS,
    RoundResult,
    make_engine,
    mask_selection_strategies,
)

MASK_STRATEGIES = mask_selection_strategies()
TASKS = ("classification", "lm")
# LM cells build a transformer per engine; 2 rounds keeps the grid cheap
# while still flowing aggregated params back into a second round.
ROUNDS = {"classification": 3, "lm": 2}
N_CLASSES = {"classification": 10, "lm": LM_VOCAB}


def _task_cfg(task, **kw):
    return _lm_cfg(**kw) if task == "lm" else _cfg(**kw)


def _run(task, strategy, backend, datasets):
    train, test = datasets
    engine = make_engine(_task_cfg(task, strategy=strategy, backend=backend),
                         train, test, n_classes=N_CLASSES[task])
    results = list(engine.rounds(ROUNDS[task]))
    return results, engine.params


def test_mask_strategy_registry_covers_issue_set():
    """The jit-selection surface the scaleout backend promises."""
    assert {"fedlecc", "poc", "lossonly", "clusterrandom", "haccs"} <= set(
        MASK_STRATEGIES
    )


@pytest.mark.parametrize("strategy", MASK_STRATEGIES)
@pytest.mark.parametrize("task", TASKS)
def test_cross_backend_conformance(task, strategy, data, lm_data):
    """For each task × strategy: identical per-round selections and
    allclose final params across host/compiled/scaleout from one seed."""
    datasets = lm_data if task == "lm" else data
    runs = {b: _run(task, strategy, b, datasets) for b in BACKENDS}
    ref_results, ref_params = runs["host"]
    assert len(ref_results) == ROUNDS[task]
    for backend in ("compiled", "scaleout"):
        results, params = runs[backend]
        for a, b in zip(ref_results, results):
            assert a.selected == b.selected, (
                f"{task}/{strategy}: host vs {backend} selected different "
                f"clients in round {a.round}: {a.selected} vs {b.selected}"
            )
            assert a.comm_mb == pytest.approx(b.comm_mb)
            assert a.mean_selected_loss == pytest.approx(
                b.mean_selected_loss, rel=1e-4
            )
        for x, y in zip(jax.tree.leaves(ref_params), jax.tree.leaves(params)):
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), atol=1e-5,
                err_msg=f"{task}/{strategy}: host vs {backend} final params "
                        f"diverge",
            )


# ------------------------------------------- compressed-aggregation cell
@pytest.mark.parametrize("task", TASKS)
def test_compressed_aggregation_close_to_exact(task, data, lm_data):
    """ROADMAP item (f): the conformance grid as the harness for the
    compressed-aggregation engine mode.  ``compress_bits=8`` stochastic-
    rounds each selected client's delta to int8 before the weighted
    reduce, so the compiled trajectory must stay allclose to the exact
    host trajectory at a loosened tolerance — and the upload ledger must
    actually shrink."""
    datasets = lm_data if task == "lm" else data
    train, test = datasets
    exact = make_engine(
        _task_cfg(task, backend="host"), train, test,
        n_classes=N_CLASSES[task],
    )
    quant = make_engine(
        _task_cfg(task, backend="compiled", compress_bits=8), train, test,
        n_classes=N_CLASSES[task],
    )
    re_, rq = list(exact.rounds(ROUNDS[task])), list(quant.rounds(ROUNDS[task]))
    # round 0 selects from identical initial params: must agree exactly
    assert re_[0].selected == rq[0].selected
    # int8 uploads: strictly less traffic than the fp32 ledger
    assert rq[-1].comm_mb < re_[-1].comm_mb
    for x, y in zip(jax.tree.leaves(exact.params), jax.tree.leaves(quant.params)):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), atol=5e-3,
            err_msg=f"{task}: compressed aggregation drifted beyond the "
                    f"quantization-error budget",
        )


# --------------------------------------- degenerate-async equivalence cells
_ASYNC_SYS = dict(profile="mobile_mix", availability="markov",
                  availability_kwargs={"p_drop": 0.2, "p_join": 0.6},
                  deadline_s=30.0, over_select=1.3, jitter_sigma=0.1)


@pytest.mark.parametrize("backend", ["host", "compiled"])
@pytest.mark.parametrize("task", TASKS)
def test_degenerate_async_conformance(task, backend, data, lm_data):
    """Acceptance (DESIGN.md §13): the degenerate async configuration
    (``dispatch="sync"``, ``buffer_k`` = the cohort, discount off) is
    bit-identical to the plain sync engine on both tasks and both eager
    backends — params, selections, history, comm, sim_clock."""
    train, test = lm_data if task == "lm" else data
    kw = dict(backend=backend, systems=dict(_ASYNC_SYS))
    sync = make_engine(_task_cfg(task, **kw), train, test,
                       n_classes=N_CLASSES[task])
    dgen = make_engine(
        _task_cfg(task, async_mode={"dispatch": "sync"}, **kw),
        train, test, n_classes=N_CLASSES[task],
    )
    rs = list(sync.rounds(ROUNDS[task]))
    rd = list(dgen.rounds(ROUNDS[task]))
    for a, b in zip(rs, rd):
        assert a.selected == b.selected, (
            f"{task}/{backend}: degenerate async diverged from sync in "
            f"round {a.round}: {a.selected} vs {b.selected}"
        )
        assert a.comm_mb == b.comm_mb
        assert a.sim_clock == b.sim_clock and a.sim_time == b.sim_time
        assert a.mean_selected_loss == b.mean_selected_loss or (
            np.isnan(a.mean_selected_loss) and np.isnan(b.mean_selected_loss)
        )
        assert b.staleness == 0.0 and b.params_version == a.round + 1
    assert sync.history == dgen.history
    for x, y in zip(jax.tree.leaves(sync.params), jax.tree.leaves(dgen.params)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{task}/{backend}: degenerate-async params diverged",
        )


# ------------------------------------------------- streaming API contract
ROUND_RESULT_FIELDS = (
    "round", "selected", "mean_selected_loss", "comm_mb",
    "test_loss", "test_acc",
    # systems axis (PR 5): simulated wall clock + deadline drops; task
    # extras (LM perplexity).  Defaults keep systems-free runs identical.
    "sim_time", "sim_clock", "n_dropped", "metrics",
    # async runtime (DESIGN.md §13): mean staleness of the aggregated
    # buffer + the server params version.  Lock-step defaults: 0 / r+1.
    "staleness", "params_version",
    # fault axis (DESIGN.md §14): injected-faulty arrivals this round +
    # clients serving a quarantine after it.  Inert zeros without faults.
    "n_faulty", "n_quarantined",
)

# every backend on the classification task + one LM cell (the LM grid
# above already streams RoundResults on all three backends)
_STREAM_CELLS = [("classification", b) for b in BACKENDS] + [("lm", "host")]


@pytest.mark.parametrize("task,backend", _STREAM_CELLS)
def test_rounds_yields_frozen_stable_round_results(task, backend, data, lm_data):
    """Regression guard for benchmark consumers: the record type, its
    field set, and its frozenness must not drift on any backend/task."""
    train, test = lm_data if task == "lm" else data
    engine = make_engine(_task_cfg(task, backend=backend), train, test,
                         n_classes=N_CLASSES[task])
    results = list(engine.rounds(2))
    assert len(results) == 2
    for r in results:
        assert isinstance(r, RoundResult)
        assert tuple(f.name for f in dataclasses.fields(r)) == ROUND_RESULT_FIELDS
        assert isinstance(r.selected, tuple)
        assert isinstance(r.mean_selected_loss, float)
        assert isinstance(r.comm_mb, float)
        with pytest.raises(dataclasses.FrozenInstanceError):
            r.round = -1
        with pytest.raises(dataclasses.FrozenInstanceError):
            r.test_acc = 1.0


# ------------------------------------------------- multi-pod mesh parity
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.data import make_classification
from repro.engine import FLConfig, make_engine

train = make_classification(800, n_features=64, n_classes=10, seed=0)
test = make_classification(200, n_features=64, n_classes=10, seed=1)
kw = dict(n_clients=12, m=4, rounds=2, strategy="fedlecc",
          strategy_kwargs={"J": 3}, hidden=(16,), eval_samples=16,
          eval_every=1, target_hd=0.8, seed=0)
host = make_engine(FLConfig(backend="host", **kw), train, test, 10)
scale = make_engine(FLConfig(backend="scaleout", **kw), train, test, 10)
assert scale.n_pods > 1, f"expected a multi-pod mesh, got {scale.n_pods}"
rh, rs = list(host.rounds(2)), list(scale.rounds(2))
for a, b in zip(rh, rs):
    assert a.selected == b.selected, (a.selected, b.selected)
for x, y in zip(jax.tree.leaves(host.params), jax.tree.leaves(scale.params)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
print("SCALEOUT_ENGINE_MULTIPOD_OK", scale.n_pods)
"""

# LM task on a real multi-pod mesh: transformer client stacks sharded
# P("pod"), selection-weighted psum over pods — must match host exactly.
_LM_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.data.synthetic import make_token_stream
from repro.engine import FLConfig, make_engine

V = 32
train = make_token_stream(48, 16, V, seed=0)
test = make_token_stream(16, 16, V, seed=1)
kw = dict(task="lm",
          task_kwargs={"model": "stablelm-3b",
                       "overrides": {"d_model": 32, "n_heads": 2,
                                     "n_kv_heads": 2, "head_dim": 16,
                                     "d_ff": 64, "vocab": V,
                                     "loss_chunk": 16, "attn_chunk": 16,
                                     "remat": False},
                       "hist_bins": 16},
          n_clients=8, m=3, rounds=2, strategy="fedlecc",
          strategy_kwargs={"J": 2}, batch_size=4, eval_samples=4,
          eval_every=1, target_hd=0.8, max_steps_cap=3, seed=0)
host = make_engine(FLConfig(backend="host", **kw), train, test, V)
scale = make_engine(FLConfig(backend="scaleout", **kw), train, test, V)
assert scale.n_pods > 1, f"expected a multi-pod mesh, got {scale.n_pods}"
rh, rs = list(host.rounds(2)), list(scale.rounds(2))
for a, b in zip(rh, rs):
    assert a.selected == b.selected, (a.selected, b.selected)
for x, y in zip(jax.tree.leaves(host.params), jax.tree.leaves(scale.params)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
print("SCALEOUT_LM_MULTIPOD_OK", scale.n_pods)
"""


def _run_subprocess(script, marker):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert marker in r.stdout, (
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    )


@pytest.mark.slow
def test_scaleout_engine_multipod_matches_host():
    """ScaleoutEngine on a real multi-pod (virtual-device) mesh — the
    psum over a >1 pod axis — still matches the host backend.  Subprocess
    so the device-count flag doesn't leak into other tests."""
    _run_subprocess(_SCRIPT, "SCALEOUT_ENGINE_MULTIPOD_OK")


@pytest.mark.slow
def test_scaleout_lm_multipod_matches_host():
    """The LM task on a real multi-pod mesh: per-client transformer
    stacks over pods, selection-weighted psum aggregation — identical
    selections and allclose params vs the host backend."""
    _run_subprocess(_LM_SCRIPT, "SCALEOUT_LM_MULTIPOD_OK")
