"""Cross-backend conformance: every mask-capable strategy must select the
same clients and land on (all)close final params on every backend —
host, compiled, and scaleout — from the same seed.  Also guards the
streaming-API contract: ``engine.rounds()`` yields frozen
``RoundResult``s with a stable field set on all backends.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from conftest import fl_cfg as _cfg
from repro.engine import (
    BACKENDS,
    RoundResult,
    make_engine,
    mask_selection_strategies,
)

ROUNDS = 3
MASK_STRATEGIES = mask_selection_strategies()


def _run(strategy, backend, data):
    train, test = data
    engine = make_engine(_cfg(strategy=strategy, backend=backend),
                         train, test, n_classes=10)
    results = list(engine.rounds(ROUNDS))
    return results, engine.params


def test_mask_strategy_registry_covers_issue_set():
    """The jit-selection surface the scaleout backend promises."""
    assert {"fedlecc", "poc", "lossonly", "clusterrandom", "haccs"} <= set(
        MASK_STRATEGIES
    )


@pytest.mark.parametrize("strategy", MASK_STRATEGIES)
def test_cross_backend_conformance(strategy, data):
    """For each strategy: identical per-round selections and allclose
    final params across host/compiled/scaleout from one seed."""
    runs = {b: _run(strategy, b, data) for b in BACKENDS}
    ref_results, ref_params = runs["host"]
    assert len(ref_results) == ROUNDS
    for backend in ("compiled", "scaleout"):
        results, params = runs[backend]
        for a, b in zip(ref_results, results):
            assert a.selected == b.selected, (
                f"{strategy}: host vs {backend} selected different clients "
                f"in round {a.round}: {a.selected} vs {b.selected}"
            )
            assert a.comm_mb == pytest.approx(b.comm_mb)
            assert a.mean_selected_loss == pytest.approx(
                b.mean_selected_loss, rel=1e-4
            )
        for x, y in zip(jax.tree.leaves(ref_params), jax.tree.leaves(params)):
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), atol=1e-5,
                err_msg=f"{strategy}: host vs {backend} final params diverge",
            )


# ------------------------------------------------- streaming API contract
ROUND_RESULT_FIELDS = (
    "round", "selected", "mean_selected_loss", "comm_mb",
    "test_loss", "test_acc",
)


@pytest.mark.parametrize("backend", BACKENDS)
def test_rounds_yields_frozen_stable_round_results(backend, data):
    """Regression guard for benchmark consumers: the record type, its
    field set, and its frozenness must not drift on any backend."""
    train, test = data
    engine = make_engine(_cfg(backend=backend), train, test, n_classes=10)
    results = list(engine.rounds(2))
    assert len(results) == 2
    for r in results:
        assert isinstance(r, RoundResult)
        assert tuple(f.name for f in dataclasses.fields(r)) == ROUND_RESULT_FIELDS
        assert isinstance(r.selected, tuple)
        assert isinstance(r.mean_selected_loss, float)
        assert isinstance(r.comm_mb, float)
        with pytest.raises(dataclasses.FrozenInstanceError):
            r.round = -1
        with pytest.raises(dataclasses.FrozenInstanceError):
            r.test_acc = 1.0


# ------------------------------------------------- multi-pod mesh parity
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.data import make_classification
from repro.engine import FLConfig, make_engine

train = make_classification(800, n_features=64, n_classes=10, seed=0)
test = make_classification(200, n_features=64, n_classes=10, seed=1)
kw = dict(n_clients=12, m=4, rounds=2, strategy="fedlecc",
          strategy_kwargs={"J": 3}, hidden=(16,), eval_samples=16,
          eval_every=1, target_hd=0.8, seed=0)
host = make_engine(FLConfig(backend="host", **kw), train, test, 10)
scale = make_engine(FLConfig(backend="scaleout", **kw), train, test, 10)
assert scale.n_pods > 1, f"expected a multi-pod mesh, got {scale.n_pods}"
rh, rs = list(host.rounds(2)), list(scale.rounds(2))
for a, b in zip(rh, rs):
    assert a.selected == b.selected, (a.selected, b.selected)
for x, y in zip(jax.tree.leaves(host.params), jax.tree.leaves(scale.params)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
print("SCALEOUT_ENGINE_MULTIPOD_OK", scale.n_pods)
"""


@pytest.mark.slow
def test_scaleout_engine_multipod_matches_host():
    """ScaleoutEngine on a real multi-pod (virtual-device) mesh — the
    psum over a >1 pod axis — still matches the host backend.  Subprocess
    so the device-count flag doesn't leak into other tests."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert "SCALEOUT_ENGINE_MULTIPOD_OK" in r.stdout, (
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    )
