"""Attention: flash vs naive oracle, decode vs full, MLA consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.attention import (
    decode_attention, flash_attention, gqa_attention, gqa_decode, init_gqa,
    init_mla, mla_attention, mla_decode, naive_attention,
)
from repro.models.common import rope_table


@pytest.mark.parametrize(
    "b,s,h,kv,d,w,g",
    [
        (2, 256, 4, 2, 32, 0, 1.0),
        (1, 128, 4, 4, 16, 32, 0.0),
        (2, 256, 8, 2, 64, 64, 0.0),
        (2, 128, 4, 1, 32, 16, 1.0),   # window set but layer is global
        (1, 512, 2, 2, 128, 128, 0.0),
    ],
)
def test_flash_matches_naive(b, s, h, kv, d, w, g):
    rng = np.random.default_rng(b * s + h)
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), jnp.float32)
    want = naive_attention(q, k, v, w, g)
    got = flash_attention(q, k, v, w, g, chunk_q=64, chunk_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_matches_full_last_row():
    rng = np.random.default_rng(0)
    b, s, h, kv, d = 2, 64, 4, 2, 32
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), jnp.float32)
    full = naive_attention(q, k, v)
    dec = decode_attention(q[:, -1:], k, v, jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1:]), atol=1e-5)


def _gqa_cfg(**kw):
    base = dict(
        name="t", family="dense", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=100, dtype="float32", attn_chunk=32,
    )
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("qk_norm", [False, True])
@pytest.mark.parametrize("rope_fraction", [1.0, 0.5])
def test_gqa_prefill_decode_consistency(qk_norm, rope_fraction):
    cfg = _gqa_cfg(qk_norm=qk_norm, rope_fraction=rope_fraction)
    p = init_gqa(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    s = 16
    x = jnp.asarray(rng.normal(0, 1, (2, s, 64)), jnp.float32)
    hd = cfg.resolved_head_dim
    rot = int(hd * cfg.rope_fraction) - int(hd * cfg.rope_fraction) % 2
    sin, cos = rope_table(s, max(rot, 2), cfg.rope_theta)
    full, (kf, vf) = gqa_attention(p, cfg, x, sin, cos)
    # decode each position from scratch
    kc = jnp.zeros((2, s, 2, hd))
    vc = jnp.zeros((2, s, 2, hd))
    outs = []
    for t in range(s):
        sin_t = jax.lax.dynamic_slice_in_dim(sin, t, 1, 0)
        cos_t = jax.lax.dynamic_slice_in_dim(cos, t, 1, 0)
        o, (kc, vc) = gqa_decode(p, cfg, x[:, t : t + 1], sin_t, cos_t, (kc, vc), t)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=5e-5)
    np.testing.assert_allclose(np.asarray(kc), np.asarray(kf), atol=1e-5)


def test_mla_prefill_decode_consistency():
    cfg = ModelConfig(
        name="mla", family="moe", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=100, dtype="float32", use_mla=True,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
        qk_rope_head_dim=8, v_head_dim=16, attn_chunk=16,
    )
    p = init_mla(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    s = 16
    x = jnp.asarray(rng.normal(0, 1, (2, s, 64)), jnp.float32)
    sin, cos = rope_table(s, cfg.qk_rope_head_dim, cfg.rope_theta)
    full, (lat_f, kr_f) = mla_attention(p, cfg, x, sin, cos)
    lat = jnp.zeros((2, s, 16))
    kr = jnp.zeros((2, s, 8))
    outs = []
    for t in range(s):
        sin_t = jax.lax.dynamic_slice_in_dim(sin, t, 1, 0)
        cos_t = jax.lax.dynamic_slice_in_dim(cos, t, 1, 0)
        o, (lat, kr) = mla_decode(p, cfg, x[:, t : t + 1], sin_t, cos_t, (lat, kr), t)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    # absorbed decode vs materialized prefill: same math, different order
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(lat), np.asarray(lat_f), atol=1e-5)
