"""Population axis (DESIGN.md §15) + energy ledger (ROADMAP (q)).

Covers the PR's acceptance cells:

- store determinism per (seed, shard) and ShardedStore ≡ InMemoryStore
  cohort bit-identity;
- lazy materialization: only resident shards are ever synthesized;
- blocked Hellinger ≡ dense (and the dense-budget ResourceWarning);
- one-shard hierarchical ≡ flat, bit-identical per mask strategy on the
  host and compiled backends;
- population config cross-validation and checkpoint carry;
- battery accounting: per-round metrics, depletion gating availability,
  and the state_dict round-trip.
"""

import os
import sys
import warnings

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from conftest import fl_cfg  # noqa: E402


# ---------------------------------------------------------------------------
# stores
# ---------------------------------------------------------------------------
def test_shard_layout_contiguous_near_equal():
    from repro.population import shard_layout

    shards = shard_layout(103, 7)
    assert len(shards) == 7
    flat = np.concatenate(shards)
    np.testing.assert_array_equal(flat, np.arange(103))  # contiguous blocks
    sizes = {len(s) for s in shards}
    assert max(sizes) - min(sizes) <= 1
    with pytest.raises(ValueError):
        shard_layout(5, 6)
    with pytest.raises(ValueError):
        shard_layout(5, 0)


def test_synthetic_loader_deterministic_per_seed_and_shard():
    from repro.population import SyntheticShardLoader, shard_layout

    loader = SyntheticShardLoader(seed=7, n_classes=6, n_features=8)
    members = shard_layout(64, 4)[2]
    a = loader.load(2, members)
    b = loader.load(2, members)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)  # bit-identical reload
    # summary replays the label stream only, bit-identical to load's
    sizes, hists = loader.summary(2, members)
    np.testing.assert_array_equal(sizes, a.sizes)
    np.testing.assert_array_equal(hists, a.hists)
    # a different shard / different seed gives different data
    c = loader.load(3, members)
    assert not np.array_equal(a.ys, c.ys)
    d = SyntheticShardLoader(seed=8, n_classes=6, n_features=8).load(2, members)
    assert not np.array_equal(a.ys, d.ys)


def test_sharded_store_gathers_bitidentical_to_inmemory():
    from repro.population import (
        ShardedStore,
        SyntheticShardLoader,
        materialize_store,
    )

    store = ShardedStore(
        SyntheticShardLoader(seed=3, n_classes=5, n_features=6),
        n_clients=48, n_shards=6,
    )
    flat = materialize_store(store)
    np.testing.assert_array_equal(store.client_sizes(), flat.client_sizes())
    np.testing.assert_array_equal(store.client_hists(), flat.client_hists())
    # scattered, unsorted cohort spanning several shards
    idx = np.array([45, 3, 17, 30, 4, 44])
    for a, b in zip(store.gather(idx), flat.gather(idx)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_store_is_lazy_with_lru_bound():
    from repro.population import ShardedStore, SyntheticShardLoader

    store = ShardedStore(
        SyntheticShardLoader(seed=1, n_classes=4, n_features=5),
        n_clients=40, n_shards=8, max_cached_shards=2,
    )
    # summaries (sizes / hists / shard_hists) never materialize features
    store.shard_hists()
    assert store.materialized_shards() == ()
    assert store.load_count == 0
    xs0, _, _ = store.gather(store.shard_members(1))
    assert store.materialized_shards() == (1,)
    store.gather(store.shard_members(5))
    store.gather(store.shard_members(6))  # evicts shard 1 (LRU bound 2)
    assert store.cached_shards() == (5, 6)
    assert store.materialized_shards() == (1, 5, 6)
    # reloading the evicted shard is bit-identical
    xs1, _, _ = store.gather(store.shard_members(1))
    np.testing.assert_array_equal(np.asarray(xs0), np.asarray(xs1))
    assert store.load_count == 4


# ---------------------------------------------------------------------------
# blocked Hellinger
# ---------------------------------------------------------------------------
def test_blocked_hellinger_matches_dense():
    import jax.numpy as jnp

    from repro.core.hellinger import hellinger_blocked, hellinger_matrix

    rng = np.random.default_rng(0)
    h = rng.random((37, 11)) + 1e-6
    dense = np.asarray(hellinger_matrix(jnp.asarray(h)))
    # block smaller than K forces multiple strips (the regression the
    # strategies.py call sites rely on)
    for block in (8, 37, 4096):
        blocked = hellinger_blocked(h, block=block)
        np.testing.assert_allclose(blocked, dense, atol=2e-6)
    np.testing.assert_array_equal(np.diag(hellinger_blocked(h)), 0.0)


def test_blocked_hellinger_rows_strip():
    import jax.numpy as jnp

    from repro.core.hellinger import hellinger_matrix, hellinger_rows

    rng = np.random.default_rng(1)
    h = rng.random((20, 7)) + 1e-6
    dense = np.asarray(hellinger_matrix(jnp.asarray(h)))
    strip = hellinger_rows(h[5:9], h)
    assert strip.shape == (4, 20)
    off_diag = ~np.eye(20, dtype=bool)[5:9]
    np.testing.assert_allclose(strip[off_diag], dense[5:9][off_diag], atol=2e-6)


def test_dense_budget_warning_configurable():
    from repro.core.hellinger import (
        dense_budget_bytes,
        hellinger_blocked,
        set_dense_budget_bytes,
    )

    h = np.random.default_rng(2).random((64, 8)) + 1e-6
    old = set_dense_budget_bytes(64 * 64 * 4 - 1)  # force the guard
    try:
        with pytest.warns(ResourceWarning, match="dense"):
            hellinger_blocked(h)
        # raising the budget (or passing one per-call) silences it
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            hellinger_blocked(h, budget_bytes=1 << 30)
    finally:
        set_dense_budget_bytes(old)
    assert dense_budget_bytes() == old


def test_strategies_route_through_blocked_build():
    """The two dense call sites (FedLECC auto-clustering, FedCor's
    K-matrix) now route through hellinger_blocked — same clusters, same
    selections as the dense build they replaced."""
    from repro.core.strategies import FedCor, FedLECC

    rng = np.random.default_rng(3)
    hists = rng.dirichlet(np.ones(10) * 0.3, size=30)
    sizes = rng.integers(10, 50, size=30)
    s = FedLECC(m=6, J=3)
    s.setup(hists, sizes, seed=0)
    sel = s.select(0, rng.random(30).astype(np.float32), np.random.default_rng(0))
    assert len(sel) == 6
    c = FedCor(m=6)
    c.setup(hists, sizes, seed=0)
    sel2 = c.select(0, rng.random(30).astype(np.float32), np.random.default_rng(0))
    assert len(sel2) == 6


# ---------------------------------------------------------------------------
# hierarchy
# ---------------------------------------------------------------------------
def _sharded_store(n_clients=96, n_shards=8, seed=5):
    from repro.population import ShardedStore, SyntheticShardLoader

    return ShardedStore(
        SyntheticShardLoader(seed=seed, n_classes=6, n_features=8),
        n_clients=n_clients, n_shards=n_shards,
    )


def test_hierarchy_explore_first_then_ranks_by_loss():
    from repro.population import HierarchicalSelector, PopulationConfig

    store = _sharded_store()
    cfg = PopulationConfig(n_shards=8, shards_per_round=2, j_shards=2)
    sel = HierarchicalSelector(cfg, store, seed=0, needs_losses=True)
    assert np.isinf(sel.estimates).all()  # unexplored shards rank first
    seen = set()
    for rnd in range(6):
        shards, members = sel.begin_round(rnd)
        assert len(shards) == 2
        np.testing.assert_array_equal(
            members,
            np.concatenate([store.shard_members(int(s)) for s in shards]),
        )
        seen.update(int(s) for s in shards)
        losses = np.full(store.n_clients, -np.inf, np.float32)
        losses[members] = 1.0 + np.asarray(members, np.float32) / 100.0
        sel.observe(losses)
    assert len(seen) > 2  # +inf estimates force exploration across shards
    # estimates of explored shards became finite member means
    explored = [s for s in range(8) if np.isfinite(sel.estimates[s])]
    assert set(explored) == seen


def test_hierarchy_resident_shards_bound_materialization():
    """The population-proportionality proof obligation: a ShardedStore
    driven by hierarchical selection synthesizes exactly the shards the
    shard-level Algorithm 1 visited — never the full range."""
    from repro.population import HierarchicalSelector, PopulationConfig

    store = _sharded_store(n_clients=160, n_shards=16)
    cfg = PopulationConfig(n_shards=16, shards_per_round=2, j_shards=2)
    sel = HierarchicalSelector(cfg, store, seed=0, needs_losses=True)
    visited = set()
    for rnd in range(3):
        shards, members = sel.begin_round(rnd)
        visited.update(int(s) for s in shards)
        store.gather(members)  # what the engine's poll does
        losses = np.zeros(store.n_clients, np.float32)
        losses[members] = 1.0
        sel.observe(losses)
    assert set(store.materialized_shards()) == visited
    assert len(store.materialized_shards()) <= 6 < store.n_shards


def test_hierarchy_select_cohort_matches_loss_rank():
    from repro.population import HierarchicalSelector, PopulationConfig

    store = _sharded_store()
    cfg = PopulationConfig(n_shards=8, shards_per_round=3, j_shards=2)
    sel = HierarchicalSelector(cfg, store, seed=0, needs_losses=True)
    _, members = sel.begin_round(0)
    rng = np.random.default_rng(0)
    member_losses = rng.random(len(members)).astype(np.float32)
    cohort = sel.select_cohort(member_losses, m=5)
    # reference: top-m by loss over the resident members
    ref = np.sort(members[np.argsort(-member_losses)[:5]])
    np.testing.assert_array_equal(cohort, ref)


def test_hierarchy_state_roundtrip():
    from repro.population import HierarchicalSelector, PopulationConfig

    store = _sharded_store()
    cfg = PopulationConfig(n_shards=8, shards_per_round=2, j_shards=2)
    a = HierarchicalSelector(cfg, store, seed=0)
    a.estimates[3] = 1.25
    b = HierarchicalSelector(cfg, store, seed=0)
    b.load_state_dict(a.state_dict())
    np.testing.assert_array_equal(a.estimates, b.estimates)
    with pytest.raises(ValueError):
        b.load_state_dict({"estimates": [1.0]})


def test_one_shard_hierarchy_is_all_resident_no_rng():
    from repro.population import (
        HierarchicalSelector,
        InMemoryStore,
        PopulationConfig,
    )

    rng = np.random.default_rng(0)
    store = InMemoryStore(
        xs=rng.random((12, 4, 3), dtype=np.float32),
        ys=rng.integers(0, 5, (12, 4)),
        mask=np.ones((12, 4), np.float32),
        sizes=np.full(12, 4),
        hists=rng.dirichlet(np.ones(5), size=12),
        n_shards=1,
    )
    sel = HierarchicalSelector(
        PopulationConfig(n_shards=1), store, seed=0, needs_losses=False
    )
    shards, members = sel.begin_round(0)
    np.testing.assert_array_equal(shards, [0])
    np.testing.assert_array_equal(members, np.arange(12))
    assert sel.resident_mask().all()


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------
def test_population_config_validation():
    from repro.population import PopulationConfig

    with pytest.raises(ValueError):
        PopulationConfig(n_shards=0)
    with pytest.raises(ValueError):
        PopulationConfig(n_shards=4, shards_per_round=5)
    with pytest.raises(ValueError):
        PopulationConfig.from_dict({"n_shards": 2, "bogus": 1})
    cfg = PopulationConfig.from_dict({"n_shards": 4, "shards_per_round": 2})
    assert cfg.n_shards == 4 and cfg.shards_per_round == 2


def test_flconfig_population_cross_validation():
    with pytest.raises(ValueError, match="population"):
        fl_cfg(backend="scaleout", population={"n_shards": 2})
    with pytest.raises(ValueError, match="population"):
        fl_cfg(backend="compiled", fuse_rounds=2, population={"n_shards": 2})
    with pytest.raises(ValueError, match="population"):
        fl_cfg(async_mode={"buffer_k": 2}, systems={},
               population={"n_shards": 2})
    with pytest.raises(ValueError, match="population"):
        fl_cfg(client_mode="fedprox", population={"n_shards": 2})
    with pytest.raises(ValueError, match="n_shards"):
        fl_cfg(population={"n_shards": 99})
    # dict form normalizes and round-trips through to_dict/from_dict
    from repro.engine import FLConfig
    from repro.population import PopulationConfig

    cfg = fl_cfg(population={"n_shards": 3, "shards_per_round": 2})
    assert isinstance(cfg.population, PopulationConfig)
    cfg2 = FLConfig.from_dict(cfg.to_dict())
    assert cfg2.population == cfg.population


def test_flconfig_energy_cross_validation():
    with pytest.raises(ValueError, match="track_energy"):
        fl_cfg(backend="compiled", fuse_rounds=2,
               systems={"track_energy": True})
    with pytest.raises(ValueError, match="track_energy"):
        fl_cfg(async_mode={"buffer_k": 2}, systems={"track_energy": True})


def test_engine_rejects_undersized_resident_shards(data):
    from repro.engine import make_engine

    train, test = data
    cfg = fl_cfg(m=8, population={"n_shards": 6, "shards_per_round": 1})
    with pytest.raises(ValueError, match="m_eff"):
        make_engine(cfg, train, test, n_classes=10)


# ---------------------------------------------------------------------------
# engine conformance: one shard ≡ flat, bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["host", "compiled"])
@pytest.mark.parametrize("strategy", ["fedlecc", "random", "lossonly"])
def test_one_shard_population_bitidentical_to_flat(strategy, backend, data):
    from repro.engine import make_engine

    train, test = data
    kw = {"strategy_kwargs": {"J": 3}} if strategy == "fedlecc" else {}
    flat = make_engine(
        fl_cfg(strategy=strategy, backend=backend, rounds=2, **kw),
        train, test, n_classes=10,
    )
    pop = make_engine(
        fl_cfg(strategy=strategy, backend=backend, rounds=2,
               population={"n_shards": 1}, **kw),
        train, test, n_classes=10,
    )
    for a, b in zip(flat.rounds(), pop.rounds()):
        assert a.selected == b.selected
        assert a.mean_selected_loss == b.mean_selected_loss
        assert a.test_loss == b.test_loss and a.test_acc == b.test_acc
        assert a.comm_mb == b.comm_mb
    for x, y in zip(jax.tree.leaves(flat.params), jax.tree.leaves(pop.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("backend", ["host", "compiled"])
def test_population_cohort_stays_inside_resident_shards(backend, data):
    from repro.engine import make_engine

    train, test = data
    cfg = fl_cfg(backend=backend, rounds=3,
                 population={"n_shards": 4, "shards_per_round": 2,
                             "j_shards": 2})
    eng = make_engine(cfg, train, test, n_classes=10)
    for r in eng.rounds():
        members = set(int(i) for i in eng._pop_members)
        assert set(r.selected) <= members
        assert len(members) < cfg.n_clients  # genuinely partial residency


def test_population_comm_counts_resident_polls_only(data):
    from repro.engine import make_engine

    train, test = data
    flat = make_engine(fl_cfg(rounds=2), train, test, n_classes=10)
    pop = make_engine(
        fl_cfg(rounds=2, population={"n_shards": 4, "shards_per_round": 2,
                                     "j_shards": 2}),
        train, test, n_classes=10,
    )
    fr = [r.comm_mb for r in flat.rounds()]
    pr = [r.comm_mb for r in pop.rounds()]
    # same model traffic, strictly fewer loss-poll bytes each round
    assert all(p < f for p, f in zip(pr, fr))
    expected_gap = 2 * (12 - 6) * 4 / (1024.0 * 1024.0)  # 2 rounds × 6 clients
    np.testing.assert_allclose(fr[-1] - pr[-1], expected_gap, rtol=1e-6)


def test_population_checkpoint_roundtrip(tmp_path, data):
    from repro.engine import make_engine

    train, test = data
    cfg = fl_cfg(rounds=4, population={"n_shards": 3, "shards_per_round": 2,
                                       "j_shards": 2})
    eng = make_engine(cfg, train, test, n_classes=10)
    it = eng.rounds()
    next(it); next(it)
    path = str(tmp_path / "pop.ckpt")
    eng.save(path)
    tail = list(it)
    resumed = make_engine(cfg, train, test, n_classes=10, resume=path)
    np.testing.assert_array_equal(
        resumed._population.estimates, eng._population.estimates
    ) if len(tail) == 0 else None
    tail2 = list(resumed.rounds())
    assert [r.selected for r in tail] == [r.selected for r in tail2]
    assert [r.test_acc for r in tail] == [r.test_acc for r in tail2]


# ---------------------------------------------------------------------------
# energy ledger (ROADMAP (q))
# ---------------------------------------------------------------------------
def test_device_profile_energy_defaults_tier_derived():
    from repro.systems.profiles import make_profile

    p = make_profile("mobile_mix", 32, seed=0)
    assert p.energy_per_step.shape == (32,) and (p.energy_per_step > 0).all()
    assert p.battery_mah.shape == (32,) and (p.battery_mah > 0).all()
    # weaker tiers burn more per step and carry smaller batteries
    lo, hi = p.tier.min(), p.tier.max()
    if lo != hi:
        assert (p.energy_per_step[p.tier == hi].mean()
                > p.energy_per_step[p.tier == lo].mean())
        assert (p.battery_mah[p.tier == hi].mean()
                < p.battery_mah[p.tier == lo].mean())


def test_energy_metrics_reported_every_round(data):
    from repro.engine import make_engine

    train, test = data
    cfg = fl_cfg(rounds=3, eval_every=2,
                 systems={"profile": "mobile_mix", "track_energy": True})
    eng = make_engine(cfg, train, test, n_classes=10)
    total = 0.0
    for r in eng.rounds():
        assert r.metrics is not None
        assert r.metrics["energy_mah"] >= 0.0
        assert r.metrics["energy_total_mah"] >= total
        total = r.metrics["energy_total_mah"]
    assert total > 0.0
    assert eng._systems.energy_total_mah == pytest.approx(total)


def test_energy_depletion_gates_availability(data):
    from repro.engine import make_engine

    train, test = data
    cfg = fl_cfg(rounds=4, systems={"track_energy": True})
    eng = make_engine(cfg, train, test, n_classes=10)
    # drain three clients up front: they must never be selected
    eng._systems.battery_mah[[0, 1, 2]] = 0.0
    assert not eng._systems.available(0)[:3].any()
    for r in eng.rounds():
        assert not ({0, 1, 2} & set(r.selected))
        assert r.metrics["n_depleted"] >= 3


def test_energy_spend_clips_at_empty():
    from repro.systems.config import SystemsConfig
    from repro.systems.runtime import SystemsRuntime

    rt = SystemsRuntime(
        SystemsConfig(track_energy=True), n_clients=4,
        steps=np.array([5, 5, 5, 5]), n_params=10,
    )
    rt.battery_mah[:] = 0.01  # less than one round's draw
    out = rt.spend_energy(0, np.array([0, 1]))
    assert out["energy_mah"] == pytest.approx(0.02)
    assert (rt.battery_mah[:2] == 0.0).all()
    assert out["n_depleted"] == 2
    # a drained client is offline at the next round's gate
    assert not rt.available(1)[:2].any()


def test_energy_state_dict_roundtrip_and_off_contract():
    from repro.systems.config import SystemsConfig
    from repro.systems.runtime import SystemsRuntime

    def mk(track):
        return SystemsRuntime(
            SystemsConfig(track_energy=track), n_clients=3,
            steps=np.array([2, 2, 2]), n_params=10,
        )
    off = mk(False)
    assert off.state_dict() == {}  # stateless contract unchanged
    with pytest.raises(ValueError):
        off.load_state_dict({"battery_mah": [1.0, 1.0, 1.0]})
    on = mk(True)
    on.spend_energy(0, np.array([0]))
    st = on.state_dict()
    assert set(st) == {"battery_mah", "energy_total_mah"}
    on2 = mk(True)
    on2.load_state_dict(st)
    np.testing.assert_array_equal(on2.battery_mah, on.battery_mah)
    assert on2.energy_total_mah == on.energy_total_mah
    with pytest.raises(ValueError):
        mk(True).load_state_dict({})


def test_energy_checkpoint_resume_bitidentical(tmp_path, data):
    from repro.engine import make_engine

    train, test = data
    cfg = fl_cfg(rounds=4, systems={"profile": "mobile_mix",
                                    "track_energy": True})
    eng = make_engine(cfg, train, test, n_classes=10)
    it = eng.rounds()
    next(it); next(it)
    path = str(tmp_path / "energy.ckpt")
    eng.save(path)
    tail = list(it)
    resumed = make_engine(cfg, train, test, n_classes=10, resume=path)
    tail2 = list(resumed.rounds())
    for a, b in zip(tail, tail2):
        assert a.selected == b.selected
        assert a.metrics["energy_total_mah"] == b.metrics["energy_total_mah"]
        assert a.metrics["n_depleted"] == b.metrics["n_depleted"]
