"""Checkpoint layer: serializer verification, save policies, the JSONL
tracker, and the engine-level kill-and-resume contract (DESIGN.md §12).

The acceptance bar pinned here: restoring a mid-run checkpoint and
finishing yields *bit-identical* params, selections, and history vs an
uninterrupted run of the same config — on every backend, with and
without the systems layer.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fl_cfg as _cfg
from repro.checkpoint import (
    Checkpointer,
    CheckpointPolicy,
    JsonlTracker,
    latest_checkpoint,
    load_checkpoint,
    read_jsonl,
    save_checkpoint,
)
from repro.engine import make_engine


# ------------------------------------------------------------ serializer
def _tree():
    return {
        "w": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.ones(3, np.float64),
        "step": np.int32(7),
        "nested": {"k": jnp.arange(4, dtype=jnp.uint32)},
    }


def test_serializer_round_trip(tmp_path):
    path = str(tmp_path / "x.ckpt")
    save_checkpoint(path, _tree(), meta={"round": 3, "tag": "t"})
    out, meta = load_checkpoint(path, like=_tree())
    assert meta == {"round": 3, "tag": "t"}
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(_tree())):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not os.path.exists(path + ".tmp")  # atomic rename cleaned up


def test_serializer_rejects_dtype_mismatch(tmp_path):
    """The silent-corruption bug this PR fixes: a float64 restore into a
    float32 structure must fail, not reinterpret bytes."""
    path = str(tmp_path / "x.ckpt")
    save_checkpoint(path, {"w": np.zeros(4, np.float64)})
    with pytest.raises(ValueError, match="dtype mismatch at leaf 0"):
        load_checkpoint(path, like={"w": np.zeros(4, np.float32)})


def test_serializer_rejects_shape_mismatch(tmp_path):
    path = str(tmp_path / "x.ckpt")
    save_checkpoint(path, {"w": np.zeros((2, 3), np.float32)})
    with pytest.raises(ValueError, match="shape mismatch at leaf 0"):
        load_checkpoint(path, like={"w": np.zeros((3, 2), np.float32)})


def test_serializer_rejects_treedef_mismatch(tmp_path):
    path = str(tmp_path / "x.ckpt")
    save_checkpoint(path, {"w": np.zeros(4, np.float32)})
    with pytest.raises(ValueError, match="treedef does not match"):
        load_checkpoint(path, like={"other_key": np.zeros(4, np.float32)})
    with pytest.raises(ValueError, match="treedef does not match"):
        load_checkpoint(path, like=[np.zeros(4, np.float32)])


def test_serializer_rejects_bad_magic(tmp_path):
    path = str(tmp_path / "x.ckpt")
    with open(path, "wb") as f:
        f.write(b"not a checkpoint at all")
    with pytest.raises(ValueError, match="bad magic header"):
        load_checkpoint(path, like={"w": np.zeros(4)})


def test_serializer_rejects_truncated_file(tmp_path):
    path = str(tmp_path / "x.ckpt")
    save_checkpoint(path, _tree())
    raw = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(raw[: len(raw) // 2])
    with pytest.raises(ValueError):  # truncated envelope OR short payload
        load_checkpoint(path, like=_tree())


def test_serializer_rejects_corrupt_payload_length(tmp_path):
    import msgpack

    from repro.checkpoint.serializer import _MAGIC

    path = str(tmp_path / "x.ckpt")
    save_checkpoint(path, {"w": np.zeros(4, np.float32)})
    raw = open(path, "rb").read()
    payload = msgpack.unpackb(raw[len(_MAGIC):], raw=False)
    payload["leaves"][0]["data"] = payload["leaves"][0]["data"][:-4]
    with open(path, "wb") as f:
        f.write(_MAGIC + msgpack.packb(payload, use_bin_type=True))
    with pytest.raises(ValueError, match="payload length mismatch"):
        load_checkpoint(path, like={"w": np.zeros(4, np.float32)})


# ---------------------------------------------------------- save policy
def test_policy_round_trigger_is_absolute():
    p = CheckpointPolicy(every_rounds=3)
    assert [r for r in range(10) if p.round_due(r)] == [2, 5, 8]
    assert not p.time_due(1e9)  # no time trigger configured


def test_policy_validation():
    with pytest.raises(ValueError, match="every_rounds"):
        CheckpointPolicy(every_rounds=0)
    with pytest.raises(ValueError, match="every_seconds"):
        CheckpointPolicy(every_seconds=0.0)
    with pytest.raises(ValueError, match="keep_last"):
        CheckpointPolicy(keep_last=0)
    with pytest.raises(ValueError, match="no trigger"):
        CheckpointPolicy(every_rounds=None, every_seconds=None)


class _FakeEngine:
    """Just enough surface for Checkpointer.save()."""

    def __init__(self):
        self._round = 0
        self.saved = []

    def save(self, path):
        self.saved.append(path)
        with open(path, "w") as f:
            f.write("x")


def test_checkpointer_time_trigger_with_fake_clock(tmp_path):
    t = [0.0]
    ck = Checkpointer(str(tmp_path / "ck"),
                      CheckpointPolicy(every_rounds=None, every_seconds=10.0),
                      clock=lambda: t[0])
    eng = _FakeEngine()
    assert ck.maybe_save(eng, 0) is None      # 0s elapsed
    t[0] = 9.0
    assert ck.maybe_save(eng, 1) is None      # under the interval
    t[0] = 10.0
    assert ck.maybe_save(eng, 2) is not None  # due; resets the timer
    t[0] = 19.0
    assert ck.maybe_save(eng, 3) is None
    assert len(eng.saved) == 1


def test_checkpointer_keep_last_prunes(tmp_path):
    ck = Checkpointer(str(tmp_path / "ck"),
                      CheckpointPolicy(every_rounds=1, keep_last=2))
    eng = _FakeEngine()
    for rnd in range(5):
        eng._round = rnd + 1
        ck.maybe_save(eng, rnd)
    kept = sorted(os.listdir(ck.directory))
    assert kept == ["round_00000004.ckpt", "round_00000005.ckpt"]
    assert latest_checkpoint(ck.directory).endswith("round_00000005.ckpt")


def test_latest_checkpoint_missing_dir(tmp_path):
    assert latest_checkpoint(str(tmp_path / "nope")) is None
    os.makedirs(tmp_path / "empty")
    assert latest_checkpoint(str(tmp_path / "empty")) is None


# ------------------------------------------------------------- tracker
def test_jsonl_tracker_schema_and_dedupe(tmp_path, data):
    train, test = data
    path = str(tmp_path / "m.jsonl")
    engine = make_engine(_cfg(eval_every=2), train, test, n_classes=10,
                         tracker=JsonlTracker(path))
    list(engine.rounds())
    engine.close_trackers()
    lines = [json.loads(x) for x in open(path)]
    assert [row["round"] for row in lines] == [0, 1, 2]
    for row in lines:
        assert set(row) >= {"round", "selected", "mean_selected_loss",
                            "comm_mb", "test_loss", "test_acc", "sim_clock",
                            "n_dropped", "metrics"}
        assert isinstance(row["selected"], list)
    assert lines[1]["test_acc"] is None  # unevaluated rounds logged too
    # at-least-once: duplicate rounds collapse, last occurrence wins
    with open(path, "a") as f:
        dup = dict(lines[0], comm_mb=123.0)
        f.write(json.dumps(dup) + "\n")
    rows = read_jsonl(path)
    assert [row["round"] for row in rows] == [0, 1, 2]
    assert rows[0]["comm_mb"] == 123.0


# ---------------------------------------- engine kill-and-resume contract
def _equiv_cfg(backend, systems, **kw):
    sys_kw = None
    if systems:
        from repro.engine import SystemsConfig

        sys_kw = SystemsConfig(profile="mobile_mix", availability="markov",
                               deadline_s=30.0, over_select=1.3)
    return _cfg(rounds=4, eval_every=2, systems=sys_kw, **{
        "backend": "compiled" if backend == "fused" else backend,
        **({"fuse_rounds": 2} if backend == "fused" else {}),
        **kw,
    })


def _params_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def _assert_history_equal(a, b):
    """Bit-equality with NaN == NaN (an all-dropped round's mean loss)."""
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


@pytest.mark.parametrize("systems", [False, True], ids=["plain", "systems"])
@pytest.mark.parametrize("backend", ["host", "compiled", "scaleout", "fused"])
def test_kill_and_resume_bit_identical(backend, systems, data, tmp_path):
    """Acceptance: save at round 2, rebuild the engine from scratch,
    resume, finish — params, per-round selections, and history must be
    bit-identical to the uninterrupted run."""
    train, test = data
    def mk(**kw):
        return make_engine(_equiv_cfg(backend, systems), train, test,
                           n_classes=10, **kw)

    # the reference runs the same save policy (different directory): on
    # the fused backend save points shape the chunk pattern, and chunk
    # patterns must match for bit-level comparison
    policy = CheckpointPolicy(every_rounds=2)
    ref = mk(checkpointer=Checkpointer(str(tmp_path / "ref"), policy))
    ref_results = list(ref.rounds())
    ref_params = jax.device_get(ref.params)

    ckdir = str(tmp_path / "ck")
    killed = mk(checkpointer=Checkpointer(ckdir, policy))
    pre = []
    it = killed.rounds()
    for _ in range(2):
        pre.append(next(it))
    it.close()  # the "kill": mid-run abandonment after the round-2 save

    resumed = mk(resume=ckdir,
                 checkpointer=Checkpointer(ckdir, policy))
    assert resumed._round == 2
    post = list(resumed.rounds())  # default = the remaining rounds

    assert [r.round for r in pre + post] == [0, 1, 2, 3]
    assert [r.selected for r in pre + post] == [r.selected for r in ref_results]
    assert [r.evaluated for r in pre + post] == [r.evaluated for r in ref_results]
    assert [r.comm_mb for r in pre + post] == [r.comm_mb for r in ref_results]
    if systems:
        assert [r.sim_clock for r in pre + post] == [
            r.sim_clock for r in ref_results
        ]
    _assert_history_equal(resumed.history, ref.history)
    assert _params_equal(ref_params, jax.device_get(resumed.params))


def test_resume_restores_feddyn_server_and_client_state(data, tmp_path):
    """agg_state (FedDyn h) and h_clients (per-client drift) ride the
    checkpoint: a resumed FedDyn run matches the uninterrupted one."""
    train, test = data
    def mk(**kw):
        return make_engine(
            _cfg(rounds=4, aggregator="feddyn", client_mode="feddyn", mu=0.1),
            train, test, n_classes=10, **kw)

    ref = mk()
    ref.run()

    path = str(tmp_path / "fd.ckpt")
    killed = mk()
    it = killed.rounds()
    next(it), next(it)
    it.close()
    killed.save(path)

    resumed = mk()
    resumed.restore(path)
    h = resumed.run()
    _assert_history_equal(h, ref.history)
    assert _params_equal(jax.device_get(ref.params),
                         jax.device_get(resumed.params))
    assert _params_equal(jax.device_get(ref.agg_state),
                         jax.device_get(resumed.agg_state))
    assert _params_equal(jax.device_get(ref.h_clients),
                         jax.device_get(resumed.h_clients))


def test_restore_rejects_config_mismatch(data, tmp_path):
    train, test = data
    path = str(tmp_path / "x.ckpt")
    make_engine(_cfg(), train, test, n_classes=10).save(path)
    other = make_engine(_cfg(m=5), train, test, n_classes=10)
    with pytest.raises(ValueError, match=r"config does not match.*'m'"):
        other.restore(path)


def test_resume_empty_dir_fails_loudly(data, tmp_path):
    train, test = data
    os.makedirs(tmp_path / "ck")
    with pytest.raises(FileNotFoundError, match="no round_"):
        make_engine(_cfg(), train, test, n_classes=10,
                    resume=str(tmp_path / "ck"))


def test_fused_chunk_boundaries_align_with_save_points(data, tmp_path):
    """With fuse_rounds=4 and a save-every-3 policy, chunks must clip at
    rounds 2 and 5 so every due save fires on committed chunk-boundary
    state — and the saved files must exist at exactly those rounds."""
    train, test = data
    ckdir = str(tmp_path / "ck")
    engine = make_engine(
        _cfg(backend="compiled", fuse_rounds=4, rounds=6, eval_every=100),
        train, test, n_classes=10,
        checkpointer=Checkpointer(ckdir, CheckpointPolicy(every_rounds=3)),
    )
    list(engine.rounds())
    assert sorted(os.listdir(ckdir)) == [
        "round_00000003.ckpt", "round_00000006.ckpt",
    ]
    # chunk pattern [0][1,2][3,4,5]: round 0 evaluates, then chunks clip
    # at the save points (rounds 2 and 5), never spanning one
    assert sorted(engine._chunk_cache) == [1, 2, 3]
