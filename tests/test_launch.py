"""Launch-layer tests: input specs, long-context variants, CLI drivers."""

import os
import subprocess
import sys

import pytest

from repro.configs import INPUT_SHAPES, get_config, list_configs
from repro.configs.inputs import decode_specs, input_specs, long_context_variant

_ENV = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def test_input_shapes_table():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["decode_32k"].kind == "decode"


@pytest.mark.parametrize("arch", list_configs())
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k"])
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    sh = INPUT_SHAPES[shape]
    specs = input_specs(cfg, sh)
    if cfg.input_mode == "tokens":
        assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
    elif cfg.input_mode == "frames":
        assert specs["frames"].shape == (sh.global_batch, sh.seq_len, cfg.d_model)
    else:
        assert specs["patches"].shape == (sh.global_batch, cfg.n_patches, cfg.d_model)
        assert specs["tokens"].shape == (sh.global_batch, sh.seq_len - cfg.n_patches)
    if sh.kind == "train":
        assert specs["labels"].shape == (sh.global_batch, sh.seq_len)
    d = decode_specs(cfg, INPUT_SHAPES["decode_32k"])
    key = "frame" if cfg.input_mode == "frames" else "token"
    assert d[key].shape[0] == 128


def test_long_context_variant_policy():
    # native sub-quadratic archs unchanged
    for arch in ("xlstm-125m", "hymba-1.5b", "gemma3-27b"):
        cfg = get_config(arch)
        assert long_context_variant(cfg).name == cfg.name
    # full-attention archs get the documented SWA variant
    for arch in ("qwen3-14b", "deepseek-v3-671b", "musicgen-large"):
        v = long_context_variant(get_config(arch))
        assert v.name.endswith("+swa4k")
        assert v.sliding_window == 4096
        assert v.layer_pattern == "L"


@pytest.mark.slow
def test_train_cli_reduced():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "xlstm-125m",
         "--reduced", "--steps", "8", "--batch", "2", "--seq", "64",
         "--log-every", "4"],
        env=_ENV, capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "step    0" in r.stdout


@pytest.mark.slow
def test_serve_cli_reduced():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-14b",
         "--reduced", "--batch", "2", "--prompt-len", "32", "--gen", "4"],
        env=_ENV, capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decoded" in r.stdout