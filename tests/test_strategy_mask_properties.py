"""Property tests for the jit-compatible strategy masks.

For random loss vectors, every strategy advertising
``supports_compiled_selection`` must produce a ``select_mask_jax`` mask
with exactly ``n_selected`` true entries that agrees with its numpy
``select`` under the same inputs and rng state — the invariant the
cross-backend conformance suite (and the mask-gated backends) rest on.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)

from hypothesis import given, settings, strategies as st

from repro.core.strategies import get_strategy
from repro.engine import mask_selection_strategies

MASK_STRATEGIES = mask_selection_strategies()


@st.composite
def mask_case(draw):
    """(K, m, hists, sizes, losses, seed) — planted-mode histograms so the
    cluster-based strategies find real structure; losses drawn continuous
    (ties are measure-zero and tie-break conventions already match)."""
    k = draw(st.integers(6, 48))
    m = draw(st.integers(1, k))
    g = draw(st.integers(1, 5))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    modes = rng.dirichlet(np.ones(10) * 0.2, size=g)
    assign = rng.integers(0, g, k)
    hists = np.stack([rng.dirichlet(modes[a] * 200.0 + 1e-3) for a in assign])
    sizes = rng.integers(20, 200, k).astype(np.float64)
    losses = rng.uniform(0.1, 5.0, k).astype(np.float32)
    return k, m, hists, sizes, losses, seed


def _setup(name, k, m, hists, sizes, seed):
    s = get_strategy(name, m=m)
    s.setup(hists, sizes, seed=seed)
    return s


@pytest.mark.parametrize("name", MASK_STRATEGIES)
@given(case=mask_case())
@settings(max_examples=25, deadline=None)
def test_mask_has_exactly_n_selected_true_entries(name, case):
    k, m, hists, sizes, losses, seed = case
    s = _setup(name, k, m, hists, sizes, seed)
    mask = np.asarray(
        s.select_mask_jax(jnp.asarray(losses), np.random.default_rng(seed))
    )
    assert mask.shape == (k,) and mask.dtype == bool
    assert int(mask.sum()) == min(m, k)


@pytest.mark.parametrize("name", MASK_STRATEGIES)
@given(case=mask_case())
@settings(max_examples=25, deadline=None)
def test_mask_agrees_with_numpy_select(name, case):
    """Two identically-seeded rng streams — one consumed by ``select``,
    one by ``select_mask_jax`` — must yield the same participant set."""
    k, m, hists, sizes, losses, seed = case
    s = _setup(name, k, m, hists, sizes, seed)
    sel = s.select(0, losses, np.random.default_rng(seed + 1))
    mask = np.asarray(
        s.select_mask_jax(jnp.asarray(losses), np.random.default_rng(seed + 1))
    )
    np.testing.assert_array_equal(np.where(mask)[0], sel)


def test_mask_strategies_need_rng_fail_loud():
    """Strategies with host-side per-round randomness reject rng=None
    instead of silently desynchronizing from the host backend."""
    rng = np.random.default_rng(0)
    hists = rng.dirichlet(np.ones(10), size=12)
    for name in ("poc", "clusterrandom"):
        s = _setup(name, 12, 4, hists, np.full(12, 50.0), 0)
        with pytest.raises(ValueError, match="rng"):
            s.select_mask_jax(jnp.zeros(12, jnp.float32))
