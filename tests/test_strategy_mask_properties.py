"""Property tests for the jit-compatible strategy masks and the async
server rule's pure cores.

For random loss vectors, every strategy advertising
``supports_compiled_selection`` must produce a ``select_mask_jax`` mask
with exactly ``n_selected`` true entries that agrees with its numpy
``select`` under the same inputs and rng state — the invariant the
cross-backend conformance suite (and the mask-gated backends) rest on.

The staleness-weight properties (DESIGN.md §13) pin the async
aggregation rule for arbitrary buffers: weights are non-negative, sum
to 1 over the surviving mass (all-zero when nothing survives), and are
permutation-equivariant in the arrival order — so the aggregate update
is invariant to how the buffer happened to be ordered.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)

from hypothesis import given, settings, strategies as st

from repro.core.strategies import get_strategy
from repro.engine import mask_selection_strategies

MASK_STRATEGIES = mask_selection_strategies()


@st.composite
def mask_case(draw):
    """(K, m, hists, sizes, losses, seed) — planted-mode histograms so the
    cluster-based strategies find real structure; losses drawn continuous
    (ties are measure-zero and tie-break conventions already match)."""
    k = draw(st.integers(6, 48))
    m = draw(st.integers(1, k))
    g = draw(st.integers(1, 5))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    modes = rng.dirichlet(np.ones(10) * 0.2, size=g)
    assign = rng.integers(0, g, k)
    hists = np.stack([rng.dirichlet(modes[a] * 200.0 + 1e-3) for a in assign])
    sizes = rng.integers(20, 200, k).astype(np.float64)
    losses = rng.uniform(0.1, 5.0, k).astype(np.float32)
    return k, m, hists, sizes, losses, seed


def _setup(name, k, m, hists, sizes, seed):
    s = get_strategy(name, m=m)
    s.setup(hists, sizes, seed=seed)
    return s


@pytest.mark.parametrize("name", MASK_STRATEGIES)
@given(case=mask_case())
@settings(max_examples=25, deadline=None)
def test_mask_has_exactly_n_selected_true_entries(name, case):
    k, m, hists, sizes, losses, seed = case
    s = _setup(name, k, m, hists, sizes, seed)
    mask = np.asarray(
        s.select_mask_jax(jnp.asarray(losses), np.random.default_rng(seed))
    )
    assert mask.shape == (k,) and mask.dtype == bool
    assert int(mask.sum()) == min(m, k)


@pytest.mark.parametrize("name", MASK_STRATEGIES)
@given(case=mask_case())
@settings(max_examples=25, deadline=None)
def test_mask_agrees_with_numpy_select(name, case):
    """Two identically-seeded rng streams — one consumed by ``select``,
    one by ``select_mask_jax`` — must yield the same participant set."""
    k, m, hists, sizes, losses, seed = case
    s = _setup(name, k, m, hists, sizes, seed)
    sel = s.select(0, losses, np.random.default_rng(seed + 1))
    mask = np.asarray(
        s.select_mask_jax(jnp.asarray(losses), np.random.default_rng(seed + 1))
    )
    np.testing.assert_array_equal(np.where(mask)[0], sel)


# ---------------------------------------- async staleness weights (§13)
@st.composite
def staleness_case(draw):
    """(sizes, staleness, discount, max_staleness, perm) — an arbitrary
    popped buffer plus a permutation of its arrival order."""
    n = draw(st.integers(1, 12))
    sizes = np.asarray(
        draw(st.lists(st.floats(1.0, 500.0), min_size=n, max_size=n))
    )
    stal = np.asarray(
        draw(st.lists(st.integers(0, 20), min_size=n, max_size=n)), np.int64
    )
    name, kwargs = draw(st.sampled_from([
        ("constant", {}),
        ("constant", {"factor": 0.5}),
        ("polynomial", {"a": 0.5}),
        ("polynomial", {"a": 2.0}),
        ("exponential", {"gamma": 0.5}),
    ]))
    max_s = draw(st.one_of(st.none(), st.integers(0, 20)))
    perm = np.random.default_rng(draw(st.integers(0, 2**31 - 1))).permutation(n)
    return sizes, stal, name, kwargs, max_s, perm


@given(case=staleness_case())
@settings(max_examples=200, deadline=None)
def test_staleness_weights_nonnegative_unit_sum(case):
    from repro.engine.async_config import (
        make_staleness_discount,
        staleness_weights,
    )

    sizes, stal, name, kwargs, max_s, _perm = case
    w = staleness_weights(sizes, stal, make_staleness_discount(name, **kwargs),
                          max_s)
    assert w.shape == sizes.shape and (w >= 0.0).all()
    survivors = max_s is None or bool((stal <= max_s).any())
    if survivors:
        assert w.sum() == pytest.approx(1.0)
        # the zero-weight drop is exact, not approximate
        if max_s is not None:
            assert (w[stal > max_s] == 0.0).all()
    else:
        np.testing.assert_array_equal(w, np.zeros_like(w))


@given(case=staleness_case())
@settings(max_examples=200, deadline=None)
def test_staleness_weights_permutation_equivariant(case):
    """Permuting the buffer's arrival order permutes the weights with
    it — so the weighted aggregate is order-invariant."""
    from repro.engine.async_config import (
        make_staleness_discount,
        staleness_weights,
    )

    sizes, stal, name, kwargs, max_s, perm = case
    d = make_staleness_discount(name, **kwargs)
    w = staleness_weights(sizes, stal, d, max_s)
    w_perm = staleness_weights(sizes[perm], stal[perm], d, max_s)
    np.testing.assert_allclose(w_perm, w[perm], rtol=1e-12, atol=0.0)
    # ... hence the aggregate over any scalar client quantity agrees
    x = sizes * 3.0 - stal
    assert float(w_perm @ x[perm]) == pytest.approx(float(w @ x))


def test_mask_strategies_need_rng_fail_loud():
    """Strategies with host-side per-round randomness reject rng=None
    instead of silently desynchronizing from the host backend."""
    rng = np.random.default_rng(0)
    hists = rng.dirichlet(np.ones(10), size=12)
    for name in ("poc", "clusterrandom"):
        s = _setup(name, 12, 4, hists, np.full(12, 50.0), 0)
        with pytest.raises(ValueError, match="rng"):
            s.select_mask_jax(jnp.zeros(12, jnp.float32))
