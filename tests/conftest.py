"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count override here — tests see the real
single CPU device.  Multi-device behaviour (shard_map, dry-run) is
tested via subprocesses that set the flag before importing jax
(test_scaleout.py, test_dryrun_mini.py).
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def data():
    """The canonical tiny synthetic FL task shared by the engine and
    cross-backend conformance suites (immutable, so session-scoped)."""
    from repro.data import make_classification

    train = make_classification(800, n_features=64, n_classes=10, seed=0)
    test = make_classification(200, n_features=64, n_classes=10, seed=1)
    return train, test


LM_VOCAB = 32


@pytest.fixture(scope="session")
def lm_data():
    """Tiny Markov token streams for the LM-task conformance grid."""
    from repro.data.synthetic import make_token_stream

    train = make_token_stream(48, 16, LM_VOCAB, seed=0)
    test = make_token_stream(16, 16, LM_VOCAB, seed=1)
    return train, test


def fl_cfg(**kw):
    """The canonical tiny-task FLConfig (12 clients, m=4, 3 rounds).
    Overriding ``strategy`` without ``strategy_kwargs`` resets the
    fedlecc-specific kwargs."""
    from repro.engine import FLConfig

    defaults = dict(
        n_clients=12, m=4, rounds=3, strategy="fedlecc",
        strategy_kwargs={"J": 3}, hidden=(16,), eval_samples=16,
        eval_every=1, target_hd=0.8, seed=0,
    )
    if "strategy" in kw and "strategy_kwargs" not in kw:
        defaults["strategy_kwargs"] = {}
    defaults.update(kw)
    return FLConfig(**defaults)


def lm_fl_cfg(**kw):
    """The canonical tiny LM-task FLConfig: a micro attention model
    (cheap to compile — the grid builds one engine per strategy ×
    backend cell) over ``lm_data`` token streams."""
    from repro.engine import FLConfig

    defaults = dict(
        task="lm",
        task_kwargs={
            "model": "stablelm-3b",
            "overrides": {"d_model": 32, "n_heads": 2, "n_kv_heads": 2,
                          "head_dim": 16, "d_ff": 64, "vocab": LM_VOCAB,
                          "loss_chunk": 16, "attn_chunk": 16, "remat": False},
            "hist_bins": 16,
        },
        n_clients=8, m=3, rounds=2, strategy="fedlecc",
        strategy_kwargs={"J": 2}, batch_size=4, eval_samples=4,
        eval_every=1, target_hd=0.8, max_steps_cap=3, seed=0,
    )
    if "strategy" in kw and "strategy_kwargs" not in kw:
        defaults["strategy_kwargs"] = {}
    defaults.update(kw)
    return FLConfig(**defaults)


def planted_histograms(rng, K=60, C=10, G=4, conc=200.0):
    """Label histograms with G planted modes (used across cluster tests)."""
    modes = rng.dirichlet(np.ones(C) * 0.2, size=G)
    assign = rng.integers(0, G, K)
    hists = np.stack([rng.dirichlet(modes[g] * conc + 1e-3) for g in assign])
    return hists, assign
