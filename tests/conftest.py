"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count override here — tests see the real
single CPU device.  Multi-device behaviour (shard_map, dry-run) is
tested via subprocesses that set the flag before importing jax
(test_scaleout.py, test_dryrun_mini.py).
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def planted_histograms(rng, K=60, C=10, G=4, conc=200.0):
    """Label histograms with G planted modes (used across cluster tests)."""
    modes = rng.dirichlet(np.ones(C) * 0.2, size=G)
    assign = rng.integers(0, G, K)
    hists = np.stack([rng.dirichlet(modes[g] * conc + 1e-3) for g in assign])
    return hists, assign
