"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aggregate.ops import aggregate_pytree_pallas, masked_weighted_sum_pallas
from repro.kernels.aggregate.ref import masked_weighted_sum_ref
from repro.kernels.flash_attention.ops import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hellinger.ops import hellinger_matrix_pallas
from repro.kernels.hellinger.ref import hellinger_matrix_ref


@pytest.mark.parametrize("k,c", [(16, 4), (100, 10), (129, 33), (256, 128)])
def test_hellinger_kernel_sweep(k, c):
    rng = np.random.default_rng(k + c)
    h = rng.dirichlet(np.ones(c) * 0.5, size=k)
    got = np.asarray(hellinger_matrix_pallas(jnp.asarray(h), interpret=True))
    want = np.asarray(hellinger_matrix_ref(jnp.asarray(h)))
    np.testing.assert_allclose(got, want, atol=2e-6)


@pytest.mark.parametrize(
    "b,s,h,kv,d,window,dtype",
    [
        (1, 128, 2, 1, 64, 0, jnp.float32),
        (2, 256, 4, 2, 32, 0, jnp.float32),
        (1, 128, 4, 4, 128, 64, jnp.float32),
        (2, 128, 2, 1, 64, 0, jnp.bfloat16),
    ],
)
def test_flash_kernel_sweep(b, s, h, kv, d, window, dtype):
    rng = np.random.default_rng(s + h + d)
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), dtype)
    ig = 0.0 if window else 1.0
    got = flash_attention_pallas(q, k, v, window=window, is_global=ig,
                                 bq=64, bk=64, interpret=True)
    kk = jnp.repeat(k, h // kv, axis=2)
    vv = jnp.repeat(v, h // kv, axis=2)
    want = attention_ref(
        jnp.transpose(q, (0, 2, 1, 3)), jnp.transpose(kk, (0, 2, 1, 3)),
        jnp.transpose(vv, (0, 2, 1, 3)), window=window, is_global=ig,
    )
    want = jnp.transpose(want, (0, 2, 1, 3))
    atol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol
    )


@pytest.mark.parametrize("m,n", [(1, 512), (10, 1000), (64, 70_000), (3, 513)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_aggregate_kernel_sweep(m, n, dtype):
    rng = np.random.default_rng(m * n % 977)
    x = jnp.asarray(rng.normal(0, 1, (m, n)), dtype)
    w = jnp.asarray(rng.uniform(0, 1, m) * (rng.random(m) > 0.3), jnp.float32)
    got = np.asarray(masked_weighted_sum_pallas(x, w, interpret=True))
    want = np.asarray(masked_weighted_sum_ref(x, w))
    atol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(got, want, atol=atol)


@pytest.mark.parametrize(
    "b,s,d,n,bt,bd",
    [(2, 64, 32, 8, 32, 32), (1, 128, 256, 16, 64, 128), (2, 100, 130, 16, 64, 128)],
)
def test_mamba_scan_kernel_sweep(b, s, d, n, bt, bd):
    from repro.kernels.mamba_scan.ops import mamba_scan_pallas
    from repro.kernels.mamba_scan.ref import mamba_scan_ref

    rng = np.random.default_rng(s + d)
    x = jnp.asarray(rng.normal(0, 0.5, (b, s, d)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.05, 0.02, (b, s, d))), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 1, (b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (b, s, n)), jnp.float32)
    al = jnp.asarray(np.log(np.tile(np.arange(1, n + 1, dtype=np.float32), (d, 1))))
    ds = jnp.asarray(rng.normal(1, 0.1, (d,)), jnp.float32)
    got = mamba_scan_pallas(x, dt, bm, cm, al, ds, bt=bt, bd=bd, interpret=True)
    want = mamba_scan_ref(x, dt, bm, cm, al, ds)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_mamba_scan_matches_model_path():
    """The kernel oracle agrees with the model's chunked associative-scan
    path given the same discretization inputs."""
    import jax as _jax

    from repro.configs.base import ModelConfig, SSMConfig
    from repro.kernels.mamba_scan.ref import mamba_scan_ref
    from repro.models.ssm import _ssm_coeffs, chunked_linear_scan, init_mamba

    cfg = ModelConfig(
        name="m", family="hybrid", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=100, dtype="float32", block_type="hymba",
        ssm=SSMConfig(d_state=8, conv_kernel=4, chunk=8),
    )
    p = init_mamba(_jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x_in = jnp.asarray(np.abs(rng.normal(0, 0.5, (2, 32, 32))), jnp.float32)
    a, b_, cmat, dx = _ssm_coeffs(p, x_in)
    h_all, _ = chunked_linear_scan(a, b_, jnp.zeros((2, 32, 8)), 8)
    y_model = jnp.einsum("bsdn,bsn->bsd", h_all, cmat) + dx
    dt = jax.nn.softplus(x_in * p["w_dt"] + p["b_dt"]) if False else None
    import jax.nn

    dt = jax.nn.softplus(x_in.astype(jnp.float32) * p["w_dt"] + p["b_dt"])
    bm = x_in.astype(jnp.float32) @ p["w_b"].astype(jnp.float32)
    cm = x_in.astype(jnp.float32) @ p["w_c"].astype(jnp.float32)
    y_kernel = mamba_scan_ref(x_in, dt, bm, cm, p["a_log"], p["d_skip"])
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model), atol=1e-4)


def test_aggregate_pytree_matches_fedavg():
    """The Pallas FedAvg reduce ≡ repro.federated.aggregation.fedavg."""
    import jax

    from repro.federated.aggregation import fedavg

    rng = np.random.default_rng(0)
    stacked = {
        "w": jnp.asarray(rng.normal(0, 1, (5, 7, 11)), jnp.float32),
        "b": jnp.asarray(rng.normal(0, 1, (5, 13)), jnp.float32),
    }
    w = np.zeros(5, np.float32)
    w[[1, 3]] = [0.25, 0.75]                       # FedLECC mask: 2 of 5 selected
    got = aggregate_pytree_pallas(stacked, jnp.asarray(w), interpret=True)
    want = fedavg(stacked, jnp.asarray(w))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
