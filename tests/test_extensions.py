"""Beyond-paper extensions: quantized aggregation + adaptive-J FedLECC."""

import jax
import jax.numpy as jnp
import numpy as np

from conftest import planted_histograms
from repro.core.strategies import get_strategy
from repro.federated.aggregation import fedavg
from repro.federated.compression import (
    compressed_fedavg, dequantize_delta, quantize_delta,
)


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    delta = {"w": jnp.asarray(rng.normal(0, 0.1, (50, 40)), jnp.float32)}
    qt = quantize_delta(delta, jax.random.PRNGKey(0), bits=8)
    deq = dequantize_delta(qt)
    # max error ≤ 1 quantization step = max|x| / 127
    step = float(jnp.max(jnp.abs(delta["w"]))) / 127
    assert float(jnp.max(jnp.abs(deq["w"] - delta["w"]))) <= step + 1e-7


def test_quantization_unbiased():
    """Stochastic rounding: E[deq] == delta (mean over many draws)."""
    delta = {"w": jnp.full((1000,), 0.0173, jnp.float32)}
    acc = np.zeros(1000)
    for i in range(50):
        deq = dequantize_delta(quantize_delta(delta, jax.random.PRNGKey(i)))
        acc += np.asarray(deq["w"])
    assert abs(acc.mean() / 50 - 0.0173) < 2e-4


def test_compressed_fedavg_close_to_exact():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(0, 1, (30, 20)), jnp.float32)}
    stacked = {"w": g["w"][None] + jnp.asarray(rng.normal(0, 0.05, (4, 30, 20)), jnp.float32)}
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    exact = fedavg(stacked, w)
    got, err = compressed_fedavg(stacked, g, w, jax.random.PRNGKey(0), bits=8)
    # deltas ~0.05 → int8 step ~ 0.15/127 ≈ 1e-3; weighted sum stays close
    assert float(jnp.max(jnp.abs(got["w"] - exact["w"]))) < 5e-3
    assert float(err) < 2e-3


def test_compressed_fedavg_respects_mask():
    rng = np.random.default_rng(2)
    g = {"w": jnp.zeros((10,), jnp.float32)}
    stacked = {"w": jnp.asarray(rng.normal(0, 1, (3, 10)), jnp.float32)}
    w = jnp.asarray([0.0, 1.0, 0.0])   # FedLECC mask: only client 1
    got, _ = compressed_fedavg(stacked, g, w, jax.random.PRNGKey(0))
    np.testing.assert_allclose(
        np.asarray(got["w"]), np.asarray(stacked["w"][1]), atol=1e-2
    )


def test_adaptive_j_valid_and_reactive(rng):
    hists, _ = planted_histograms(rng, K=60, G=5)
    s = get_strategy("fedlecc_adaptive", m=10)
    s.setup(hists, np.full(60, 100), seed=0)
    # flat losses → spread (large J)
    flat = np.ones(60)
    sel_flat = s.select(0, flat, np.random.default_rng(0))
    assert len(sel_flat) == 10
    # one cluster dominating → concentrate
    peaked = np.ones(60)
    peaked[s.labels == s.labels[0]] = 10.0
    sel_peak = s.select(1, peaked, np.random.default_rng(1))
    assert len(sel_peak) == 10
    n_clusters_flat = len(np.unique(s.labels[sel_flat]))
    n_clusters_peak = len(np.unique(s.labels[sel_peak]))
    assert n_clusters_peak <= n_clusters_flat
