"""The systems axis (``FLConfig.systems``, ``repro.systems``, DESIGN.md
§10): device profiles, availability traces, the wall-clock round
simulation, and deadline/over-selection semantics.

Covers the PR's acceptance surface:

- ``systems=None`` (the default) stays bit-identical to the
  frictionless engine, and an *inert* systems config (uniform profile,
  everyone always on, no deadline, over_select=1) matches it too;
- availability-gated masks are identical across host / compiled / fused
  backends (one shared exogenous trace);
- deadline drops reweight the survivors to a unit-sum weight vector;
- a deadline + over-selection configuration reaches the target accuracy
  in less simulated wall-clock than the no-deadline baseline;
- the compiled cohort train and the fused chunks keep their
  no-retrace guarantees with systems enabled;
- HACCS's latency tiebreak consumes the profile-derived latency;
- the LM task surfaces held-out perplexity (total and per topic
  cluster) in ``RoundResult.metrics`` and the run history.
"""

import jax
import numpy as np
import pytest

from conftest import LM_VOCAB, fl_cfg as _cfg, lm_fl_cfg as _lm_cfg
from repro.core.selection import selection_weights
from repro.engine import FLConfig, SystemsConfig, make_engine
from repro.systems import (
    RoundClock,
    list_availability_models,
    list_profiles,
    make_availability,
    make_profile,
    round_outcome,
)


def _max_err(a, b):
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# ------------------------------------------------------------- config
def test_systems_config_validation_and_round_trip():
    with pytest.raises(ValueError, match="unknown device profile"):
        SystemsConfig(profile="datacenter")
    with pytest.raises(ValueError, match="unknown availability model"):
        SystemsConfig(availability="solar_flare")
    with pytest.raises(ValueError, match="over_select"):
        SystemsConfig(over_select=0.5)
    with pytest.raises(ValueError, match="deadline_s"):
        SystemsConfig(deadline_s=0.0)
    with pytest.raises(ValueError, match="jitter_sigma"):
        SystemsConfig(jitter_sigma=-1.0)
    with pytest.raises(ValueError, match="unknown SystemsConfig keys"):
        SystemsConfig.from_dict({"profile": "uniform", "bogus": 1})
    with pytest.raises(ValueError, match="systems must be"):
        _cfg(systems=42)

    import json

    cfg = _cfg(systems=SystemsConfig(
        profile="mobile_mix", availability="markov",
        availability_kwargs={"p_drop": 0.2, "p_join": 0.6},
        deadline_s=30.0, over_select=1.3, jitter_sigma=0.2,
    ))
    d = json.loads(json.dumps(cfg.to_dict()))
    assert isinstance(d["systems"], dict)  # JSON-safe nested form
    restored = FLConfig.from_dict(d)
    assert restored == cfg and isinstance(restored.systems, SystemsConfig)
    # the frictionless default serializes as null and restores as None
    assert _cfg().to_dict()["systems"] is None
    assert FLConfig.from_dict(_cfg().to_dict()).systems is None


def test_systems_config_m_effective():
    sc = SystemsConfig(over_select=1.3)
    assert sc.m_effective(10, 100) == 13
    assert sc.m_effective(10, 12) == 12           # clipped to the population
    assert SystemsConfig().m_effective(10, 100) == 10


# ------------------------------------------------------------ profiles
def test_profile_presets_registered_and_shaped():
    assert {"uniform", "zipf_compute", "mobile_mix"} <= set(list_profiles())
    assert {"always", "bernoulli", "markov"} <= set(list_availability_models())
    for name in ("uniform", "zipf_compute", "mobile_mix"):
        p = make_profile(name, 40, seed=0)
        assert p.n_clients == 40
        for arr in (p.compute_speed, p.down_mbps, p.up_mbps):
            assert arr.shape == (40,) and (arr > 0).all()
    # deterministic per seed, different across seeds
    a, b = make_profile("mobile_mix", 40, seed=0), make_profile("mobile_mix", 40, seed=0)
    np.testing.assert_array_equal(a.compute_speed, b.compute_speed)
    c = make_profile("mobile_mix", 40, seed=1)
    assert not np.array_equal(a.compute_speed, c.compute_speed)
    # uniform really is uniform; the mixes really spread
    u = make_profile("uniform", 40)
    assert np.ptp(u.compute_speed) == 0.0
    assert np.ptp(make_profile("zipf_compute", 40).compute_speed) > 0
    with pytest.raises(ValueError, match="unknown device profile"):
        make_profile("nope", 4)


def test_availability_traces_deterministic():
    for name, kw in (("always", {}), ("bernoulli", {"p": 0.7}),
                     ("markov", {"p_drop": 0.3, "p_join": 0.5})):
        a = make_availability(name, 50, seed=3, **kw)
        b = make_availability(name, 50, seed=3, **kw)
        for t in (0, 5, 2):  # out-of-order access must not change the trace
            np.testing.assert_array_equal(a.mask(t), b.mask(t))
            assert a.mask(t).shape == (50,) and a.mask(t).dtype == bool
    assert make_availability("always", 8).mask(123).all()
    bern = make_availability("bernoulli", 2000, seed=0, p=0.7)
    assert abs(bern.mask(0).mean() - 0.7) < 0.05
    mark = make_availability("markov", 2000, seed=0, p_drop=0.1, p_join=0.4)
    # stationary on-fraction = p_join / (p_join + p_drop) = 0.8
    assert abs(np.mean([mark.mask(t).mean() for t in range(10)]) - 0.8) < 0.05
    with pytest.raises(ValueError, match="p_drop"):
        make_availability("markov", 4, p_drop=1.5)


# --------------------------------------------------------------- clock
def test_round_clock_and_deadline_outcome():
    prof = make_profile("zipf_compute", 8, seed=0)
    clock = RoundClock(prof, download_mb=10.0, upload_mb=10.0,
                       steps=np.full(8, 20), jitter_sigma=0.0, seed=0)
    base = clock.base_times()
    assert (base > 0).all()
    np.testing.assert_array_equal(clock.times(0), clock.times(1))  # no jitter
    jittered = RoundClock(prof, 10.0, 10.0, np.full(8, 20),
                          jitter_sigma=0.3, seed=0)
    assert not np.array_equal(jittered.times(0), jittered.times(1))
    np.testing.assert_array_equal(jittered.times(4), jittered.times(4))

    sel = np.arange(6)
    avail = np.ones(8, bool)
    # no deadline: everyone reachable arrives; round takes the slowest
    out = round_outcome(sel, avail, base, None)
    assert out.n_dropped == 0 and out.sim_time == base[sel].max()
    np.testing.assert_array_equal(out.survivors, sel)
    # a deadline between the fastest and slowest drops the stragglers
    # and caps the round at the deadline
    d = float(np.median(base[sel]))
    out = round_outcome(sel, avail, base, d)
    assert 0 < out.n_dropped < len(sel)
    assert out.sim_time == d
    assert (base[out.survivors] <= d).all()
    # offline clients are dropped at dispatch and pay nothing
    avail[sel[0]] = False
    out2 = round_outcome(sel, avail, base, None)
    assert sel[0] not in out2.survivors and out2.n_reached == len(sel) - 1


def test_arrival_order_agrees_with_round_outcome_survivors():
    """The async event queue vs the deadline policy (DESIGN.md §13):
    with an infinite deadline, ``arrival_order``'s queue holds exactly
    ``round_outcome``'s survivor set, ordered by (arrival time, index)."""
    from repro.engine.async_config import arrival_order

    rng = np.random.default_rng(0)
    for _ in range(25):
        K = 16
        avail = rng.random(K) < 0.7
        times = rng.uniform(1.0, 50.0, K)
        sel = np.sort(rng.choice(K, size=6, replace=False))
        out = round_outcome(sel, avail, times, None)
        order = arrival_order(sel, avail[sel], times[sel])
        np.testing.assert_array_equal(np.sort(order), out.survivors)
        assert (np.diff(times[order]) >= 0).all()  # arrival-sorted


def test_markov_trace_independent_of_async_event_clock(data):
    """``SystemsRuntime.state_dict``'s contract, regression-pinned: the
    markov availability chain is indexed by the integer aggregation-step
    index, never by ``sim_clock`` — so after an async run has advanced
    the event clock to non-integer arrival instants, a freshly built
    runtime (sim_clock 0, masks queried out of order) re-derives the
    bit-identical trace."""
    train, test = data
    cfg = _cfg(rounds=6, eval_every=2, systems=dict(
        profile="mobile_mix", availability="markov",
        availability_kwargs={"p_drop": 0.3, "p_join": 0.5},
        jitter_sigma=0.1,
    ), async_mode={"buffer_k": 3, "concurrency": 8})
    eng = make_engine(cfg, train, test, 10)
    results = list(eng.rounds())
    assert any(r.sim_clock % 1.0 != 0.0 for r in results)  # event clock moved
    assert eng._systems.state_dict() == {}                 # stateless contract
    fresh = make_engine(cfg, train, test, 10)
    for t in (5, 0, 3, 1, 4, 2):  # out-of-order vs the consumed runtime
        np.testing.assert_array_equal(
            fresh._systems.available(t), eng._systems.available(t)
        )
        np.testing.assert_array_equal(
            fresh._systems.times(t), eng._systems.times(t)
        )


def test_deadline_drop_reweighting_sums_to_one_over_survivors():
    """The static-shape drop mechanism: survivors of the dispatched
    cohort keep their (renormalized) FedAvg weight, dropped clients are
    exact zeros, and the weight vector sums to 1."""
    sizes = np.array([10.0, 40.0, 25.0, 25.0, 60.0, 5.0])
    dispatched = np.array([0, 1, 3, 4])
    survivors = np.array([1, 4])
    mask = np.zeros(6, bool)
    mask[survivors] = True
    w = np.asarray(selection_weights(mask, sizes))
    assert w.sum() == pytest.approx(1.0)
    assert (w[[0, 2, 3, 5]] == 0).all()  # dropped + unselected: exact zeros
    assert w[1] == pytest.approx(40.0 / 100.0)
    assert w[4] == pytest.approx(60.0 / 100.0)
    del dispatched  # (the dropped members of it are the zeroed slots)


# ------------------------------------------------ engine integration
def _sys_kw(**over):
    base = dict(profile="zipf_compute", availability="bernoulli",
                availability_kwargs={"p": 0.7}, deadline_s=2.0,
                over_select=1.5, jitter_sigma=0.1)
    if "availability" in over and "availability_kwargs" not in over:
        base["availability_kwargs"] = {}
    base.update(over)
    return base


def test_inert_systems_matches_frictionless_engine(data):
    """The golden regression: an *inert* systems config (uniform
    profile, everyone on, no deadline, over_select=1) must reproduce
    the systems=None trajectory bit for bit — enabling the layer
    without any friction changes nothing but the clock fields."""
    train, test = data
    for backend in ("host", "compiled"):
        plain = make_engine(_cfg(backend=backend), train, test, 10)
        inert = make_engine(
            _cfg(backend=backend, systems=SystemsConfig()), train, test, 10
        )
        rp, ri = list(plain.rounds(3)), list(inert.rounds(3))
        for a, b in zip(rp, ri):
            assert a.selected == b.selected
            assert b.n_dropped == 0 and b.sim_time > 0.0
            assert a.comm_mb == pytest.approx(b.comm_mb)
        assert _max_err(plain.params, inert.params) == 0.0


_AVAIL_CASES = {
    "bernoulli": {"p": 0.7},
    # churny chain (stationary on-fraction 0.5) so offline dispatches
    # and deadline drops both actually occur within the short run
    "markov": {"p_drop": 0.4, "p_join": 0.4},
}


@pytest.mark.parametrize("availability", sorted(_AVAIL_CASES))
def test_availability_gated_masks_identical_across_backends(availability, data):
    """host / compiled / fused consume one exogenous availability trace:
    identical survivor sets, drop counts, simulated times, and allclose
    params — the conformance cell the acceptance criteria name."""
    train, test = data
    kw = dict(strategy="fedlecc", strategy_kwargs={"J": 3}, rounds=6,
              eval_every=2,
              systems=_sys_kw(availability=availability,
                              availability_kwargs=_AVAIL_CASES[availability]))
    runs = {}
    for name, cfg_kw in (
        ("host", dict(backend="host")),
        ("compiled", dict(backend="compiled")),
        ("fused", dict(backend="compiled", fuse_rounds=3)),
    ):
        eng = make_engine(_cfg(**{**kw, **cfg_kw}), train, test, 10)
        runs[name] = (list(eng.rounds(6)), eng.params)
    ref, ref_params = runs["host"]
    assert any(r.n_dropped > 0 for r in ref)  # the deadline actually bites
    for name in ("compiled", "fused"):
        rs, params = runs[name]
        for a, b in zip(ref, rs):
            assert a.selected == b.selected, (name, a.round)
            assert a.n_dropped == b.n_dropped
            assert a.sim_time == pytest.approx(b.sim_time)
            assert a.comm_mb == pytest.approx(b.comm_mb)
            assert a.mean_selected_loss == pytest.approx(
                b.mean_selected_loss, rel=1e-4, nan_ok=True
            )
        assert _max_err(ref_params, params) < 1e-5


def test_over_selection_dispatches_ceil_m_times_factor(data):
    train, test = data
    cfg = _cfg(systems=_sys_kw(availability="always", deadline_s=None,
                               over_select=1.5, jitter_sigma=0.0))
    eng = make_engine(cfg, train, test, 10)
    assert eng.m_eff == 6  # ceil(4 * 1.5)
    (r0,) = list(eng.rounds(1))
    assert len(r0.selected) == 6 and r0.n_dropped == 0


def test_no_upload_round_keeps_model(data):
    """If every dispatched client is dropped (deadline below the fastest
    device), the global model must stand still, not collapse to the
    all-zero weighted sum."""
    train, test = data
    sys_kw = _sys_kw(availability="always", deadline_s=1e-6, jitter_sigma=0.0)
    for backend, extra in (("host", {}), ("compiled", {}),
                           ("compiled", {"fuse_rounds": 2})):
        eng = make_engine(_cfg(backend=backend, systems=dict(sys_kw), **extra),
                          train, test, 10)
        before = jax.device_get(eng.params)
        rs = list(eng.rounds(2))
        assert all(r.selected == () and r.n_dropped == 6 for r in rs)
        assert _max_err(before, jax.device_get(eng.params)) == 0.0


def test_deadline_over_selection_beats_no_deadline_sim_time(data):
    """The acceptance property: under a heterogeneous profile, a
    deadline + over-selection configuration reaches the target accuracy
    in less simulated wall-clock than waiting for every straggler."""
    train, test = data
    rounds = 10
    kw = dict(strategy="fedlecc", strategy_kwargs={"J": 3}, rounds=rounds,
              eval_every=1)
    base_eng = make_engine(_cfg(systems=dict(
        profile="zipf_compute", availability="always", jitter_sigma=0.0,
    ), **kw), train, test, 10)
    base = list(base_eng.rounds(rounds))
    # deadline at the median device time: stragglers dropped, rounds
    # capped well below the slowest-device time the baseline pays
    d = float(np.median(base_eng._systems.clock.base_times()))
    ddl_eng = make_engine(_cfg(systems=dict(
        profile="zipf_compute", availability="always", jitter_sigma=0.0,
        deadline_s=d, over_select=1.5,
    ), **kw), train, test, 10)
    ddl = list(ddl_eng.rounds(rounds))

    target = min(max(r.test_acc for r in base),
                 max(r.test_acc for r in ddl)) * 0.95

    def time_to(rs):
        return next(r.sim_clock for r in rs if r.test_acc is not None
                    and r.test_acc >= target)

    assert any(r.n_dropped > 0 for r in ddl)
    assert time_to(ddl) < time_to(base)


def test_no_retrace_with_systems_enabled(data):
    """The static-shape drop mechanism keeps the jit caches at one
    entry: the cohort train never retraces as survivors change, and
    each fused chunk length compiles exactly once."""
    train, test = data
    sys_kw = _sys_kw(profile="mobile_mix", availability="markov",
                     availability_kwargs={"p_drop": 0.2, "p_join": 0.6})
    eager = make_engine(_cfg(backend="compiled", systems=sys_kw, rounds=5,
                             eval_every=2), train, test, 10)
    rs = list(eager.rounds(5))
    assert len({r.selected for r in rs}) > 1       # cohorts moved
    assert eager._train_cohort._cache_size() == 1  # ... without retracing
    fused = make_engine(_cfg(backend="compiled", fuse_rounds=2, rounds=7,
                             eval_every=100, systems=sys_kw), train, test, 10)
    list(fused.rounds(7))
    assert sorted(fused._chunk_cache) == [1, 2]
    for fn in fused._chunk_cache.values():
        assert fn._cache_size() == 1


def test_haccs_latency_tiebreak_uses_profile(data):
    """ROADMAP'd in the tentpole: with a systems profile, HACCS ranks by
    the profile-derived expected round time instead of the placeholder
    lognormal draw."""
    train, test = data
    sys_kw = dict(profile="mobile_mix", availability="always")
    eng = make_engine(_cfg(strategy="haccs", systems=sys_kw), train, test, 10)
    np.testing.assert_array_equal(
        eng.strategy.latency, eng._systems.latency_hint()
    )
    plain = make_engine(_cfg(strategy="haccs"), train, test, 10)
    assert not np.array_equal(plain.strategy.latency, eng.strategy.latency)
    # selection still returns the full cohort through the engine
    (r0,) = list(eng.rounds(1))
    assert len(r0.selected) == 4


def test_systems_runtime_with_scaleout_backend(data):
    """The fourth backend: the scaleout psum weights carry only the
    survivors, matching the host trajectory under one systems config."""
    train, test = data
    kw = dict(strategy="fedlecc", strategy_kwargs={"J": 3}, rounds=3,
              eval_every=1, systems=_sys_kw())
    host = make_engine(_cfg(backend="host", **kw), train, test, 10)
    scale = make_engine(_cfg(backend="scaleout", **kw), train, test, 10)
    rh, rs = list(host.rounds(3)), list(scale.rounds(3))
    for a, b in zip(rh, rs):
        assert a.selected == b.selected and a.n_dropped == b.n_dropped
        assert a.sim_time == pytest.approx(b.sim_time)
    assert _max_err(host.params, scale.params) < 1e-5


# ------------------------------------------------- random / poc tiers
def test_random_strategy_joins_mask_and_traced_tiers(data):
    """ROADMAP (g): random carries select_mask_jax (host-lockstep) and
    select_mask_traced; the host-only set shrinks to fedcls/fedcor."""
    from repro.engine import mask_selection_strategies
    from repro.engine.registry import (
        STRATEGY_REGISTRY,
        traced_selection_strategies,
    )

    masked = set(mask_selection_strategies())
    assert "random" in masked and "poc" in masked
    host_only = set(STRATEGY_REGISTRY.names()) - masked
    assert host_only == {"fedcls", "fedcor"}
    assert {"random", "poc"} <= set(traced_selection_strategies())

    train, test = data
    host = make_engine(_cfg(strategy="random", backend="host"),
                       train, test, 10)
    comp = make_engine(_cfg(strategy="random", backend="compiled"),
                       train, test, 10)
    rh, rc = list(host.rounds(3)), list(comp.rounds(3))
    for a, b in zip(rh, rc):
        assert a.selected == b.selected  # one rng stream, lockstep
    assert _max_err(host.params, comp.params) < 1e-5


def test_offline_clients_deprioritized_by_every_strategy():
    """The -inf availability gate: with more online clients than slots,
    no strategy dispatches an offline client."""
    from repro.core.strategies import get_strategy

    rng = np.random.default_rng(0)
    K, m = 24, 6
    hists = rng.dirichlet(np.ones(10) * 0.3, size=K)
    sizes = np.full(K, 80.0)
    offline = np.zeros(K, bool)
    offline[rng.choice(K, size=10, replace=False)] = True
    losses = rng.uniform(0.5, 3.0, K).astype(np.float32)
    gated = np.where(offline, -np.inf, losses).astype(np.float32)
    for name in ("fedlecc", "lossonly", "poc", "haccs", "random",
                 "clusterrandom", "fedcls", "fedcor", "fedlecc_adaptive",
                 "fedcs"):
        s = get_strategy(name, m=m)
        s.setup(hists, sizes, seed=0)
        sel = s.select(0, gated, np.random.default_rng(1))
        assert not offline[sel].any(), f"{name} dispatched offline clients"
        if getattr(s, "supports_compiled_selection", False):
            mask = np.asarray(s.select_mask_jax(gated, np.random.default_rng(1)))
            assert not offline[mask].any(), f"{name} jax mask hit offline"
        if getattr(s, "supports_traced_selection", False):
            tmask = np.asarray(s.select_mask_traced(
                jax.numpy.asarray(gated), jax.random.PRNGKey(0)
            ))
            assert int(tmask.sum()) == m
            assert not offline[tmask].any(), f"{name} traced mask hit offline"


# ------------------------------------------------- LM perplexity (h)
def test_lm_task_surfaces_perplexity_metrics(lm_data):
    """ROADMAP (h): the lm task reports held-out perplexity, total and
    per topic cluster, on evaluated rounds — and run() lands it in the
    history dict."""
    train, test = lm_data
    eng = make_engine(_lm_cfg(), train, test, n_classes=LM_VOCAB)
    results = list(eng.rounds(2))
    for r in results:
        assert r.evaluated and r.metrics is not None
        assert r.metrics["ppl"] > 1.0 and np.isfinite(r.metrics["ppl"])
        per = r.metrics["ppl_per_cluster"]
        assert isinstance(per, dict) and len(per) >= 1
        assert all(np.isfinite(v) and v > 0 for v in per.values())
    # total ppl is consistent with the reported test CE loss scale
    assert np.log(results[-1].metrics["ppl"]) == pytest.approx(
        results[-1].test_loss, rel=0.2
    )
    eng2 = make_engine(_lm_cfg(), train, test, n_classes=LM_VOCAB)
    hist = eng2.run()
    assert "ppl" in hist and len(hist["ppl"]) == len(hist["round"])
    assert "ppl_per_cluster" in hist


def test_classification_task_has_no_extra_metrics(data):
    train, test = data
    eng = make_engine(_cfg(), train, test, 10)
    (r0, *_rest) = list(eng.rounds(1))
    assert r0.metrics is None
    hist_keys = set(make_engine(_cfg(), train, test, 10).run())
    assert "ppl" not in hist_keys and "sim_clock" not in hist_keys
