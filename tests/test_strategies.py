"""Selection-strategy registry + communication ledger."""

import numpy as np
import pytest

from conftest import planted_histograms
from repro.core.comm_model import CommModel
from repro.core.strategies import get_strategy
from repro.engine.registry import STRATEGY_REGISTRY, list_strategies


@pytest.mark.parametrize("name", list_strategies())
def test_strategy_valid_selection(name, rng):
    hists, _ = planted_histograms(rng, K=50)
    s = get_strategy(name, m=8)
    s.setup(hists, np.full(50, 100), seed=0)
    losses = rng.uniform(0.1, 3.0, 50)
    for rnd in range(3):
        sel = s.select(rnd, losses, np.random.default_rng(rnd))
        assert len(sel) == 8
        assert len(set(sel.tolist())) == 8
        assert (sel >= 0).all() and (sel < 50).all()


def test_fedlecc_strategy_uses_clusters(rng):
    hists, assign = planted_histograms(rng, K=60, G=5)
    s = get_strategy("fedlecc", m=10, J=4)
    s.setup(hists, np.full(60, 100), seed=0)
    assert s.n_clusters >= 3
    losses = rng.uniform(0.1, 3.0, 60)
    sel = s.select(0, losses, np.random.default_rng(0))
    assert len(np.unique(s.labels[sel])) >= 3  # diversity across clusters


def test_poc_prefers_high_loss(rng):
    s = get_strategy("poc", m=5, d=20)
    s.setup(np.ones((50, 10)), np.full(50, 100), seed=0)
    losses = np.arange(50, dtype=float)
    sel = s.select(0, losses, np.random.default_rng(0))
    assert losses[sel].mean() > losses.mean()  # biased toward high loss


def test_plain_subclass_of_base_stays_host_only():
    """The extension-base contract: subclassing SelectionStrategy and
    overriding only select() must NOT inherit the jit/traced selection
    flags — otherwise a mask-gated backend would silently run the base
    mask instead of the subclass's selection logic (the registered
    `random` strategy opts in via the UniformRandom subclass)."""
    from repro.core.strategies import SelectionStrategy, UniformRandom

    class Mine(SelectionStrategy):
        def select(self, rnd, losses, rng):
            return np.arange(self.m)

    assert not Mine.supports_compiled_selection
    assert not Mine.supports_traced_selection
    assert UniformRandom.supports_compiled_selection
    assert STRATEGY_REGISTRY["random"] is UniformRandom


def test_unknown_strategy_raises():
    with pytest.raises(KeyError):
        get_strategy("nope", m=3)


def test_legacy_strategies_alias_is_registry():
    # deprecated dict-style consumers keep working against the registry
    from repro.core.strategies import STRATEGIES

    assert STRATEGIES is STRATEGY_REGISTRY
    assert "fedlecc" in STRATEGIES
    assert sorted(STRATEGIES) == list_strategies()
    assert STRATEGIES["fedlecc"] is STRATEGY_REGISTRY["fedlecc"]


def test_haccs_largest_cluster_guaranteed_slot(rng):
    """Regression: proportional-slot rounding must never starve the
    largest cluster (docstring promises >=1 slot for it).  With m=1 and
    the dominant cluster under half the population, np.round gives it 0
    slots — the fix pins it to 1, so the pick comes from that cluster."""
    s = get_strategy("haccs", m=1)
    hists, _ = planted_histograms(rng, K=50)
    s.setup(hists, np.full(50, 100), seed=0)
    # dominant-cluster histogram: 10/50 = 0.2 -> round(m*0.2) == 0 slots
    s.labels = np.array([0] * 10 + [1] * 8 + [2] * 8 + [3] * 8 + [4] * 8 + [5] * 8)
    s.n_clusters = 6
    losses = rng.uniform(0.1, 1.0, 50)
    for seed in range(5):
        sel = s.select(0, losses, np.random.default_rng(seed))
        assert len(sel) == 1
        assert s.labels[sel[0]] == 0  # picked from the largest cluster


def test_comm_model_ledger():
    cm = CommModel(n_params=199_210, K=100, n_classes=10)
    per_round = cm.round_mb(10, needs_losses=True)
    # model traffic dominates: 2·10·199210·4 bytes ≈ 15.2 MB
    assert 15.0 < per_round < 15.4
    total = cm.total_mb(150, 10, needs_losses=True, needs_histograms=True)
    assert abs(total - (cm.one_time_mb(True) + 150 * per_round)) < 1e-9
    # fewer clients → strictly less traffic
    assert cm.round_mb(2, True) < cm.round_mb(10, True)
    # loss polling costs K floats
    assert cm.round_mb(10, True) - cm.round_mb(10, False) == pytest.approx(
        100 * 4 / (1024 * 1024)
    )
