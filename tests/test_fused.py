"""Fused execution mode (``FLConfig.fuse_rounds``, DESIGN.md §8.6) and
the static cohort gather of the compiled backend:

- config validation of ``fuse_rounds`` / ``compress_bits``
- fused-vs-eager equivalence (deterministic traced strategies)
- chunked-vs-contiguous ``rounds()`` equivalence for ``fuse_rounds > 0``
- the no-retrace guard: the cohort train step and each fused chunk
  length compile exactly once across 3+ rounds
- cohort gather vs the legacy ungathered mask-gated path
- the empty-selection ``mean_selected_loss`` regression guard
"""

import warnings

import jax
import numpy as np
import pytest

from conftest import fl_cfg as _cfg
from repro.engine import FLConfig, make_engine
from repro.engine.registry import traced_selection_strategies

TRACED = traced_selection_strategies()


def _max_err(a, b):
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# ------------------------------------------------------------- validation
def test_traced_strategy_registry():
    """The traced-selection surface fuse_rounds promises (and the one
    documented exclusion: fedlecc_adaptive's J is a static argument).
    poc joined via the Gumbel-top-k candidate draw and random via
    key-derived uniform scores (ROADMAP (j) / (g))."""
    assert {"fedlecc", "lossonly", "clusterrandom", "haccs",
            "poc", "random"} <= set(TRACED)
    assert "fedlecc_adaptive" not in TRACED


def test_fuse_rounds_validation():
    with pytest.raises(ValueError, match="fuse_rounds must be >= 0"):
        _cfg(backend="compiled", fuse_rounds=-1)
    with pytest.raises(ValueError, match="backend='compiled'"):
        _cfg(backend="host", fuse_rounds=2)
    with pytest.raises(ValueError, match="select_mask_traced") as ei:
        _cfg(backend="compiled", strategy="fedlecc_adaptive", fuse_rounds=2)
    for name in TRACED:  # actionable: the error names every traced strategy
        assert name in str(ei.value)
    with pytest.raises(ValueError, match="fedavg"):
        _cfg(backend="compiled", fuse_rounds=2, aggregator="fednova")
    # a valid fused config constructs and round-trips (new fields included)
    cfg = _cfg(backend="compiled", fuse_rounds=3, compress_bits=8)
    restored = FLConfig.from_dict(cfg.to_dict())
    assert restored.fuse_rounds == 3 and restored.compress_bits == 8


def test_compress_bits_validation():
    with pytest.raises(ValueError, match="compress_bits"):
        _cfg(backend="compiled", compress_bits=9)
    with pytest.raises(ValueError, match="compress_bits"):
        _cfg(backend="compiled", compress_bits=1)
    with pytest.raises(ValueError, match="backend='compiled'"):
        _cfg(backend="host", compress_bits=8)
    with pytest.raises(ValueError, match="fedavg"):
        _cfg(backend="compiled", compress_bits=8, aggregator="fednova")


# ---------------------------------------------------- fused ≡ eager loop
@pytest.mark.parametrize("strategy", ["fedlecc", "lossonly", "haccs"])
def test_fused_matches_eager_compiled(strategy, data):
    """For strategies deterministic given losses, the scanned fused
    chunks must reproduce the eager compiled loop round for round —
    identical selections and (all)close params."""
    train, test = data
    kw = dict(strategy=strategy, rounds=6, eval_every=2)
    if strategy == "fedlecc":
        kw["strategy_kwargs"] = {"J": 3}
    eager = make_engine(_cfg(backend="compiled", **kw), train, test, 10)
    fused = make_engine(_cfg(backend="compiled", fuse_rounds=3, **kw),
                        train, test, 10)
    re_, rf = list(eager.rounds(6)), list(fused.rounds(6))
    assert len(rf) == 6
    for a, b in zip(re_, rf):
        assert a.round == b.round
        assert a.selected == b.selected
        assert a.comm_mb == pytest.approx(b.comm_mb)
        assert a.mean_selected_loss == pytest.approx(b.mean_selected_loss,
                                                     rel=1e-5)
        assert a.evaluated == b.evaluated  # same absolute eval cadence
    assert _max_err(eager.params, fused.params) < 1e-6


def test_fused_chunked_vs_contiguous_rounds(data):
    """rounds(3)+rounds(3) through the fused engine must land on the
    same trajectory as one contiguous rounds(6) call (the chunk-resume
    contract: persisted PRNG carry + absolute eval cadence)."""
    train, test = data
    mk = lambda: make_engine(
        _cfg(backend="compiled", fuse_rounds=3, rounds=6, eval_every=2),
        train, test, 10,
    )
    contiguous, chunked = mk(), mk()
    ra = list(contiguous.rounds(6))
    rb = list(chunked.rounds(3)) + list(chunked.rounds(3))
    assert [r.selected for r in ra] == [r.selected for r in rb]
    assert [r.round for r in rb] == list(range(6))
    # identical absolute cadence: the chunked calls evaluate exactly the
    # rounds the contiguous call does (no per-call final-round force-eval)
    assert {r.round for r in ra if r.evaluated} == {
        r.round for r in rb if r.evaluated
    }
    assert _max_err(contiguous.params, chunked.params) < 1e-6
    assert ra[-1].comm_mb == pytest.approx(rb[-1].comm_mb)


def test_fused_matches_host_end_to_end(data):
    """The full chain host → fused: fold_in client keys + traced
    selection + cohort gather + in-scan fedavg land on the host
    trajectory."""
    train, test = data
    host = make_engine(_cfg(backend="host", rounds=4), train, test, 10)
    fused = make_engine(_cfg(backend="compiled", fuse_rounds=4, rounds=4),
                        train, test, 10)
    rh, rf = list(host.rounds(4)), list(fused.rounds(4))
    for a, b in zip(rh, rf):
        assert a.selected == b.selected
    assert _max_err(host.params, fused.params) < 1e-5


@pytest.mark.parametrize("strategy", ["clusterrandom", "poc", "random"])
def test_fused_randomized_strategies_self_consistent(strategy, data):
    """The randomized strategies' fused selection rides the JAX PRNG
    stream (clusterrandom: key-derived Algorithm 1 scores; poc:
    Gumbel-top-k candidate draw; random: key-derived uniform scores):
    deterministic per seed, uniform-valid (exactly m selected), but not
    host-lockstep (documented deviation)."""
    train, test = data
    kw = dict(strategy=strategy, rounds=4, eval_every=2)
    if strategy == "clusterrandom":
        kw["strategy_kwargs"] = {"J": 3}
    a = make_engine(_cfg(backend="compiled", fuse_rounds=2, **kw),
                    train, test, 10)
    b = make_engine(_cfg(backend="compiled", fuse_rounds=2, **kw),
                    train, test, 10)
    ra, rb = list(a.rounds(4)), list(b.rounds(4))
    assert [r.selected for r in ra] == [r.selected for r in rb]
    assert all(len(r.selected) == 4 for r in ra)
    assert _max_err(a.params, b.params) == 0.0


# --------------------------------------------------------- no-retrace
def test_cohort_train_compiles_once_across_rounds(data):
    """The static-shape cohort gather must not retrace as the selected
    cohort changes round to round (m is static; indices are traced)."""
    train, test = data
    engine = make_engine(_cfg(backend="compiled", rounds=4), train, test, 10)
    results = list(engine.rounds(4))
    assert len({r.selected for r in results}) > 1  # cohorts actually moved
    assert engine._train_cohort._cache_size() == 1


def test_fused_chunk_compiles_once_per_length(data):
    """Each distinct chunk length compiles exactly once; repeated
    steady-state chunks reuse the cached executable."""
    train, test = data
    engine = make_engine(
        _cfg(backend="compiled", fuse_rounds=2, rounds=7, eval_every=100),
        train, test, 10,
    )
    list(engine.rounds(7))  # chunks: [0], [1,2], [3,4], [5,6]
    assert sorted(engine._chunk_cache) == [1, 2]
    for fn in engine._chunk_cache.values():
        assert fn._cache_size() == 1


# ------------------------------------------------ cohort gather parity
def test_cohort_gather_matches_ungathered_mask_path(data):
    """Training just the gathered cohort must reproduce the legacy
    every-client-trains mask-gated path (zero-weight clients only ever
    contributed zeros)."""
    train, test = data
    gathered = make_engine(_cfg(backend="compiled", rounds=3),
                           train, test, 10)
    ungathered = make_engine(_cfg(backend="compiled", rounds=3),
                             train, test, 10, cohort_gather=False)
    assert gathered.cohort_gather and not ungathered.cohort_gather
    rg, ru = list(gathered.rounds(3)), list(ungathered.rounds(3))
    for a, b in zip(rg, ru):
        assert a.selected == b.selected
        assert a.mean_selected_loss == pytest.approx(b.mean_selected_loss,
                                                     rel=1e-5)
    assert _max_err(gathered.params, ungathered.params) < 1e-6


def test_compressed_fused_matches_compressed_eager(data):
    """The quantization stream (fold_in(k_train, K)) is shared between
    the eager compiled aggregation and the fused in-scan aggregation."""
    train, test = data
    kw = dict(backend="compiled", compress_bits=8, rounds=3, eval_every=1)
    eager = make_engine(_cfg(**kw), train, test, 10)
    fused = make_engine(_cfg(fuse_rounds=3, **kw), train, test, 10)
    re_, rf = list(eager.rounds(3)), list(fused.rounds(3))
    for a, b in zip(re_, rf):
        assert a.selected == b.selected
    assert _max_err(eager.params, fused.params) < 1e-6


# ------------------------------------------- empty-selection regression
def test_empty_selection_mean_loss_is_nan_without_warning(data):
    """A strategy returning an empty selection used to trip numpy's
    ``RuntimeWarning: Mean of empty slice`` via ``np.mean([])``; the
    guard returns a clean nan instead."""
    train, test = data
    engine = make_engine(_cfg(rounds=1), train, test, 10)
    engine.select = lambda rnd, losses: np.array([], dtype=np.int64)
    engine.local_train = lambda rnd, sel, key: (None, np.array([], np.float32))
    engine.aggregate = lambda rnd, sel, payload: None
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # any RuntimeWarning would raise
        (result,) = list(engine.rounds(1))
    assert np.isnan(result.mean_selected_loss)
    assert result.selected == ()


# ------------------------------------------------- donation contract
def test_fused_donation_invalidates_stale_param_aliases(data):
    """Fused chunks donate the params buffers: an unobserved pre-run
    alias of ``engine.params`` dies with the first chunk (the documented
    hazard — snapshot with ``jax.device_get`` / ``jnp.copy`` instead of
    aliasing; an existing zero-copy host view also happens to pin the
    buffer on CPU, so the alias here is deliberately never read before
    the run)."""
    train, test = data
    engine = make_engine(_cfg(backend="compiled", fuse_rounds=2, rounds=2),
                         train, test, 10)
    stale = engine.params  # aliased device buffers, never materialized
    list(engine.rounds(2))
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(jax.tree.leaves(stale)[0])
    # the engine's own params were re-bound to the chunk result
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(engine.params))


# ----------------------------------------------- PRNG carry persistence
def test_rounds_resume_does_not_replay_key_stream(data):
    """The carried key persists across chunked rounds() calls (the
    O(rounds) re-split replay is gone) without changing the stream: a
    resumed engine matches a contiguous run bit for bit."""
    train, test = data
    a = make_engine(_cfg(rounds=6), train, test, 10)
    b = make_engine(_cfg(rounds=6), train, test, 10)
    ra = list(a.rounds(6))
    rb = list(b.rounds(2)) + list(b.rounds(2)) + list(b.rounds(2))
    assert [r.selected for r in ra] == [r.selected for r in rb]
    assert _max_err(a.params, b.params) == 0.0
    assert b._key is not None  # the persisted carry
