"""Mini dry-run in a subprocess: the dryrun driver's build_step path on an
8-virtual-device mesh with reduced configs — one arch per family plus the
collective-bytes parser unit tests."""

import os
import subprocess
import sys

import pytest

from repro.launch.dryrun import collective_bytes

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.jax_compat import cost_analysis, set_mesh
from repro.launch.dryrun import build_step, collective_bytes

mesh = jax.make_mesh((2, 4), ("data", "model"))
cases = [
    ("qwen3-14b", InputShape("t", 256, 8, "train")),
    ("deepseek-v3-671b", InputShape("t", 256, 8, "train")),
    ("xlstm-125m", InputShape("d", 256, 8, "decode")),
    ("hymba-1.5b", InputShape("p", 256, 8, "prefill")),
]
for arch, shape in cases:
    cfg = get_config(arch, reduced=True)
    if cfg.moe:
        from dataclasses import replace
        cfg = replace(cfg, moe=replace(cfg.moe, impl="capacity"))
    fn, arg_specs, (ins, outs), donate = build_step(cfg, mesh, shape)
    with set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=ins, out_shardings=outs,
                           donate_argnums=donate).lower(*arg_specs).compile()
    cost = cost_analysis(compiled)
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    assert cost.get("flops", 0) > 0, (arch, cost)
    print(f"MINI_OK {arch} {shape.kind} flops={cost.get('flops'):.3e} "
          f"coll={sum(coll.values()):.3e}")
print("ALL_MINI_OK")
"""


@pytest.mark.slow
def test_mini_dryrun_per_family():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert "ALL_MINI_OK" in r.stdout, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


def test_collective_bytes_parser():
    hlo = """
  %ar = bf16[16,128]{1,0} all-reduce(bf16[16,128]{1,0} %x), replica_groups={}
  %ag.1 = f32[64,256]{1,0} all-gather(f32[16,256]{1,0} %y), dimensions={0}
  %rs = f32[4,256]{1,0} reduce-scatter(f32[16,256]{1,0} %z), dimensions={0}
  %cp = u8[1024]{0} collective-permute(u8[1024]{0} %w)
  %add = f32[8,8]{1,0} add(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 128 * 2
    assert got["all-gather"] == 64 * 256 * 4
    assert got["reduce-scatter"] == 4 * 256 * 4
    assert got["collective-permute"] == 1024
    assert "add" not in got


def test_collective_bytes_empty():
    assert collective_bytes("%x = f32[2] add(...)") == {}
