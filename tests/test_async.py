"""The asynchronous runtime (``FLConfig.async_mode``, DESIGN.md §13):
FedBuff-style buffered aggregation with staleness-discounted weights.

Covers the PR's acceptance surface:

- ``AsyncConfig`` validation and ``FLConfig`` round-tripping;
- staleness discounts and ``staleness_weights`` against hand-computed
  values (the property suite drives the permutation invariants);
- buffer semantics: aggregation fires at exactly ``buffer_k`` arrivals,
  arrivals past ``max_staleness`` are dropped with exactly zero weight;
- the degenerate configuration (``dispatch="sync"``, discount off) is
  bit-identical to the synchronous engine on host and compiled — the
  cross-task cells live in test_backend_conformance.py;
- event-clock monotonicity, params-version accounting, same-seed
  determinism, host/compiled agreement;
- kill-and-resume mid-buffer through ``Engine.save``/``restore`` —
  in-flight ledger, buffer, and params version ride the checkpoint.
"""

import jax
import numpy as np
import pytest

from conftest import fl_cfg as _cfg
from repro.engine import AsyncConfig, FLConfig, make_engine
from repro.engine.async_config import (
    arrival_order,
    make_staleness_discount,
    staleness_weights,
)
from repro.engine.registry import list_staleness_discounts


def _max_err(a, b):
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def _sys(**over):
    base = dict(profile="mobile_mix", availability="markov",
                availability_kwargs={"p_drop": 0.2, "p_join": 0.6},
                jitter_sigma=0.1)
    if "availability" in over and "availability_kwargs" not in over:
        base["availability_kwargs"] = {}
    base.update(over)
    return base


def _async_cfg(**kw):
    kw.setdefault("systems", _sys())
    kw.setdefault("async_mode", {"buffer_k": 3, "concurrency": 8})
    kw.setdefault("rounds", 6)
    kw.setdefault("eval_every", 2)
    return _cfg(**kw)


# ---------------------------------------------------------------- config
def test_async_config_field_validation():
    with pytest.raises(ValueError, match="dispatch"):
        AsyncConfig(dispatch="eventually")
    with pytest.raises(ValueError, match="buffer_k"):
        AsyncConfig(buffer_k=0)
    with pytest.raises(ValueError, match="concurrency"):
        AsyncConfig(concurrency=-1)
    with pytest.raises(ValueError, match="unknown staleness discount"):
        AsyncConfig(staleness="logarithmic")
    with pytest.raises(ValueError, match="max_staleness"):
        AsyncConfig(max_staleness=-2)
    with pytest.raises(ValueError, match="unknown AsyncConfig keys"):
        AsyncConfig.from_dict({"buffer_k": 2, "bogus": 1})
    with pytest.raises(ValueError, match="async_mode must be"):
        _cfg(systems=_sys(), async_mode=42)
    # resolution helpers
    a = AsyncConfig(buffer_k=3)
    assert a.buffer_effective(10) == 3 and AsyncConfig().buffer_effective(10) == 10
    assert a.concurrency_effective(4) == 6      # max(2·3, 4)
    assert AsyncConfig().concurrency_effective(4) == 8
    assert AsyncConfig().discount_off()
    assert not AsyncConfig(staleness="polynomial").discount_off()
    assert not AsyncConfig(staleness_kwargs={"factor": 0.5}).discount_off()


def test_async_config_combination_validation():
    ok = dict(systems=_sys(), async_mode={"buffer_k": 3})
    _cfg(**ok)  # the base combination is accepted
    with pytest.raises(ValueError, match="backend"):
        _cfg(backend="scaleout", **ok)
    with pytest.raises(ValueError, match="mutually exclusive"):
        _cfg(backend="compiled", fuse_rounds=2, **ok)
    with pytest.raises(ValueError, match="fedavg"):
        _cfg(aggregator="fednova", **ok)
    with pytest.raises(ValueError, match="client_mode"):
        _cfg(client_mode="fedprox", mu=0.1, **ok)
    with pytest.raises(ValueError, match="compress_bits"):
        _cfg(backend="compiled", compress_bits=8, **ok)
    with pytest.raises(ValueError, match="systems"):
        _cfg(async_mode={"buffer_k": 3})
    with pytest.raises(ValueError, match="deadline"):
        _cfg(systems=_sys(deadline_s=30.0), async_mode={"buffer_k": 3})
    with pytest.raises(ValueError, match="concurrency"):
        _cfg(systems=_sys(), async_mode={"buffer_k": 3, "concurrency": 2})
    with pytest.raises(ValueError, match="population"):
        _cfg(systems=_sys(), async_mode={"buffer_k": 50})
    # the degenerate dispatch awaits the whole cohort: buffer_k must
    # be None or the effective cohort size
    with pytest.raises(ValueError, match="buffer_k must be None"):
        _cfg(systems=_sys(), async_mode={"dispatch": "sync", "buffer_k": 2})
    _cfg(systems=_sys(deadline_s=30.0), async_mode={"dispatch": "sync"})


def test_async_config_round_trips_through_flconfig():
    import json

    cfg = _async_cfg(async_mode={
        "buffer_k": 3, "concurrency": 8, "staleness": "polynomial",
        "staleness_kwargs": {"a": 0.5}, "max_staleness": 4,
    })
    assert isinstance(cfg.async_mode, AsyncConfig)  # dict form normalized
    d = json.loads(json.dumps(cfg.to_dict()))
    assert isinstance(d["async_mode"], dict)        # JSON-safe nested form
    restored = FLConfig.from_dict(d)
    assert restored == cfg and isinstance(restored.async_mode, AsyncConfig)
    # the sync default serializes as null and restores as None
    assert _cfg().to_dict()["async_mode"] is None
    assert FLConfig.from_dict(_cfg().to_dict()).async_mode is None


# ------------------------------------------------------------- discounts
def test_staleness_discounts_hand_computed():
    assert {"constant", "polynomial", "exponential"} <= set(
        list_staleness_discounts()
    )
    s = np.array([0, 1, 3, 8])
    np.testing.assert_allclose(
        make_staleness_discount("constant")(s), np.ones(4)
    )
    np.testing.assert_allclose(
        make_staleness_discount("constant", factor=0.25)(s), np.full(4, 0.25)
    )
    # FedBuff's (1+s)^-a at a=0.5: 1, 1/sqrt(2), 1/2, 1/3
    np.testing.assert_allclose(
        make_staleness_discount("polynomial", a=0.5)(s),
        [1.0, 2 ** -0.5, 0.5, 1.0 / 3.0],
    )
    np.testing.assert_allclose(
        make_staleness_discount("exponential", gamma=0.5)(s),
        [1.0, 0.5, 0.125, 0.5 ** 8],
    )


def test_staleness_discount_probe_rejects_bad_kwargs():
    with pytest.raises(ValueError, match="non-negative"):
        make_staleness_discount("constant", factor=-1.0)
    with pytest.raises(ValueError, match="non-negative"):
        AsyncConfig(staleness_kwargs={"factor": -1.0})
    with pytest.raises(TypeError):
        make_staleness_discount("polynomial", exponent=2.0)  # unknown kwarg
    with pytest.raises(KeyError):
        make_staleness_discount("nope")


def test_staleness_weights_hand_computed():
    d = make_staleness_discount("polynomial", a=1.0)  # d(s) = 1/(1+s)
    sizes = np.array([100.0, 50.0, 60.0])
    stal = np.array([0, 1, 2])
    # u = sizes·d = [100, 25, 20] → normalized over 145
    np.testing.assert_allclose(
        staleness_weights(sizes, stal, d), [100 / 145, 25 / 145, 20 / 145]
    )
    # max_staleness=1 zeroes the s=2 entry and renormalizes over 125
    np.testing.assert_allclose(
        staleness_weights(sizes, stal, d, max_staleness=1),
        [100 / 125, 25 / 125, 0.0],
    )
    # discount off → plain size weighting
    np.testing.assert_allclose(
        staleness_weights(sizes, stal, make_staleness_discount("constant")),
        sizes / sizes.sum(),
    )


def test_staleness_weights_edge_cases():
    d = make_staleness_discount("constant")
    # everything past max_staleness: all-zero weights, no NaN
    w = staleness_weights(np.array([10.0, 20.0]), np.array([5, 9]), d,
                          max_staleness=3)
    np.testing.assert_array_equal(w, np.zeros(2))
    with pytest.raises(ValueError, match="share a shape"):
        staleness_weights(np.ones(3), np.zeros(2, np.int64), d)
    with pytest.raises(ValueError, match="share a shape"):
        arrival_order(np.arange(3), np.ones(3, bool), np.zeros(2))


# ----------------------------------------- degenerate ≡ sync equivalence
@pytest.mark.parametrize("backend", ["host", "compiled"])
def test_degenerate_async_bit_identical_to_sync(backend, data):
    """The backbone contract: ``dispatch="sync"`` + discount off is the
    lock-step engine — params, selections, history, comm, sim_clock all
    bit-identical (the cross-task conformance cells ride on this)."""
    train, test = data
    kw = dict(backend=backend, rounds=4, eval_every=2,
              systems=_sys(deadline_s=30.0, over_select=1.3))
    sync = make_engine(_cfg(**kw), train, test, 10)
    dgen = make_engine(_cfg(async_mode={"dispatch": "sync"}, **kw),
                       train, test, 10)
    rs, rd = list(sync.rounds()), list(dgen.rounds())
    for a, b in zip(rs, rd):
        assert a.selected == b.selected
        assert a.comm_mb == b.comm_mb
        assert a.sim_clock == b.sim_clock and a.sim_time == b.sim_time
        assert a.n_dropped == b.n_dropped
        # lock-step semantics: no staleness, version = round + 1
        assert b.staleness == 0.0 and b.params_version == a.round + 1
    assert sync.history == dgen.history
    assert _max_err(sync.params, dgen.params) == 0.0


# ------------------------------------------------------ buffer semantics
def test_buffer_fires_at_exactly_buffer_k_arrivals(data):
    """With the idle population never exhausted, every aggregation step
    pops exactly ``buffer_k`` arrivals — never more, never fewer."""
    train, test = data
    cfg = _async_cfg(systems=_sys(availability="always"),
                     async_mode={"buffer_k": 3, "concurrency": 8})
    eng = make_engine(cfg, train, test, 10)
    results = list(eng.rounds())
    assert eng._buffer_k == 3
    for r in results:
        assert len(r.selected) + r.n_dropped == 3
        assert r.n_dropped == 0  # no max_staleness → nothing dropped
    # the in-flight target is respected between steps
    assert eng._n_inflight() <= 8


def test_max_staleness_drops_stale_arrivals_with_zero_weight(data):
    """``max_staleness=0``: only updates trained against the *current*
    params version aggregate; anything staler is dropped — and the
    reported mean staleness over the kept set is exactly 0."""
    train, test = data
    cfg = _async_cfg(async_mode={"buffer_k": 2, "concurrency": 8,
                                 "max_staleness": 0})
    eng = make_engine(cfg, train, test, 10)
    before = jax.device_get(eng.params)
    results = list(eng.rounds())
    assert sum(r.n_dropped for r in results) > 0   # the bound bites
    assert any(r.selected for r in results)        # ... but not everything
    for r in results:
        assert r.staleness == 0.0                  # kept ⊆ {s ≤ 0}
    assert _max_err(before, jax.device_get(eng.params)) > 0.0


def test_staleness_observed_without_bound(data):
    """Under a heterogeneous profile with no ``max_staleness``, slow
    clients really do arrive stale — the discount has something to do."""
    train, test = data
    cfg = _async_cfg(async_mode={"buffer_k": 2, "concurrency": 8,
                                 "staleness": "polynomial"})
    eng = make_engine(cfg, train, test, 10)
    results = list(eng.rounds())
    assert max(r.staleness for r in results) > 0.0
    assert all(r.n_dropped == 0 for r in results)


# --------------------------------------------------- event clock / versions
def test_event_clock_monotone_and_additive(data):
    train, test = data
    eng = make_engine(_async_cfg(), train, test, 10)
    results = list(eng.rounds())
    clock = 0.0
    for r in results:
        assert r.sim_time >= 0.0
        assert r.sim_clock == pytest.approx(clock + r.sim_time)
        assert r.sim_clock >= clock  # monotone, never rewinds
        clock = r.sim_clock
    assert clock > 0.0
    # the async event clock lands on arrival instants, not deadline
    # multiples — fractional by construction under a jittered profile
    assert any(r.sim_clock % 1.0 != 0.0 for r in results)


def test_params_version_counts_applied_aggregations(data):
    train, test = data
    eng = make_engine(_async_cfg(async_mode={
        "buffer_k": 3, "concurrency": 8, "max_staleness": 1,
    }), train, test, 10)
    prev = 0
    for r in eng.rounds():
        bump = 1 if r.selected else 0  # empty/fully-stale steps don't bump
        assert r.params_version == prev + bump
        prev = r.params_version
    assert prev >= 1 and eng._version == prev


def test_inflight_clients_never_double_dispatched(data):
    """Busy in-flight clients ride the -inf gate: at every step the
    pending ledger holds each client at most once."""
    train, test = data
    eng = make_engine(_async_cfg(), train, test, 10)
    for _ in eng.rounds():
        pending = np.concatenate(
            [g.sel[g.pending] for g in eng._ledger]
        ) if eng._ledger else np.zeros(0, np.int64)
        assert len(pending) == len(set(pending.tolist()))


# ------------------------------------------------------------ determinism
def test_same_seed_runs_bit_identical(data):
    train, test = data
    runs = []
    for _ in range(2):
        eng = make_engine(_async_cfg(), train, test, 10)
        runs.append((list(eng.rounds()), jax.device_get(eng.params)))
    (ra, pa), (rb, pb) = runs
    assert [r.selected for r in ra] == [r.selected for r in rb]
    assert [r.sim_clock for r in ra] == [r.sim_clock for r in rb]
    assert [r.params_version for r in ra] == [r.params_version for r in rb]
    assert _max_err(pa, pb) == 0.0


def test_async_host_and_compiled_agree(data):
    """The async loop drives the same backend hooks the conformance grid
    certifies: identical dispatch decisions, allclose params."""
    train, test = data
    host = make_engine(_async_cfg(backend="host"), train, test, 10)
    comp = make_engine(_async_cfg(backend="compiled"), train, test, 10)
    rh, rc = list(host.rounds()), list(comp.rounds())
    for a, b in zip(rh, rc):
        assert a.selected == b.selected
        assert a.params_version == b.params_version
        assert a.sim_clock == pytest.approx(b.sim_clock)
        assert a.comm_mb == pytest.approx(b.comm_mb)
    assert _max_err(host.params, comp.params) < 1e-5


# -------------------------------------------------------- kill-and-resume
@pytest.mark.parametrize("backend", ["host", "compiled"])
def test_async_kill_and_resume_mid_buffer_bit_identical(backend, data, tmp_path):
    """Acceptance: kill mid-run with a non-empty in-flight ledger,
    restore into a fresh engine, finish — selections, history, params,
    sim_clock, and params version all bit-identical to the
    uninterrupted run."""
    train, test = data
    cfg = _async_cfg(backend=backend, rounds=8, eval_every=2)

    ref = make_engine(cfg, train, test, 10)
    ref_results = list(ref.rounds())
    ref_params = jax.device_get(ref.params)

    killed = make_engine(cfg, train, test, 10)
    it = killed.rounds()
    pre = [next(it) for _ in range(4)]
    it.close()  # the "kill": mid-run abandonment
    assert killed._ledger and killed._n_inflight() > 0  # genuinely mid-buffer
    path = str(tmp_path / "async.ckpt")
    killed.save(path)

    resumed = make_engine(cfg, train, test, 10)
    resumed.restore(path)
    assert resumed._round == 4
    assert resumed._version == killed._version
    assert resumed._n_inflight() == killed._n_inflight()
    post = list(resumed.rounds())

    full = pre + post
    assert [r.round for r in full] == [r.round for r in ref_results]
    assert [r.selected for r in full] == [r.selected for r in ref_results]
    assert [r.sim_clock for r in full] == [r.sim_clock for r in ref_results]
    assert [r.comm_mb for r in full] == [r.comm_mb for r in ref_results]
    assert [r.params_version for r in full] == [
        r.params_version for r in ref_results
    ]
    assert resumed.history.keys() == ref.history.keys()
    for k in ref.history:
        np.testing.assert_array_equal(
            np.asarray(resumed.history[k]), np.asarray(ref.history[k])
        )
    assert _max_err(ref_params, jax.device_get(resumed.params)) == 0.0


def test_async_restore_rejects_foreign_checkpoints(data, tmp_path):
    """A sync checkpoint has no ledger meta — the async engine refuses
    it loudly; and a plain engine can't restore an async checkpoint (the
    state trees don't match)."""
    train, test = data
    sync_cfg = _cfg(systems=_sys())
    async_cfg_ = _async_cfg()
    sync_path = str(tmp_path / "sync.ckpt")
    make_engine(sync_cfg, train, test, 10).save(sync_path)
    with pytest.raises(ValueError, match="no async ledger"):
        make_engine(async_cfg_, train, test, 10).restore(sync_path)

    async_path = str(tmp_path / "async.ckpt")
    eng = make_engine(async_cfg_, train, test, 10)
    it = eng.rounds()
    next(it)
    it.close()
    eng.save(async_path)
    with pytest.raises(ValueError):
        make_engine(sync_cfg, train, test, 10).restore(async_path)


def test_async_compiled_requires_cohort_gather(data):
    from repro.engine import AsyncCompiledEngine

    train, test = data
    with pytest.raises(ValueError, match="cohort_gather"):
        AsyncCompiledEngine(_async_cfg(backend="compiled"), train, test, 10,
                            cohort_gather=False)


# ------------------------------------------------- fedcs (follow-up (n))
def test_fedcs_ranks_by_predicted_round_time():
    from repro.core.strategies import get_strategy

    rng = np.random.default_rng(0)
    hists = rng.dirichlet(np.ones(10), size=8)
    lat = np.array([5.0, 1.0, 9.0, 2.0, 7.0, 3.0, 8.0, 4.0])
    s = get_strategy("fedcs", m=3)
    s.setup(hists, np.full(8, 50.0), seed=0, latency=lat)
    losses = np.zeros(8, np.float32)
    np.testing.assert_array_equal(s.select(0, losses, None), [1, 3, 5])
    # offline (-inf-gated) clients fall behind every online one
    gated = losses.copy()
    gated[[1, 3]] = -np.inf
    np.testing.assert_array_equal(s.select(0, gated, None), [0, 5, 7])
    # without a latency signal, deterministic lowest-index-first
    s2 = get_strategy("fedcs", m=3)
    s2.setup(hists, np.full(8, 50.0), seed=0)
    np.testing.assert_array_equal(s2.select(0, losses, None), [0, 1, 2])


def test_fedcs_drives_the_async_runtime(data):
    """The predicted-T_i strategy inside the async scheduler: it polls
    no losses, dispatches the fastest idle clients, and its buffer
    drains strictly faster than fedlecc's under the same profile."""
    train, test = data
    fast = make_engine(_async_cfg(strategy="fedcs"), train, test, 10)
    slow = make_engine(_async_cfg(), train, test, 10)  # fedlecc
    rf, rs = list(fast.rounds()), list(slow.rounds())
    assert all(r.selected for r in rf)
    assert rf[-1].sim_clock < rs[-1].sim_clock
    assert rf[-1].comm_mb < rs[-1].comm_mb  # no loss polls on dispatch
