"""Batch scheduler: bucketing, padding, EOS handling, result integrity."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import decode_step, init_transformer, prefill
from repro.serving import BatchScheduler


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3-14b", reduced=True)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_scheduler_drains_mixed_lengths(served):
    cfg, params = served
    sched = BatchScheduler(cfg, params, max_batch=3, max_new=4)
    rng = np.random.default_rng(0)
    ids = []
    for plen in (16, 16, 16, 16, 24, 24):   # two buckets, one underfull group
        ids.append(sched.submit(rng.integers(0, cfg.vocab, plen)))
    assert sched.pending() == 6
    done = sched.run()
    assert done == 6 and sched.pending() == 0
    for rid in ids:
        out = sched.result(rid)
        assert out.shape == (4,)
        assert (out >= 0).all() and (out < cfg.vocab).all()


def test_scheduler_matches_unbatched_decode(served):
    """A request served in a (padded) group produces exactly the same
    greedy tokens as a standalone prefill+decode."""
    cfg, params = served
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    sched = BatchScheduler(cfg, params, max_batch=4, max_new=5)
    rid = sched.submit(prompt)
    sched.run()
    got = sched.result(rid)

    import jax.numpy as jnp

    batch = {"tokens": jnp.asarray(prompt[None])}
    logits, cache = prefill(params, cfg, batch, max_len=16 + 5)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    want = [int(tok[0, 0])]
    for i in range(4):
        logits, cache = decode_step(params, cfg, {"token": tok}, cache, jnp.int32(16 + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        want.append(int(tok[0, 0]))
    np.testing.assert_array_equal(got, np.array(want))


def test_scheduler_eos_truncates(served):
    cfg, params = served
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    # find whatever token the model actually emits first and use it as EOS
    probe = BatchScheduler(cfg, params, max_batch=1, max_new=3)
    rid = probe.submit(prompt)
    probe.run()
    first = int(probe.result(rid)[0])
    sched = BatchScheduler(cfg, params, max_batch=1, max_new=6, eos_id=first)
    rid = sched.submit(prompt)
    sched.run()
    out = sched.result(rid)
    assert out[-1] == first and len(out) <= 6


def test_unfinished_result_raises(served):
    cfg, params = served
    sched = BatchScheduler(cfg, params, max_batch=2, max_new=2)
    rid = sched.submit(np.zeros(8, np.int32))
    with pytest.raises(RuntimeError):
        sched.result(rid)
