"""Algorithm 1: invariants (hypothesis) + numpy/JAX implementation
equivalence + aggregation-weight properties."""

import jax.numpy as jnp
import pytest
import numpy as np

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)

from hypothesis import given, settings, strategies as st

from repro.core.selection import fedlecc_select, fedlecc_select_jax, selection_weights


@st.composite
def selection_case(draw):
    k = draw(st.integers(4, 60))
    n_clusters = draw(st.integers(1, 8))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_clusters, k)
    losses = rng.uniform(0.1, 5.0, k).astype(np.float32)
    m = draw(st.integers(1, k))
    J = draw(st.integers(1, 10))
    return labels, losses, m, J


@given(selection_case())
@settings(max_examples=60, deadline=None)
def test_selection_invariants(case):
    labels, losses, m, J = case
    sel = fedlecc_select(labels, losses, m=m, J=J)
    assert len(sel) == min(m, len(labels))           # exactly m selected
    assert len(set(sel.tolist())) == len(sel)        # no duplicates
    assert (sel >= 0).all() and (sel < len(labels)).all()


@given(selection_case())
@settings(max_examples=60, deadline=None)
def test_numpy_jax_equivalence(case):
    labels, losses, m, J = case
    a = fedlecc_select(labels, losses, m=m, J=J)
    n_clusters = int(labels.max()) + 1
    Jj = max(1, min(J, len(np.unique(labels))))
    mask = np.asarray(
        fedlecc_select_jax(
            jnp.asarray(labels), jnp.asarray(losses), m=min(m, len(labels)),
            J=Jj, n_clusters=n_clusters,
        )
    )
    b = np.where(mask)[0]
    np.testing.assert_array_equal(a, b)


def test_top_cluster_highest_loss_client_always_selected():
    rng = np.random.default_rng(7)
    for _ in range(20):
        labels = rng.integers(0, 5, 40)
        losses = rng.uniform(0, 3, 40)
        sel = fedlecc_select(labels, losses, m=8, J=3)
        # the single highest-loss client of the highest-mean-loss cluster
        clusters = np.unique(labels)
        means = np.array([losses[labels == c].mean() for c in clusters])
        top_c = clusters[np.argmax(means)]
        members = np.where(labels == top_c)[0]
        star = members[np.argmax(losses[members])]
        assert star in sel


def test_cluster_diversity_respected():
    """With J=m and singleton-capacity z=1, selection spans J clusters."""
    labels = np.repeat(np.arange(5), 8)           # 5 clusters × 8 members
    rng = np.random.default_rng(1)
    losses = rng.uniform(1, 2, 40)
    sel = fedlecc_select(labels, losses, m=5, J=5)
    assert len(np.unique(labels[sel])) == 5


def test_backfill_when_cluster_small():
    # cluster 0: huge loss but only 1 member; z=3 forces backfill
    labels = np.array([0] + [1] * 6 + [2] * 6)
    losses = np.array([10.0] + [5.0] * 6 + [1.0] * 6)
    sel = fedlecc_select(labels, losses, m=6, J=2)
    assert 0 in sel
    assert len(sel) == 6


def test_selection_weights_properties():
    mask = jnp.asarray(np.array([1, 0, 1, 1, 0], bool))
    sizes = jnp.asarray(np.array([10.0, 20.0, 30.0, 40.0, 50.0]))
    w = np.asarray(selection_weights(mask, sizes))
    assert abs(w.sum() - 1.0) < 1e-6
    assert w[1] == 0 and w[4] == 0
    np.testing.assert_allclose(w[[0, 2, 3]], np.array([10, 30, 40]) / 80.0, atol=1e-6)
