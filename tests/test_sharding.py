"""Sharding policy: divisibility guards, spec construction, full-config
coverage (eval_shape only — no allocation)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_configs
from repro.models.transformer import init_transformer, transformer_specs
from repro.sharding import make_policy


class FakeMesh:
    """Shape-only stand-in (tests run on 1 CPU device; policy math is pure)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_divisibility_guard_replicates():
    mesh = FakeMesh({"data": 16, "model": 16})
    pol = make_policy(mesh, batch_size=256)
    # vocab 32001 (hymba) does not divide 16 → replicated
    assert pol.spec_for(("vocab", "embed"), (32001, 1600)) == P()
    # vocab 151936 divides → sharded on model
    assert pol.spec_for(("vocab", "embed"), (151936, 5120)) == P("model")


def test_batch_rule():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    pol = make_policy(mesh, batch_size=256)
    assert pol.spec_for(("batch", "seq_in"), (256, 4096)) == P(("pod", "data"))
    # batch 1 → replicated
    pol1 = make_policy(mesh, batch_size=1)
    assert pol1.spec_for(("batch", "seq_in"), (1, 4096)) == P()


def test_seq_sharding_for_long_decode():
    mesh = FakeMesh({"data": 16, "model": 16})
    pol = make_policy(mesh, batch_size=1, shard_seq=True)
    spec = pol.spec_for(("layers", "batch", "seq", "kv_heads", None),
                        (62, 1, 524288, 16, 128))
    assert spec == P(None, None, ("data",), "model")


def test_no_mesh_axis_reuse():
    mesh = FakeMesh({"data": 4, "model": 4})
    pol = make_policy(mesh, batch_size=16)
    # both dims want 'model' — second must be dropped
    spec = pol.spec_for(("experts", "ffn"), (16, 64))
    assert spec == P("model")


def test_fsdp_variant_rules():
    mesh = FakeMesh({"data": 16, "model": 16})
    pol = make_policy(mesh, batch_size=256, variant="fsdp")
    # batch shards over ALL axes (256-way)
    assert pol.spec_for(("batch", "seq_in"), (256, 4096)) == P(("data", "model"))
    # weights stored sharded over all axes (ZeRO-3)
    assert pol.spec_for(("embed", "ffn"), (5120, 17408)) == P(None, ("data", "model"))
    # divisibility guard still applies (17408 % 256 = 0 ✓; 100 % 256 ✗)
    assert pol.spec_for(("embed", "ffn"), (5120, 100)) == P()


@pytest.mark.parametrize("arch", list_configs())
@pytest.mark.parametrize("mesh_shape", [{"data": 16, "model": 16},
                                        {"pod": 2, "data": 16, "model": 16}])
def test_full_config_specs_build(arch, mesh_shape):
    """Every full config's param tree gets a valid NamedSharding tree on
    both production meshes (structure + divisibility)."""
    cfg = get_config(arch)
    mesh = FakeMesh(mesh_shape)
    pol = make_policy(mesh, batch_size=256)
    pshapes = jax.eval_shape(lambda k: init_transformer(k, cfg), jax.random.PRNGKey(0))
    specs = transformer_specs(cfg)
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, tuple, type(None))) for e in x
    )
    flat_specs = jax.tree.leaves(specs, is_leaf=is_axes)
    flat_shapes = jax.tree.leaves(pshapes)
    assert len(flat_specs) == len(flat_shapes)
    for sp, sh in zip(flat_specs, flat_shapes):
        pspec = pol.spec_for(sp, sh.shape)   # must not raise
        # guard actually holds: every sharded dim divides
        for dim, entry in zip(sh.shape, list(pspec) + [None] * 10):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % total == 0
