"""Dirichlet partitioner: exactness, skew monotonicity, HD calibration."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)

from hypothesis import given, settings, strategies as st

from repro.core.hellinger import average_hd
from repro.data.partition import (
    calibrate_alpha,
    dirichlet_partition,
    label_histograms,
    pack_clients,
)
from repro.data.synthetic import make_classification


@given(
    st.integers(2, 12),            # clients
    st.floats(0.05, 10.0),         # alpha
    st.integers(0, 10**6),         # seed
)
@settings(max_examples=25, deadline=None)
def test_partition_is_exact(k, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, 400)
    parts = dirichlet_partition(labels, k, alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == 400
    assert len(np.unique(allidx)) == 400           # every sample exactly once
    assert all(len(p) >= 8 for p in parts)          # min-size guarantee


def test_skew_monotone_in_alpha():
    """Monotone in the practical range (extreme-skew top-up causes known
    mild non-monotonicity below ~0.05 — see calibrate_alpha docstring)."""
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 5000)
    hds = []
    for alpha in [0.1, 0.5, 2.0, 10.0]:
        parts = dirichlet_partition(labels, 20, alpha, seed=0)
        h = label_histograms(labels, parts, 10)
        hds.append(float(average_hd(h)))
    assert hds[0] > hds[-1]                        # more alpha → more IID
    assert hds == sorted(hds, reverse=True)


def test_calibrate_alpha_hits_target():
    rng = np.random.default_rng(1)
    labels = rng.integers(0, 10, 8000)
    for target in [0.9, 0.7]:
        alpha = calibrate_alpha(labels, 50, target, 10, seed=1)
        parts = dirichlet_partition(labels, 50, alpha, seed=1)
        hd = float(average_hd(label_histograms(labels, parts, 10)))
        assert abs(hd - target) < 0.06


def test_pack_clients_masks_padding():
    ds = make_classification(300, n_features=64 * 1, n_classes=4, seed=0)
    # n_features must be square: use 64 → 8×8
    parts = dirichlet_partition(ds.y, 6, 0.3, seed=0)
    xs, ys, mask = pack_clients(ds.x, ds.y, parts)
    assert xs.shape[0] == 6 and xs.shape[1] == max(len(p) for p in parts)
    for i, p in enumerate(parts):
        assert mask[i].sum() == len(p)
        np.testing.assert_array_equal(ys[i, : len(p)], ds.y[p])


def test_shard_partition_balanced_and_skewed():
    from repro.data.partition import calibrate_shards, shard_partition

    rng = np.random.default_rng(3)
    labels = rng.integers(0, 10, 10_000)
    parts = shard_partition(labels, 100, shards_per_client=1, seed=0)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == 10_000           # exact partition
    sizes = np.array([len(p) for p in parts])
    assert sizes.max() - sizes.min() <= 2              # balanced
    h = label_histograms(labels, parts, 10)
    # 1 shard/client ⇒ (almost) single-class clients ⇒ HD ≈ 0.909
    hd = float(average_hd(h))
    assert 0.85 < hd < 0.95
    # calibration picks more shards for milder targets
    s_mild = calibrate_shards(labels, 100, 0.6, 10, seed=0)
    assert s_mild > 1


def test_histograms_normalized():
    rng = np.random.default_rng(2)
    labels = rng.integers(0, 7, 900)
    parts = dirichlet_partition(labels, 9, 0.2, seed=2)
    h = label_histograms(labels, parts, 7)
    np.testing.assert_allclose(h.sum(1), 1.0, atol=1e-9)
