"""MoE: capacity path vs dense oracle, shard-sum decomposition, gradients,
router properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)

from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import (
    _shared_expert, init_moe, moe_capacity, moe_dense,
)


def _cfg(e=8, k=2, shared=1, cf=100.0, d=64, fe=32):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=d, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=100, dtype="float32",
        moe=MoEConfig(n_experts=e, top_k=k, d_expert=fe, n_shared=shared,
                      capacity_factor=cf),
    )


@given(
    st.integers(2, 16),    # experts
    st.integers(1, 4),     # top_k
    st.integers(0, 1),     # shared
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_capacity_matches_dense_with_ample_capacity(e, k, shared, seed):
    k = min(k, e)
    cfg = _cfg(e=e, k=k, shared=shared)
    p = init_moe(jax.random.PRNGKey(seed % 1000), cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (2, 8, 64)), jnp.float32)
    yd, auxd = moe_dense(p, cfg, x)
    yc, auxc = moe_capacity(p, cfg, x.reshape(-1, 64))
    np.testing.assert_allclose(
        np.asarray(yd).reshape(-1, 64), np.asarray(yc), atol=5e-5
    )
    assert abs(float(auxd) - float(auxc)) < 1e-6


def test_shard_partials_sum_to_dense():
    cfg = _cfg(e=8, k=2, shared=1)
    p = init_moe(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    x2d = jnp.asarray(rng.normal(0, 1, (32, 64)), jnp.float32)

    def shard(lo, hi):
        q = dict(p)
        for key in ("w_gate", "w_up", "w_down"):
            q[key] = p[key][lo:hi]
        return q

    parts = [
        moe_capacity(shard(o, o + 2), cfg, x2d, expert_offset=o,
                     n_local_experts=2, include_shared=False)[0]
        for o in range(0, 8, 2)
    ]
    total = sum(parts) + _shared_expert(p, cfg, x2d)
    want, _ = moe_dense(p, cfg, x2d.reshape(1, 32, 64))
    np.testing.assert_allclose(np.asarray(total), np.asarray(want)[0], atol=5e-5)


def test_gradients_match_dense():
    cfg = _cfg(e=4, k=2, shared=1)
    p = init_moe(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (2, 8, 64)), jnp.float32)

    gd = jax.grad(lambda p_: jnp.sum(moe_dense(p_, cfg, x)[0] ** 2))(p)
    gc = jax.grad(
        lambda p_: jnp.sum(moe_capacity(p_, cfg, x.reshape(-1, 64))[0] ** 2)
    )(p)
    for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_capacity_drops_lowest_weight_on_overflow():
    """With capacity 1 token per expert, the highest-weight assignment
    survives."""
    cfg = _cfg(e=2, k=1, shared=0, cf=1e-9)  # cap = max(1, ~0) = 1
    p = init_moe(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(5)
    x2d = jnp.asarray(rng.normal(0, 1, (6, 64)), jnp.float32)
    y, _ = moe_capacity(p, cfg, x2d)
    # at most 2 tokens (1 per expert) produce nonzero output
    nonzero = (np.abs(np.asarray(y)).max(axis=1) > 1e-7).sum()
    assert nonzero <= 2


def test_aux_loss_balanced_router_is_one():
    """Uniform routing gives aux = E · Σ (1/E)(1/E) · E = 1."""
    cfg = _cfg(e=4, k=4, shared=0)  # top_k = E → f uniform
    p = init_moe(jax.random.PRNGKey(6), cfg)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])   # uniform probs
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, 64)), jnp.float32)
    _, aux = moe_dense(p, cfg, x)
    assert abs(float(aux) - 1.0) < 1e-5
